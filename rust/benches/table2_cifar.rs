//! Table 2 (+ Table 4b row) — CIFAR-scale (3072 px) generation throughput.
//!
//! Same protocol as table1_mnist at 4x the sequence length, where the gap
//! between O(1)-per-token linear decode and the quadratic baselines widens
//! (paper: 4,462x over softmax). Quadratic rows are prefix-measured and
//! extrapolated (~).
//!
//! Run: cargo bench --bench table2_cifar  (BENCH_QUICK=1 for a fast pass)

use std::time::Duration;

use linear_transformer::attention::AttentionKind;
use linear_transformer::benchkit::Table;
use linear_transformer::benchkit_gen::measure_steps;
use linear_transformer::config::ModelConfig;
use linear_transformer::nn::TransformerLM;
use linear_transformer::rng::Rng;

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let budget = Duration::from_secs(if quick { 5 } else { 12 });
    let cfg = ModelConfig::cifar();
    let n = cfg.max_len;

    let mut table = Table::new(
        "Table 2: CIFAR-scale (3072 px) generation throughput",
        &["method", "images/sec", "speedup_vs_softmax", "measured_px"],
    );
    let mut rows: Vec<(String, f64, usize)> = Vec::new();

    let variants: Vec<(String, AttentionKind, bool)> = vec![
        ("softmax".into(), AttentionKind::Softmax, false),
        ("stateful-softmax".into(), AttentionKind::Softmax, true),
        ("lsh-1".into(), AttentionKind::Lsh { rounds: 1 }, false),
        ("lsh-4".into(), AttentionKind::Lsh { rounds: 4 }, false),
        ("linear (ours)".into(), AttentionKind::Linear, false),
    ];
    for (name, kind, kv) in variants {
        let model = TransformerLM::init(&cfg, kind, 1);
        // the "softmax" row is the naive full-recompute baseline; plain
        // session() would now route softmax models through the KV cache
        let mut sess = if kv {
            model.session_kv()
        } else if kind == AttentionKind::Softmax {
            model.session_recompute()
        } else {
            model.session()
        };
        let mut rng = Rng::new(0);
        let mut logits = sess.step(0);
        let is_linear = kind == AttentionKind::Linear;
        let this_budget = if is_linear { Duration::from_secs(3600) } else { budget };
        let m = measure_steps(n - 1, this_budget, |_t| {
            let px = linear_transformer::sampling::sample_logits(&logits, 1.0, &mut rng);
            logits = sess.step(px);
        });
        rows.push((
            format!("{name}{}", m.label()),
            1.0 / m.total_secs,
            m.steps_measured,
        ));
    }

    let softmax_ips = rows[0].1;
    for (name, ips, measured) in rows {
        table.row(vec![
            name,
            format!("{ips:.4}"),
            format!("{:.1}x", ips / softmax_ips),
            measured.to_string(),
        ]);
    }
    table.emit("table2_cifar.csv");
    println!("\n(~ = prefix-measured + extrapolated tail; see EXPERIMENTS.md)");
}
