//! Tables 4 & 5 (supplementary) — stateful baselines and batch-1 latency.
//!
//! Table 5: seconds to generate a single image (batch size 1, CPU) for
//! every decode strategy, on both the MNIST (784) and CIFAR (3072)
//! geometries. Table 4's extra observation — stateful-softmax is much
//! faster than vanilla softmax but still far behind linear, with a state
//! that grows per token — falls out of the same sweep, so both tables are
//! emitted here. Quadratic rows are prefix-measured and extrapolated (~).
//!
//! Expected shape (paper, CPU column): linear fastest (5.5s MNIST / 45s
//! CIFAR on their hardware), stateful-softmax ~1.3-1.6x slower, softmax
//! 13-192x slower, lsh in between; linear is the only method whose decode
//! state does not grow.
//!
//! Run: cargo bench --bench table45_latency  (BENCH_QUICK=1 for a fast pass)

use std::time::Duration;

use linear_transformer::attention::AttentionKind;
use linear_transformer::benchkit::Table;
use linear_transformer::benchkit_gen::measure_steps;
use linear_transformer::config::ModelConfig;
use linear_transformer::nn::TransformerLM;
use linear_transformer::rng::Rng;

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let budget = Duration::from_secs(if quick { 4 } else { 8 });

    let mut table = Table::new(
        "Tables 4+5: single-image latency, batch 1, CPU",
        &["dataset", "method", "seconds/image", "vs_linear", "state_end", "measured_px"],
    );

    for (dataset, cfg) in [("mnist", ModelConfig::mnist()), ("cifar", ModelConfig::cifar())] {
        let n = cfg.max_len;
        let mut rows: Vec<(String, f64, String, usize)> = Vec::new();
        let variants: Vec<(String, AttentionKind, bool)> = vec![
            ("softmax".into(), AttentionKind::Softmax, false),
            ("stateful-softmax".into(), AttentionKind::Softmax, true),
            ("lsh-1".into(), AttentionKind::Lsh { rounds: 1 }, false),
            ("lsh-4".into(), AttentionKind::Lsh { rounds: 4 }, false),
            ("linear (ours)".into(), AttentionKind::Linear, false),
        ];
        for (name, kind, kv) in variants {
            let model = TransformerLM::init(&cfg, kind, 1);
            // the "softmax" row is the naive full-recompute baseline;
            // plain session() would now route it through the KV cache
            let mut sess = if kv {
                model.session_kv()
            } else if kind == AttentionKind::Softmax {
                model.session_recompute()
            } else {
                model.session()
            };
            let mut rng = Rng::new(0);
            let mut logits = sess.step(0);
            let is_linear = kind == AttentionKind::Linear;
            let this_budget = if is_linear { Duration::from_secs(3600) } else { budget };
            let m = measure_steps(n - 1, this_budget, |_t| {
                let px = linear_transformer::sampling::sample_logits(&logits, 1.0, &mut rng);
                logits = sess.step(px);
            });
            let state = linear_transformer::benchkit::fmt_bytes(sess.state_bytes());
            rows.push((
                format!("{name}{}", m.label()),
                m.total_secs,
                if is_linear || kv {
                    format!("{state}{}", if is_linear { " (const)" } else { " (grown)" })
                } else {
                    format!("{state} (history)")
                },
                m.steps_measured,
            ));
        }
        let linear_secs = rows.last().unwrap().1;
        for (name, secs, state, measured) in rows {
            table.row(vec![
                dataset.to_string(),
                name,
                format!("{secs:.2}"),
                format!("{:.1}x", secs / linear_secs),
                state,
                measured.to_string(),
            ]);
        }
    }
    table.emit("table45_latency.csv");
    println!("\n(~ = prefix-measured + extrapolated; paper Table 5 CPU column is the comparison point)");

    // ---- batched serving throughput: per-slot loop vs one-GEMM-per-tick ----
    // The RNN view makes batch-B decode a dense [B, d, m] state block; this
    // sweep shows what that buys over advancing B sessions one at a time.
    let steps = if quick { 48 } else { 192 };
    let cfg = ModelConfig::mnist();
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 1);
    let mut btable = Table::new(
        "Batched decode throughput (mnist geometry, tokens/s)",
        &["batch", "per_slot_tok_s", "batched_tok_s", "speedup"],
    );
    for &b in &[1usize, 4, 16, 64] {
        let mut sessions: Vec<_> = (0..b).map(|_| model.session()).collect();
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            for sess in sessions.iter_mut() {
                let _ = sess.step((step % cfg.vocab) as u32);
            }
        }
        let per_slot = (b * steps) as f64 / t0.elapsed().as_secs_f64();

        // serial kernels here; the thread sweep below isolates the pool win
        let mut batched = model.batched_session_with_pool(b, None);
        for _ in 0..b {
            batched.alloc_row().expect("capacity");
        }
        let tokens: Vec<u32> = vec![0; b];
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let _ = batched.step_batch(&tokens);
        }
        let batched_tps = (b * steps) as f64 / t0.elapsed().as_secs_f64();
        btable.row(vec![
            b.to_string(),
            format!("{per_slot:.0}"),
            format!("{batched_tps:.0}"),
            format!("{:.2}x", batched_tps / per_slot),
        ]);
    }
    btable.emit("table45_batched_decode.csv");

    // ---- worker-pool thread sweep: the B=16 decode tick at 1..max cores ----
    let max_threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut sweep: Vec<usize> = [1usize, 2, 4, max_threads]
        .into_iter()
        .filter(|&t| t <= max_threads)
        .collect();
    sweep.dedup();
    let mut ttable = Table::new(
        "Batched decode tick vs GEMM-pool threads (mnist geometry, B=16)",
        &["threads", "tok_s", "speedup_vs_serial"],
    );
    let b = 16usize;
    let mut base = 0.0f64;
    for &threads in &sweep {
        let pool = if threads == 1 {
            None
        } else {
            Some(std::sync::Arc::new(linear_transformer::parallel::ThreadPool::new(threads)))
        };
        let mut batched = model.batched_session_with_pool(b, pool);
        for _ in 0..b {
            batched.alloc_row().expect("capacity");
        }
        let tokens: Vec<u32> = vec![0; b];
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let _ = batched.step_batch(&tokens);
        }
        let tok_s = (b * steps) as f64 / t0.elapsed().as_secs_f64();
        if threads == 1 {
            base = tok_s;
        }
        ttable.row(vec![
            threads.to_string(),
            format!("{tok_s:.0}"),
            format!("{:.2}x", tok_s / base),
        ]);
    }
    ttable.emit("table45_gemm_threads.csv");
}
