//! Table 1 (+ Table 4a row) — MNIST image-generation throughput.
//!
//! Generates 784-pixel images with every decode strategy over the same
//! model weights and reports images/sec:
//!   softmax           — recompute the full forward per pixel (O(t²)/px)
//!   stateful-softmax  — KV-cache decode (supplementary C.1, O(t)/px)
//!   lsh-1 / lsh-4     — Reformer decode (recompute; no stateful decode)
//!   linear            — the paper's RNN decode (O(1)/px)
//!   linear (pjrt)     — same through the batched AOT decode artifact
//!
//! Quadratic rows are measured on a step prefix and extrapolated (marked ~,
//! see benchkit_gen). Expected shape: linear orders of magnitude above
//! softmax/lsh, stateful-softmax in between — paper ratios 317x / 0.6-1.5x.
//!
//! Run: cargo bench --bench table1_mnist  (BENCH_QUICK=1 for a fast pass)

use std::time::Duration;

use linear_transformer::attention::AttentionKind;
use linear_transformer::benchkit::Table;
use linear_transformer::benchkit_gen::measure_steps;
use linear_transformer::config::ModelConfig;
use linear_transformer::nn::TransformerLM;
use linear_transformer::rng::Rng;

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let budget = Duration::from_secs(if quick { 5 } else { 12 });
    let cfg = ModelConfig::mnist();
    let n = cfg.max_len;

    let mut table = Table::new(
        "Table 1: MNIST (784 px) generation throughput",
        &["method", "images/sec", "speedup_vs_softmax", "decode_state", "measured_px"],
    );

    let mut rows: Vec<(String, f64, String, usize)> = Vec::new();

    // softmax: full recompute per pixel
    {
        let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 1);
        let mut sess = model.session_recompute();
        let mut rng = Rng::new(0);
        let mut logits = sess.step(0);
        let m = measure_steps(n - 1, budget, |_t| {
            let px = linear_transformer::sampling::sample_logits(&logits, 1.0, &mut rng);
            logits = sess.step(px);
        });
        rows.push((
            format!("softmax{}", m.label()),
            1.0 / m.total_secs,
            format!("{} B (history)", sess.state_bytes()),
            m.steps_measured,
        ));
    }

    // stateful softmax (KV cache)
    {
        let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 1);
        let mut sess = model.session_kv();
        let mut rng = Rng::new(0);
        let mut logits = sess.step(0);
        let m = measure_steps(n - 1, budget, |_t| {
            let px = linear_transformer::sampling::sample_logits(&logits, 1.0, &mut rng);
            logits = sess.step(px);
        });
        rows.push((
            format!("stateful-softmax{}", m.label()),
            1.0 / m.total_secs,
            format!("{} B (grows)", sess.state_bytes()),
            m.steps_measured,
        ));
    }

    // lsh-1, lsh-4: recompute decode
    for rounds in [1usize, 4] {
        let model = TransformerLM::init(&cfg, AttentionKind::Lsh { rounds }, 1);
        let mut sess = model.session();
        let mut rng = Rng::new(0);
        let mut logits = sess.step(0);
        let m = measure_steps(n - 1, budget, |_t| {
            let px = linear_transformer::sampling::sample_logits(&logits, 1.0, &mut rng);
            logits = sess.step(px);
        });
        rows.push((
            format!("lsh-{rounds}{}", m.label()),
            1.0 / m.total_secs,
            format!("{} B (history)", sess.state_bytes()),
            m.steps_measured,
        ));
    }

    // linear: the RNN decode — fast enough to measure fully
    {
        let model = TransformerLM::init(&cfg, AttentionKind::Linear, 1);
        let mut sess = model.session();
        let mut rng = Rng::new(0);
        let mut logits = sess.step(0);
        let m = measure_steps(n - 1, Duration::from_secs(3600), |_t| {
            let px = linear_transformer::sampling::sample_logits(&logits, 1.0, &mut rng);
            logits = sess.step(px);
        });
        assert!(!m.extrapolated);
        rows.push((
            "linear (ours)".into(),
            1.0 / m.total_secs,
            format!("{} B (constant)", sess.state_bytes()),
            m.steps_measured,
        ));
    }

    // linear through the PJRT batched decode artifact, if built
    let art_dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&art_dir).join("manifest.json").exists() {
        if let Ok(ips) = pjrt_linear_images_per_sec(&art_dir, &cfg, 32) {
            rows.push((
                "linear (pjrt, batch 32)".into(),
                ips,
                "constant".into(),
                n,
            ));
        }
    }

    let softmax_ips = rows[0].1;
    for (name, ips, state, measured) in rows {
        table.row(vec![
            name,
            format!("{ips:.3}"),
            format!("{:.1}x", ips / softmax_ips),
            state,
            measured.to_string(),
        ]);
    }
    table.emit("table1_mnist.csv");
    println!("\n(~ = prefix-measured, quadratic/linear tail extrapolated; see EXPERIMENTS.md)");
}

/// Images/sec of the batched PJRT decode artifact (all slots aligned).
fn pjrt_linear_images_per_sec(
    dir: &str,
    cfg: &ModelConfig,
    batch: usize,
) -> anyhow::Result<f64> {
    use linear_transformer::runtime::{Runtime, Value};
    let mut rt = Runtime::open(dir)?;
    let art = rt.load(&format!("mnist_decode_linear_b{batch}"))?;
    let weights = rt.load_weights("mnist_linear")?;
    let spec = rt.bundle.model("mnist_linear").unwrap().clone();
    let params: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_head());
    let mut s = vec![0.0f32; l * batch * h * dh * dh];
    let mut z = vec![0.0f32; l * batch * h * dh];
    let mut rng = Rng::new(0);
    let mut token = vec![0i32; batch];
    // time a slice of steps, scale to the full image
    let steps = 64usize;
    let t0 = std::time::Instant::now();
    for pos in 0..steps {
        let mut inputs = params.clone();
        inputs.push(Value::I32(vec![batch], token.clone()));
        inputs.push(Value::I32(vec![batch], vec![pos as i32; batch]));
        inputs.push(Value::F32(vec![l, batch, h, dh, dh], s));
        inputs.push(Value::F32(vec![l, batch, h, dh], z));
        let out = art.run(&inputs)?;
        let logits = out[0].as_f32()?;
        for (b, t) in token.iter_mut().enumerate() {
            *t = linear_transformer::sampling::sample_logits(
                &logits[b * cfg.vocab..(b + 1) * cfg.vocab],
                1.0,
                &mut rng,
            ) as i32;
        }
        s = out[1].as_f32()?.to_vec();
        z = out[2].as_f32()?.to_vec();
    }
    let per_step = t0.elapsed().as_secs_f64() / steps as f64;
    Ok(batch as f64 / (per_step * cfg.max_len as f64))
}
