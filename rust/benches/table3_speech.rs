//! Table 3 — speech recognition (CTC): training time per epoch + PER.
//!
//! For each encoder (Bi-LSTM, softmax transformer, linear transformer) the
//! bench measures the PJRT train-step wall time on synthetic WSJ-shaped
//! batches and scales it to a fixed-size epoch, reproducing the paper's
//! time/epoch column. PER is evaluated with greedy CTC decoding after a
//! short warm-up training run (documented: paper trains to convergence —
//! hours; the *ordering* of time/epoch and the PER trend are the
//! reproduction targets; see EXPERIMENTS.md).
//!
//! Run: cargo bench --bench table3_speech  (BENCH_QUICK=1 for a fast pass)

use linear_transformer::benchkit::Table;
use linear_transformer::data::speech::{BLANK, VOCAB};
use linear_transformer::metrics::{ctc_greedy_decode, phoneme_error_rate};
use linear_transformer::runtime::{Runtime, Value};
use linear_transformer::trainer::{self, Trainer};

const EPOCH_UTTERANCES: usize = 512; // synthetic-WSJ epoch size

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let warmup_steps = if quick { 4 } else { 12 };
    let timing_steps = if quick { 2 } else { 3 };

    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("artifacts not built; run `make artifacts`");
        return;
    }
    let mut rt = Runtime::open(&dir).unwrap();

    let mut table = Table::new(
        "Table 3: speech CTC — validation PER + training time/epoch",
        &["method", "PER_%", "time/epoch_s", "ms/step", "steps_trained"],
    );

    for variant in ["bilstm", "softmax", "linear"] {
        let mut tr = Trainer::new(&mut rt, "speech", variant).unwrap();
        let specs = tr.batch_specs().to_vec();
        let (b, t) = (specs[0].shape[0], specs[0].shape[1]);
        let max_labels = specs[2].shape[1];
        let mut batch_fn = trainer::speech_batch_fn(t, b, max_labels, 0);

        // short training warm-up so PER is meaningfully below chance
        for step in 0..warmup_steps {
            tr.step(1e-3, batch_fn(step)).unwrap();
        }
        // timed steps
        let t0 = std::time::Instant::now();
        for step in 0..timing_steps {
            tr.step(1e-3, batch_fn(warmup_steps + step)).unwrap();
        }
        let per_step = t0.elapsed().as_secs_f64() / timing_steps as f64;
        let steps_per_epoch = EPOCH_UTTERANCES.div_ceil(b);
        let epoch_secs = per_step * steps_per_epoch as f64;

        // PER via the fwd artifact + greedy decode on held-out batches
        let per = eval_per(&mut rt, variant, &tr, b, t, max_labels);

        table.row(vec![
            variant.to_string(),
            format!("{per:.1}"),
            format!("{epoch_secs:.1}"),
            format!("{:.0}", per_step * 1e3),
            (warmup_steps + timing_steps).to_string(),
        ]);
    }
    table.emit("table3_speech.csv");
    println!(
        "\n(epoch = {EPOCH_UTTERANCES} synthetic utterances; PER after only \
         {warmup_steps}+{timing_steps} steps — orderings, not absolute paper values)"
    );
}

fn eval_per(
    rt: &mut Runtime,
    variant: &str,
    tr: &Trainer,
    b: usize,
    t: usize,
    max_labels: usize,
) -> f64 {
    let fwd = rt.load(&format!("speech_{variant}_fwd")).unwrap();
    let weights = tr.weights().unwrap();
    let spec = rt.bundle.model(&format!("speech_{variant}")).unwrap().clone();
    let params: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let mut gen = linear_transformer::data::SpeechDataset::new(t, 777);
    let mut pairs = Vec::new();
    for _ in 0..2 {
        let (feats, frame_len, labels, label_len) = gen.batch(b, max_labels);
        let mut inputs = params.clone();
        inputs.push(Value::F32(
            vec![b, t, linear_transformer::data::speech::N_MELS],
            feats,
        ));
        let out = fwd.run(&inputs).unwrap();
        let logp = out[0].as_f32().unwrap();
        for bi in 0..b {
            let frames = frame_len[bi] as usize;
            let hyp = ctc_greedy_decode(
                &logp[bi * t * VOCAB..(bi * t + frames) * VOCAB],
                frames,
                VOCAB,
                BLANK,
            );
            let reference: Vec<u32> = labels
                [bi * max_labels..bi * max_labels + label_len[bi] as usize]
                .iter()
                .map(|&l| l as u32)
                .collect();
            pairs.push((hyp, reference));
        }
    }
    phoneme_error_rate(&pairs)
}
