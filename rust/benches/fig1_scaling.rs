//! Figure 1 — forward+backward time and peak memory vs sequence length.
//!
//! Sweeps N ∈ {2^9 .. 2^14} for softmax, linear, lsh-4 and lsh-8 attention
//! (per head, D = M = 32 like the paper's per-head dims), timing one
//! fwd+bwd pass per sample and reporting the engines' peak-memory models
//! (asserted against actual buffer allocation in the unit tests).
//!
//! Expected shape (paper): softmax grows ~4x per N-doubling in both time
//! and memory and runs out of budget first; linear and lsh grow ~2x
//! (linear in N); linear is fastest with constant O(D·M) extra memory.
//!
//! Run: cargo bench --bench fig1_scaling   (BENCH_QUICK=1 for a fast pass)

use std::time::Duration;

use linear_transformer::attention::{cost_fwd_bwd, linear, lsh, softmax, AttentionKind};
use linear_transformer::benchkit::{fmt_bytes, fmt_duration, opts_from_env, Table};
use linear_transformer::rng::Rng;

const D: usize = 32;
const M: usize = 32;

fn main() {
    let quick = std::env::var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let max_n: usize = if quick { 1 << 12 } else { 1 << 13 };
    let opts = opts_from_env();
    let budget_per_cfg = Duration::from_secs(if quick { 3 } else { 8 });

    let mut table = Table::new(
        "Figure 1: fwd+bwd per sample vs sequence length (per head, D=M=32)",
        &["method", "N", "time", "time_per_token", "peak_memory"],
    );

    let mut n = 512usize;
    while n <= max_n {
        let mut rng = Rng::new(n as u64);
        let q = rng.normal_vec(n * D, 1.0);
        let k = rng.normal_vec(n * D, 1.0);
        let v = rng.normal_vec(n * M, 1.0);
        let g = rng.normal_vec(n * M, 1.0);

        // --- softmax (skip when the quadratic cost exceeds the budget,
        //     like the paper's GPU running out of memory at N=4096) ---
        let est_secs = (n as f64 / 4096.0).powi(2) * 4.0;
        if est_secs < budget_per_cfg.as_secs_f64() * 4.0 {
            let m = linear_transformer::benchkit::bench(
                "softmax",
                linear_transformer::benchkit::BenchOpts {
                    max_total: budget_per_cfg,
                    ..opts
                },
                || {
                    let _ = softmax::forward_backward(&q, &k, &v, &g, n, D, M, true);
                },
            );
            push_row(&mut table, "softmax", AttentionKind::Softmax, n, &m);
        } else {
            table.row(vec![
                "softmax".into(),
                n.to_string(),
                "OOB (budget)".into(),
                "-".into(),
                fmt_bytes(
                    cost_fwd_bwd(AttentionKind::Softmax, n as u64, D as u64, M as u64)
                        .peak_bytes() as usize,
                ),
            ]);
        }

        // --- linear (the paper's kernel: constant-memory fwd+bwd) ---
        let m = linear_transformer::benchkit::bench(
            "linear",
            linear_transformer::benchkit::BenchOpts {
                max_total: budget_per_cfg,
                ..opts
            },
            || {
                let _ = linear::forward_backward_causal(&q, &k, &v, &g, n, D, M);
            },
        );
        push_row(&mut table, "linear", AttentionKind::Linear, n, &m);

        // --- lsh-4 / lsh-8 ---
        for rounds in [4usize, 8] {
            let cfg = lsh::LshConfig {
                rounds,
                buckets: 64.min(n / 16).max(2),
                chunk: 32,
                seed: 0,
            };
            let rots = lsh::make_rotations(&cfg, D);
            let m = linear_transformer::benchkit::bench(
                "lsh",
                linear_transformer::benchkit::BenchOpts {
                    max_total: budget_per_cfg,
                    ..opts
                },
                || {
                    let _ = lsh::forward_backward(&cfg, &rots, &q, &k, &v, &g, n, D, M, true);
                },
            );
            push_row(
                &mut table,
                &format!("lsh-{rounds}"),
                AttentionKind::Lsh { rounds },
                n,
                &m,
            );
        }
        n *= 2;
    }
    table.emit("fig1_scaling.csv");
    println!("\n(memory column = engine peak-allocation model; linear attention's is constant in N)");
}

fn push_row(
    table: &mut Table,
    name: &str,
    kind: AttentionKind,
    n: usize,
    m: &linear_transformer::benchkit::Measurement,
) {
    let cost = cost_fwd_bwd(kind, n as u64, D as u64, M as u64);
    table.row(vec![
        name.into(),
        n.to_string(),
        fmt_duration(m.mean),
        format!("{:.2} µs", m.mean.as_secs_f64() * 1e6 / n as f64),
        fmt_bytes(cost.peak_bytes() as usize),
    ]);
}
