//! Native-vs-PJRT full-forward parity on identical weights (placeholder
//! extended below; see also runtime_integration.rs).

use linear_transformer::attention::AttentionKind;
use linear_transformer::nn::TransformerLM;
use linear_transformer::runtime::{Runtime, Value};

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

#[test]
fn eval_loss_parity_all_lm_variants() {
    // for each lm model with an eval artifact, pjrt eval loss ~= native nll
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    for key in ["copy_linear", "copy_softmax"] {
        let eval = rt.load(&format!("{key}_eval")).unwrap();
        let weights = rt.load_weights(key).unwrap();
        let spec = rt.bundle.model(key).unwrap().clone();
        let cfg = &spec.config;
        let kind = match spec.attention.as_str() {
            "linear" => AttentionKind::Linear,
            "softmax" => AttentionKind::Softmax,
            _ => continue,
        };
        let params: Vec<Value> = spec
            .params
            .iter()
            .map(|n| Value::from_tensor(weights.req(n)))
            .collect();
        let shape = eval.spec.inputs[params.len()].shape.clone();
        let (b, n) = (shape[0], shape[1]);
        let mut gen = linear_transformer::data::CopyTask::new(n, 11);
        let lm = gen.batch(b);
        let mut inputs = params.clone();
        inputs.push(Value::I32(vec![b, n], lm.inputs.iter().map(|&t| t as i32).collect()));
        inputs.push(Value::I32(vec![b, n], lm.targets.iter().map(|&t| t as i32).collect()));
        inputs.push(Value::F32(vec![b, n], vec![1.0; b * n]));
        let pjrt_loss = eval.run(&inputs).unwrap()[0].scalar().unwrap() as f64;

        let native = TransformerLM::from_bundle(cfg, kind, &weights).unwrap();
        let mut total = 0.0;
        for s in 0..b {
            total += native.sequence_nll(
                &lm.inputs[s * n..(s + 1) * n],
                &lm.targets[s * n..(s + 1) * n],
            );
        }
        let native_nll = total / b as f64;
        assert!(
            (native_nll - pjrt_loss).abs() < 0.02,
            "{key}: native {native_nll:.4} vs pjrt {pjrt_loss:.4}"
        );
    }
}
