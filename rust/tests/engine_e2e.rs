//! End-to-end serving-engine tests, including the PJRT batched engine.

use linear_transformer::config::ServeConfig;
use linear_transformer::coordinator::engine::{PjrtEngine, PjrtEngineSpec};
use linear_transformer::coordinator::request::GenerateRequest;

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

#[test]
fn pjrt_engine_serves_batched_requests() {
    let Some(dir) = artifacts_dir() else { return };
    // mnist decode artifact exists at b=1 and b=32; use b=1 for speed here
    let mut handle = PjrtEngine::spawn(
        PjrtEngineSpec {
            artifacts_dir: dir,
            task: "copy".into(),
            model_cfg: linear_transformer::config::ModelConfig::small_copy(),
        },
        ServeConfig {
            max_batch: 1,
            max_wait_us: 100,
            ..Default::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = (0..3u64)
        .map(|id| {
            handle.submit(GenerateRequest {
                id,
                prompt: vec![12, 3, 4, 1],
                max_new: 6,
                temperature: 0.0,
                top_k: 0,
            })
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 6);
        assert!(resp.tokens.iter().all(|&t| t < 13));
    }
    let st = handle.stats();
    assert_eq!(st.completed, 3);
    handle.shutdown();
}
