//! Self-hosting tests for `lintra analyze`: per-rule positive and
//! negative fixtures, the suppression-pragma grammar, bitwise-critical
//! tag scoping, the interprocedural reachability model (call graph +
//! tick closure + `alloc` rule), lexer lifetime-tick regressions — and
//! the integration assertion the CI gate relies on: the repo's own tree
//! (`rust/src` + `examples`) analyzes clean modulo the committed
//! baseline.
//!
//! Fixtures are source *text*, not compiled code, so they deliberately
//! contain the constructs the rules forbid.

use linear_transformer::analysis::{
    analyze_paths, analyze_source, analyze_sources, Baseline, Finding, Rule,
};

/// A hot-path file name: rule `panic` applies.
const HOT: &str = "rust/src/coordinator/engine.rs";
/// A kernel file name: not hot-path, not an env/lock allowlist file.
const KERNEL: &str = "rust/src/tensor.rs";

fn rules_of(path: &str, src: &str) -> Vec<Rule> {
    analyze_source(path, src).into_iter().map(|f| f.rule).collect()
}

fn show(findings: &[Finding]) -> String {
    findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}\n", f.path, f.line, f.rule.slug(), f.message))
        .collect()
}

// ---------------------------------------------------------------------------
// rule `panic`
// ---------------------------------------------------------------------------

#[test]
fn panic_rule_flags_unwrap_expect_and_macros() {
    let src = r#"
fn f(x: Option<u32>) -> u32 {
    let a = x.unwrap();
    let b = x.expect("b");
    panic!("a: {a} b: {b}");
}
"#;
    let findings = analyze_source(HOT, src);
    assert_eq!(findings.len(), 3, "{}", show(&findings));
    assert!(findings.iter().all(|f| f.rule == Rule::Panic));
    assert_eq!(
        findings.iter().map(|f| f.line).collect::<Vec<_>>(),
        vec![3, 4, 5],
        "findings carry 1-based line numbers"
    );
}

#[test]
fn panic_rule_flags_fallible_indexing_but_not_plain_subscripts() {
    let src = r#"
fn f(v: &[u32], i: usize) -> u32 {
    let a = v[i];
    let b = v[i + 1];
    let c = &v[1..3];
    a + b + c[0]
}
"#;
    let findings = analyze_source(HOT, src);
    assert_eq!(findings.len(), 2, "{}", show(&findings));
    assert_eq!(findings[0].line, 4, "computed index `v[i + 1]`");
    assert_eq!(findings[1].line, 5, "range slice `v[1..3]`");
}

#[test]
fn panic_rule_applies_only_to_hot_path_files() {
    let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    assert_eq!(rules_of(HOT, src), vec![Rule::Panic]);
    assert!(rules_of(KERNEL, src).is_empty(), "tensor.rs is not hot-path");
}

#[test]
fn panic_rule_skips_unwrap_or_else_and_test_modules() {
    let src = r#"
fn f(x: Option<u32>) -> u32 {
    x.unwrap_or_else(|| 0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        Some(1).unwrap();
        let v = vec![1, 2];
        let _ = &v[0..2];
    }
}
"#;
    assert!(rules_of(HOT, src).is_empty());
}

// ---------------------------------------------------------------------------
// suppression pragmas
// ---------------------------------------------------------------------------

#[test]
fn inline_pragma_with_reason_suppresses() {
    let src = r#"
fn f(x: Option<u32>) -> u32 {
    x.unwrap() // lintra: allow(panic) -- checked non-empty by the caller
}
"#;
    assert!(rules_of(HOT, src).is_empty());
}

#[test]
fn own_line_pragma_covers_the_next_code_line() {
    let src = r#"
fn f(x: Option<u32>) -> u32 {
    // lintra: allow(panic) -- checked non-empty by the caller
    x.unwrap()
}
"#;
    assert!(rules_of(HOT, src).is_empty());
}

#[test]
fn pragma_without_reason_is_reported_and_does_not_suppress() {
    let src = r#"
fn f(x: Option<u32>) -> u32 {
    x.unwrap() // lintra: allow(panic)
}
"#;
    // both the original violation and the malformed pragma surface
    assert_eq!(rules_of(HOT, src), vec![Rule::Panic, Rule::Pragma]);
}

#[test]
fn pragma_naming_an_unknown_rule_is_reported() {
    let src = "// lintra: allow(bogus) -- misspelled\nfn f() {}\n";
    assert_eq!(rules_of(KERNEL, src), vec![Rule::Pragma]);
    let src = "// lintra: frobnicate the lints\nfn f() {}\n";
    assert_eq!(rules_of(KERNEL, src), vec![Rule::Pragma]);
}

#[test]
fn prose_mentioning_the_grammar_is_not_a_pragma() {
    let src = "// see the lintra: allow(panic) grammar in ARCHITECTURE.md\nfn f() {}\n";
    assert!(rules_of(HOT, src).is_empty());
}

#[test]
fn quoted_and_commented_violations_do_not_fire() {
    let src = r#"
fn f() -> &'static str {
    // a comment may say .unwrap() or panic! freely
    "so may a string: x.unwrap(); std::env::var(\"X\"); unsafe"
}
"#;
    assert!(rules_of(HOT, src).is_empty());
}

// ---------------------------------------------------------------------------
// rule `bitwise`
// ---------------------------------------------------------------------------

#[test]
fn bitwise_rule_fires_only_inside_tagged_fns() {
    let src = r#"
// lintra: bitwise-critical
fn dotp(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x.mul_add(*y, 0.0)).sum()
}

fn untagged(x: f32) -> f32 {
    x.mul_add(2.0, 1.0)
}
"#;
    let findings = analyze_source(KERNEL, src);
    assert_eq!(findings.len(), 1, "{}", show(&findings));
    assert_eq!((findings[0].rule, findings[0].line), (Rule::Bitwise, 4));
}

#[test]
fn bitwise_rule_flags_multiple_scalar_accumulators() {
    let src = r#"
// lintra: bitwise-critical
fn split_sum(a: &[f32]) -> f32 {
    let mut acc_lo = 0.0f32;
    let mut acc_hi = 0.0f32;
    for (i, &x) in a.iter().enumerate() {
        if i % 2 == 0 {
            acc_lo += x;
        } else {
            acc_hi += x;
        }
    }
    acc_lo + acc_hi
}
"#;
    let findings = analyze_source(KERNEL, src);
    assert_eq!(findings.len(), 1, "{}", show(&findings));
    assert_eq!(findings[0].rule, Rule::Bitwise);
    assert_eq!(findings[0].line, 5, "reported at the second accumulator");
}

#[test]
fn bitwise_rule_accepts_one_scalar_and_array_accumulators() {
    let src = r#"
// lintra: bitwise-critical
fn tiled(a: &[f32]) -> f32 {
    let mut acc = [0.0f32; 4];
    let mut sum = 0.0f32;
    for &x in a {
        sum += x;
    }
    sum + acc[0]
}
"#;
    assert!(rules_of(KERNEL, src).is_empty());
}

#[test]
fn bitwise_rule_flags_unordered_containers() {
    let src = r#"
// lintra: bitwise-critical
fn reduce(a: &[f32]) -> f32 {
    let mut seen = std::collections::HashMap::new();
    seen.insert(0u32, a.len());
    a.iter().sum()
}
"#;
    assert_eq!(rules_of(KERNEL, src), vec![Rule::Bitwise]);
}

#[test]
fn bitwise_allow_pragma_suppresses_with_reason() {
    let src = r#"
// lintra: bitwise-critical
fn dotp(a: &[f32]) -> f32 {
    // lintra: allow(bitwise) -- the reference kernel uses the fused form too
    a.iter().map(|x| x.mul_add(2.0, 0.0)).sum()
}
"#;
    assert!(rules_of(KERNEL, src).is_empty());
}

// ---------------------------------------------------------------------------
// rules `env`, `safety`, `lock`
// ---------------------------------------------------------------------------

#[test]
fn env_rule_is_scoped_to_the_resolver_files() {
    let src = r#"
fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok()
}
"#;
    assert_eq!(rules_of("rust/src/benchkit.rs", src), vec![Rule::Env]);
    assert!(rules_of("rust/src/config.rs", src).is_empty());
    assert!(rules_of("rust/src/parallel.rs", src).is_empty());
}

#[test]
fn safety_rule_requires_an_adjacent_justification() {
    let bare = r#"
fn f(p: *const u32) -> u32 {
    unsafe { *p }
}
"#;
    assert_eq!(rules_of(KERNEL, bare), vec![Rule::Safety]);

    let justified = r#"
fn f(p: *const u32) -> u32 {
    // SAFETY: the caller guarantees p points at a live u32
    unsafe { *p }
}
"#;
    assert!(rules_of(KERNEL, justified).is_empty());

    // a blank line between the comment and the unsafe breaks contiguity
    let detached = "// SAFETY: stale\n\nfn f(p: *const u32) -> u32 { unsafe { *p } }\n";
    assert_eq!(rules_of(KERNEL, detached), vec![Rule::Safety]);
}

#[test]
fn lock_rule_points_at_the_wrapper_and_survives_spacing() {
    let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n";
    assert_eq!(rules_of("rust/src/nn.rs", src), vec![Rule::Lock]);
    let spaced = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock() . unwrap () }\n";
    assert_eq!(rules_of("rust/src/nn.rs", spaced), vec![Rule::Lock]);
    // parallel.rs hosts the approved wrapper, so `lock` does not apply —
    // but it is a hot-path file, so the raw .unwrap() still trips `panic`
    assert_eq!(rules_of("rust/src/parallel.rs", src), vec![Rule::Panic]);
}

// ---------------------------------------------------------------------------
// lexer: lifetime ticks vs char literals (regression)
// ---------------------------------------------------------------------------

#[test]
fn lifetime_ticks_do_not_swallow_violations_end_to_end() {
    // before the lexer fix, `'a` opened a bogus char literal and the
    // rest of the line — including the violation — was blanked out
    let src = "\
fn f<'a>(x: &'a Option<u32>) -> u32 { x.unwrap() }
fn g(s: &'static str, x: Option<u32>) -> u32 { let _ = s; x.unwrap() }
";
    let findings = analyze_source(HOT, src);
    assert_eq!(findings.len(), 2, "{}", show(&findings));
    assert!(findings.iter().all(|f| f.rule == Rule::Panic));
}

#[test]
fn real_char_literals_still_blank_their_contents() {
    // a char literal containing `!` must not trip macro detection, and
    // an escaped quote must not leak the literal into the code view
    let src = "\
fn f() -> char { '!' }
fn g() -> char { '\\'' }
fn h(x: Option<u32>) -> u32 { let c = 'q'; let _ = c; x.unwrap() }
";
    let findings = analyze_source(HOT, src);
    assert_eq!(findings.len(), 1, "{}", show(&findings));
    assert_eq!(findings[0].line, 3, "only the real .unwrap() fires");
}

// ---------------------------------------------------------------------------
// interprocedural: tick closure, alloc rule, hot-closure superset
// ---------------------------------------------------------------------------

fn files(v: &[(&str, &str)]) -> Vec<(String, String)> {
    v.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect()
}

#[test]
fn tick_closure_carries_panic_and_alloc_into_other_files() {
    // run_engine (tick root, serving file) calls into a kernel file;
    // the helper's unwrap and allocation are findings even though
    // tensor.rs is outside the serving file list
    let a = analyze_sources(&files(&[
        (
            HOT,
            "pub fn run_engine() {\n    crate::tensor::tick_helper();\n}\n",
        ),
        (
            KERNEL,
            "\
pub fn tick_helper() {
    let v: Vec<u32> = Vec::new();
    let _ = v.first().copied().unwrap();
}
pub fn cold_helper() {
    let v = vec![0u32; 4];
    let _ = v[0];
}
",
        ),
    ]));
    assert!(
        a.scope.tick_contains(KERNEL, "tick_helper"),
        "tick closure: {:?}",
        a.scope.tick_fns
    );
    assert!(
        !a.scope.tick_contains(KERNEL, "cold_helper"),
        "cold_helper is unreachable from run_engine"
    );
    let in_kernel: Vec<&Finding> =
        a.findings.iter().filter(|f| f.path == KERNEL).collect();
    assert!(
        in_kernel.iter().any(|f| f.rule == Rule::Panic),
        "tick-reachable unwrap must surface: {}",
        show(&a.findings)
    );
    assert!(
        in_kernel.iter().any(|f| f.rule == Rule::Alloc),
        "tick-reachable allocation must surface: {}",
        show(&a.findings)
    );
    assert!(
        !in_kernel.iter().any(|f| f.message.contains("cold_helper")),
        "nothing fires in the unreachable helper: {}",
        show(&a.findings)
    );
}

#[test]
fn method_calls_resolve_across_modules_via_receivers() {
    // run_engine ticks a backend method; the impl lives in another file
    // and its body allocates — the finding lands there
    let a = analyze_sources(&files(&[
        (
            HOT,
            "\
pub fn run_engine(b: &mut crate::nn::Sess) {
    b.step_once();
}
",
        ),
        (
            "rust/src/nn/mod.rs",
            "\
pub struct Sess;
impl Sess {
    pub fn step_once(&mut self) {
        let _ = vec![0.0f32; 8];
    }
}
",
        ),
    ]));
    assert!(
        a.scope.tick_contains("rust/src/nn/mod.rs", "step_once"),
        "tick closure: {:?}",
        a.scope.tick_fns
    );
    assert!(
        a.findings
            .iter()
            .any(|f| f.path == "rust/src/nn/mod.rs" && f.rule == Rule::Alloc),
        "{}",
        show(&a.findings)
    );
}

#[test]
fn unresolved_calls_are_reported_conservatively() {
    let a = analyze_sources(&files(&[(
        HOT,
        "pub fn run_engine() {\n    std::mem::forget(Vec::<u32>::with_capacity(4));\n}\n",
    )]));
    assert!(
        a.scope.unresolved_calls >= 1,
        "external calls must be tallied, got {}",
        a.scope.unresolved_calls
    );
}

/// The superset criterion: the computed hot closure covers every fn the
/// PR 7 hand-maintained six-file list covered (by construction — all
/// non-test fns in those files are roots) *plus* what they reach.
#[test]
fn computed_hot_closure_covers_the_old_hand_listed_files() {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let repo = manifest.parent().expect("rust/ sits inside the repo root");
    let a = analyze_paths(&[manifest.join("src"), repo.join("examples")]).unwrap();
    for file in linear_transformer::analysis::SERVING_FILES {
        assert!(
            a.scope.hot_fns.iter().any(|(f, _)| {
                f.ends_with(file) || file.ends_with(f.as_str())
            }),
            "hot closure must cover {file}: every fn there is a root"
        );
    }
    // and it reaches beyond the old list: tick-called fns in kernel files
    for (file, name) in [
        ("rust/src/coordinator/engine.rs", "run_engine"),
        ("rust/src/nn/mod.rs", "step_batch_into"),
        ("rust/src/nn/mod.rs", "prefill_row_partial_into"),
        ("rust/src/attention/linear.rs", "step_batch_pooled"),
        ("rust/src/tensor.rs", "matmul_into_pooled"),
        ("rust/src/tensor.rs", "matmul_into_w_pooled"),
        ("rust/src/sampling.rs", "sample_logits_topk"),
    ] {
        assert!(
            a.scope.tick_contains(file, name),
            "{file}::{name} must be tick-reachable; tick closure has {} fns",
            a.scope.tick_fns.len()
        );
    }
}

// ---------------------------------------------------------------------------
// the CI gate: the repo's own tree analyzes clean modulo the baseline
// ---------------------------------------------------------------------------

#[test]
fn repo_tree_is_analyze_clean_modulo_baseline() {
    let manifest = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let repo = manifest.parent().expect("rust/ sits inside the repo root");
    let a = analyze_paths(&[manifest.join("src"), repo.join("examples")]).unwrap();
    let text = std::fs::read_to_string(repo.join("analysis_baseline.json"))
        .expect("analysis_baseline.json is committed at the repo root");
    let baseline = Baseline::parse(&text).expect("committed baseline parses");
    let diff = baseline.diff(&a.findings);
    assert!(
        diff.fresh.is_empty(),
        "`lintra analyze --deny --baseline analysis_baseline.json rust/src examples` \
         must stay green; fresh findings:\n{}",
        show(&diff.fresh)
    );
    // the ratchet works both ways: entries whose findings vanished
    // should be removed from the baseline (regenerate with
    // `lintra analyze --baseline analysis_baseline.json --write-baseline`)
    assert!(
        diff.resolved.is_empty(),
        "baseline entries are stale — ratchet them out:\n{}",
        diff.resolved.join("\n")
    );
}
