//! Cross-backend differential harness: softmax KV-cache vs quadratic
//! recompute vs linear attention.
//!
//! Three tiers of agreement, each with a precise claim:
//!
//! 1. **Bitwise** — the softmax KV-cache *is* quadratic attention with
//!    memoized K/V rows: per-lane float-op order is identical to the
//!    causal `softmax::forward` last row, and to feeding the prompt one
//!    tick at a time. Asserted with `to_bits`, no tolerance.
//! 2. **Numeric** — the batched softmax session vs `TransformerLM::forward`
//!    differ only in float-op association (per-row vs fused residual
//!    adds), so a tight `assert_close_ulp` envelope holds.
//! 3. **Behavioral** — linear attention (eq. 4-5, `elu+1` kernel) and
//!    softmax attention (eq. 2) are *different functions*; with identical
//!    weights their logits agree only in gross shape. We therefore assert
//!    (a) a documented gross-divergence envelope (no confident logit ever
//!    flips sign catastrophically) and (b) greedy-argmax agreement only on
//!    *decisive-margin* steps, where the softmax top-2 margin exceeds
//!    twice the measured cross-backend divergence — there, disagreement is
//!    mathematically impossible, so any failure pinpoints a real bug in
//!    one of the two decode stacks rather than formulation drift.
//!
//! Randomized cases go through `propcheck`, so failures print the seed
//! for replay.

use linear_transformer::attention::{softmax, AttentionKind};
use linear_transformer::config::ModelConfig;
use linear_transformer::nn::TransformerLM;
use linear_transformer::propcheck;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 11,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        max_len: 48,
        ..ModelConfig::small_copy()
    }
}

/// Tier 1, attention core: stepping the KV cache one token at a time is
/// bitwise equal to a full O(t²) causal recompute of the same prefix, at
/// every position, over random shapes and inputs.
#[test]
fn kv_step_is_bitwise_equal_to_quadratic_recompute_at_every_position() {
    propcheck::check("kv_step_vs_quadratic", propcheck::default_cases(), |g| {
        let n = g.usize_in(1, 24);
        let dims = [4usize, 8, 16];
        let d = dims[g.usize_in(0, 2)];
        let m = dims[g.usize_in(0, 2)];
        let q = g.vec_f32(n * d, 0.8);
        let k = g.vec_f32(n * d, 0.8);
        let v = g.vec_f32(n * m, 1.0);

        let mut cache = softmax::BatchedKvCache::new(1, d, m, n);
        cache.push_row().expect("fresh cache has capacity");
        let mut step_out = vec![0.0f32; m];
        for t in 0..n {
            cache.step_batch(
                &q[t * d..(t + 1) * d],
                &k[t * d..(t + 1) * d],
                &v[t * m..(t + 1) * m],
                &mut step_out,
            );
            // full quadratic recompute of the prefix [..t], causal
            let mut full = vec![0.0f32; (t + 1) * m];
            softmax::forward(
                &q[..(t + 1) * d],
                &k[..(t + 1) * d],
                &v[..(t + 1) * m],
                t + 1,
                d,
                m,
                true,
                &mut full,
            );
            for j in 0..m {
                let (got, want) = (step_out[j], full[t * m + j]);
                if got.to_bits() != want.to_bits() {
                    return Err(format!(
                        "n={n} d={d} m={m} pos={t} col={j}: step {got:e} != recompute {want:e}"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Tier 1, full session: chunked prefill (one-shot and arbitrary interior
/// slicings) is bitwise equal to feeding the prompt one tick at a time —
/// the same contract the linear backend's prefill already guarantees.
#[test]
fn softmax_prefill_is_bitwise_equal_to_per_tick_feeding() {
    let cfg = tiny_cfg();
    let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 7);
    propcheck::check("softmax_prefill_vs_ticks", 12, |g| {
        let n = g.usize_in(2, cfg.max_len - 2);
        let prompt: Vec<u32> = (0..n).map(|_| g.usize_in(0, cfg.vocab - 1) as u32).collect();

        let mut ticked = model.batched_softmax_session(1);
        ticked.alloc_row().expect("capacity 1");
        let mut tick_logits = Vec::new();
        for &t in &prompt {
            tick_logits = ticked.step_batch(&[t]);
        }

        let mut oneshot = model.batched_softmax_session(1);
        oneshot.alloc_row().expect("capacity 1");
        let pre_logits = oneshot.prefill_row(0, &prompt);
        if tick_logits.len() != pre_logits.len() {
            return Err("logit length mismatch".into());
        }

        // random interior slicing through the resumable entry point
        let mut sliced = model.batched_softmax_session(1);
        sliced.alloc_row().expect("capacity 1");
        let mut off = 0;
        let mut sliced_logits = None;
        while off < n {
            let c = g.usize_in(1, n - off);
            let finish = off + c == n;
            sliced_logits = sliced.prefill_row_partial(0, &prompt[off..off + c], finish);
            off += c;
        }
        let sliced_logits = sliced_logits.ok_or("finishing slice must yield logits")?;

        for j in 0..tick_logits.len() {
            if tick_logits[j].to_bits() != pre_logits[j].to_bits() {
                return Err(format!(
                    "n={n} logit {j}: per-tick {:e} != one-shot prefill {:e}",
                    tick_logits[j], pre_logits[j]
                ));
            }
            if tick_logits[j].to_bits() != sliced_logits[j].to_bits() {
                return Err(format!(
                    "n={n} logit {j}: per-tick {:e} != sliced prefill {:e}",
                    tick_logits[j], sliced_logits[j]
                ));
            }
        }
        Ok(())
    });
}

/// Tier 2: the batched KV session vs the reference `forward` pass. These
/// associate the residual adds differently, so the claim is numeric, not
/// bitwise: every logit within a tight ULP/rel/abs envelope.
#[test]
fn softmax_session_matches_forward_within_tight_envelope() {
    let cfg = tiny_cfg();
    let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 3);
    let prompt: Vec<u32> = (0..30u32).map(|i| (i * 7 + 2) % cfg.vocab as u32).collect();

    let mut sess = model.batched_softmax_session(1);
    sess.alloc_row().expect("capacity 1");
    let mut logits = Vec::new();
    for &t in &prompt {
        logits = sess.step_batch(&[t]);
    }

    let full = model.forward(&prompt);
    let want = full.row(prompt.len() - 1);
    assert_eq!(logits.len(), want.len());
    for j in 0..want.len() {
        propcheck::assert_close_ulp(
            logits[j],
            want[j],
            256,
            1e-3,
            2e-3,
            &format!("logit {j} after {} tokens", prompt.len()),
        );
    }
}

/// Tier 3: linear vs softmax attention with identical weights (same init
/// seed; `TransformerLM::init` draws weights independently of the
/// attention kind). The formulations are NOT numerically equal — eq. 4-5
/// replaces `exp(q·k/√d)` with the `elu(q)+1 · elu(k)+1` kernel — so this
/// test asserts only what genuinely must hold:
///
/// - every logit is finite on both paths;
/// - a gross-divergence envelope: `assert_close_ulp` with rel_tol 1.5 /
///   abs_tol 2.5, which can only trip when a confidently-large logit
///   (|x| ≳ 1.4) flips to a confidently-large opposite sign — formulation
///   drift at random-init scale stays far inside it;
/// - greedy argmax agreement on decisive steps: wherever the softmax
///   top-2 margin exceeds 2·max_j|lin_j − soft_j| for that step, both
///   backends must pick the same token. At position 0 both formulations
///   reduce to (nearly) returning the value row verbatim, so decisive
///   steps provably exist — asserted as a non-vacuity check.
#[test]
fn linear_and_softmax_agree_on_decisive_greedy_steps() {
    let cfg = tiny_cfg();
    let lin = TransformerLM::init(&cfg, AttentionKind::Linear, 11);
    let soft = TransformerLM::init(&cfg, AttentionKind::Softmax, 11);

    let decisive_total = std::cell::Cell::new(0usize);
    propcheck::check("linear_vs_softmax_decisive_argmax", 16, |g| {
        let n = g.usize_in(2, 8);
        let prompt: Vec<u32> = (0..n).map(|_| g.usize_in(0, cfg.vocab - 1) as u32).collect();
        let lin_out = lin.forward(&prompt);
        let soft_out = soft.forward(&prompt);

        let mut decisive_here = 0usize;
        for t in 0..n {
            let (lr, sr) = (lin_out.row(t), soft_out.row(t));
            let mut diff_inf = 0.0f32;
            for j in 0..cfg.vocab {
                if !lr[j].is_finite() || !sr[j].is_finite() {
                    return Err(format!("non-finite logit at pos {t} col {j}"));
                }
                diff_inf = diff_inf.max((lr[j] - sr[j]).abs());
                // gross-divergence envelope (documented above); loose by
                // construction — it bounds catastrophe, not equality
                propcheck::assert_close_ulp(
                    lr[j],
                    sr[j],
                    64,
                    1.5,
                    2.5,
                    &format!("linear vs softmax logit, pos {t} col {j}"),
                );
            }
            let (s_arg, s_margin) = top2_margin(sr);
            let (l_arg, _) = top2_margin(lr);
            if s_margin > 2.0 * diff_inf {
                decisive_here += 1;
                if l_arg != s_arg {
                    return Err(format!(
                        "pos {t}: decisive step (margin {s_margin:e} > 2*{diff_inf:e}) \
                         but argmax differs: linear {l_arg}, softmax {s_arg}"
                    ));
                }
            }
        }
        decisive_total.set(decisive_total.get() + decisive_here);
        Ok(())
    });
    assert!(
        decisive_total.get() > 0,
        "no decisive-margin steps across the whole sweep; the agreement check never ran"
    );
}

/// Argmax and the top-1/top-2 margin of a logit row (first index wins ties,
/// matching greedy sampling).
fn top2_margin(row: &[f32]) -> (usize, f32) {
    let mut best = 0usize;
    for j in 1..row.len() {
        if row[j] > row[best] {
            best = j;
        }
    }
    let mut second = f32::NEG_INFINITY;
    for (j, &x) in row.iter().enumerate() {
        if j != best && x > second {
            second = x;
        }
    }
    (best, row[best] - second)
}

/// Satellite 3 at the integration level: export a softmax lane mid-stream,
/// import it into a *fresh* session, and the continuation is bitwise equal
/// to the uninterrupted run. Snapshot size must scale with the cut point
/// (the honest O(N) cost the capability matrix documents).
#[test]
fn softmax_snapshot_roundtrip_resumes_bitwise() {
    let cfg = tiny_cfg();
    let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 5);
    let tokens: Vec<u32> = (0..28u32).map(|i| (i * 5 + 1) % cfg.vocab as u32).collect();
    let cut = 10usize;

    let mut base = model.batched_softmax_session(1);
    base.alloc_row().expect("capacity 1");
    let mut base_logits = Vec::new();
    let mut snap_early = None;
    let mut snap_cut = None;
    for (i, &t) in tokens.iter().enumerate() {
        base_logits = base.step_batch(&[t]);
        if i + 1 == cut / 2 {
            snap_early = Some(base.export_lane(0));
        }
        if i + 1 == cut {
            snap_cut = Some(base.export_lane(0));
        }
    }
    let snap_early = snap_early.unwrap();
    let snap = snap_cut.unwrap();
    assert_eq!(snap.pos, cut);
    // O(N) payload: bytes scale linearly with the cut position
    assert_eq!(snap.bytes() / snap.pos, snap_early.bytes() / snap_early.pos);
    assert!(snap.bytes() > snap_early.bytes());

    let mut resumed = model.batched_softmax_session(1);
    resumed.alloc_row().expect("capacity 1");
    resumed.import_lane(0, &snap);
    assert_eq!(resumed.pos(0), cut);
    let mut resumed_logits = Vec::new();
    for &t in &tokens[cut..] {
        resumed_logits = resumed.step_batch(&[t]);
    }
    assert_eq!(base_logits.len(), resumed_logits.len());
    for j in 0..base_logits.len() {
        assert_eq!(
            base_logits[j].to_bits(),
            resumed_logits[j].to_bits(),
            "logit {j}: resumed-from-snapshot stream diverged from the uninterrupted run"
        );
    }
}
