//! Low-precision weight-storage parity (the weight-dtype contract).
//!
//! The contract, in order of strictness (ARCHITECTURE.md, "Weight
//! storage & numeric contract"):
//!
//! * f32 is the bitwise reference: the pooled column-split B=1 GEMV must
//!   reproduce the serial kernel bit-for-bit at any thread count, and the
//!   packed (f16/bf16/int8) kernels must be bitwise self-consistent
//!   across batch size, prompt chunking, and pooling — every output
//!   element is one accumulator walking k in ascending order.
//! * f16/bf16/int8 decode logits track the f32 reference within a
//!   documented per-dtype `(rel_tol, abs_tol)` through multi-step decode.
//! * Greedy streams match f32 wherever the f32 argmax margin exceeds the
//!   documented logit tolerance (a margin inside the tolerance band is
//!   legitimately undecidable at low precision).
//! * An offline `lintra cast` bundle is *exactly* the in-memory cast:
//!   quantize(dequantize(x)) == quantize(x), so serving a cast bundle
//!   reproduces serving the f32 bundle with `--weight-dtype` set.

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::{ModelConfig, ServeConfig};
use linear_transformer::coordinator::engine::NativeEngine;
use linear_transformer::coordinator::request::GenerateRequest;
use linear_transformer::nn::{quantized_param, random_param_tensors, TransformerLM};
use linear_transformer::propcheck::assert_close_ulp;
use linear_transformer::rng::Rng;
use linear_transformer::tensor::WeightDtype;
use linear_transformer::weights::WeightBundle;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 17,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        max_len: 96,
        d_ff: 64,
        chunk: 16,
        causal: true,
        lsh_rounds: 1,
        lsh_buckets: 8,
        lsh_chunk: 8,
    }
}

/// Wide enough that the pooled kernels' fan-out gates actually engage:
/// a B=1 [128]x[128,128] GEMV is 16384 mul-adds with 128 output columns,
/// exactly at PAR_MIN_WORK and past PAR_MIN_GEMV_COLS.
fn wide_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 32,
        d_model: 128,
        n_heads: 4,
        n_layers: 2,
        max_len: 192,
        d_ff: 256,
        chunk: 16,
        causal: true,
        lsh_rounds: 1,
        lsh_buckets: 8,
        lsh_chunk: 8,
    }
}

fn stream(len: usize, vocab: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(vocab as u64) as u32).collect()
}

/// Deterministic model for a seed with an *explicit* storage dtype, so
/// the tests mean the same thing whether or not the ambient
/// LINTRA_WEIGHT_DTYPE (the CI f16 leg) is set.
fn model_at(cfg: &ModelConfig, seed: u64, dtype: WeightDtype) -> TransformerLM {
    let mut m = TransformerLM::init(cfg, AttentionKind::Linear, seed);
    m.cast_weights(dtype);
    m
}

/// The documented per-dtype decode-logit tolerances vs the f32 reference
/// (rel_tol, abs_tol). These are the numbers ARCHITECTURE.md states.
fn tolerance(dtype: WeightDtype) -> (f32, f32) {
    match dtype {
        WeightDtype::F32 => (0.0, 0.0),
        WeightDtype::F16 => (5e-2, 5e-2),
        WeightDtype::Bf16 => (1e-1, 1e-1),
        WeightDtype::Int8 => (2e-1, 2e-1),
    }
}

#[test]
fn pooled_column_split_b1_gemv_is_bitwise_serial() {
    // B=1 decode ticks on a 4-thread pool vs no pool: the column-split
    // GEMV partitions output columns (never a reduction), so the bits
    // must match at any thread count — for the f32 kernel and for every
    // packed dtype's widening kernel alike
    let cfg = wide_cfg();
    let prompt = stream(100, cfg.vocab, 6100); // crosses a PREFILL_CHUNK
    for dtype in [
        WeightDtype::F32,
        WeightDtype::F16,
        WeightDtype::Bf16,
        WeightDtype::Int8,
    ] {
        let model = model_at(&cfg, 7, dtype);
        let pool = std::sync::Arc::new(linear_transformer::parallel::ThreadPool::new(4));
        let mut serial = model.batched_session_with_pool(1, None);
        let mut pooled = model.batched_session_with_pool(1, Some(pool));
        serial.alloc_row().unwrap();
        pooled.alloc_row().unwrap();
        let a = serial.prefill_row(0, &prompt);
        let b = pooled.prefill_row(0, &prompt);
        assert_eq!(a, b, "{}: pooled prefill logits differ", dtype.name());
        for t in 0..12 {
            let tok = ((t * 5) % cfg.vocab) as u32;
            let la = serial.step_batch(&[tok]);
            let lb = pooled.step_batch(&[tok]);
            assert_eq!(
                la,
                lb,
                "{}: pooled B=1 decode tick {t} not bitwise serial",
                dtype.name()
            );
        }
    }
}

#[test]
fn low_precision_decode_logits_stay_within_contract() {
    // a 30-token prompt walk plus decode ticks through the RNN state:
    // quantization error accumulates through (S, Z) and must still land
    // inside the documented per-dtype band at every step
    let cfg = tiny_cfg();
    let reference = model_at(&cfg, 42, WeightDtype::F32);
    let tokens = stream(30, cfg.vocab, 8800);
    for dtype in [WeightDtype::F16, WeightDtype::Bf16, WeightDtype::Int8] {
        let (rel, abs) = tolerance(dtype);
        let quant = model_at(&cfg, 42, dtype);
        let mut ref_sess = reference.session();
        let mut q_sess = quant.session();
        for (step, &t) in tokens.iter().enumerate() {
            let want = ref_sess.step(t);
            let got = q_sess.step(t);
            for (v, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_close_ulp(
                    *g,
                    *w,
                    16,
                    rel,
                    abs,
                    &format!("{} step {step} logit {v}", dtype.name()),
                );
            }
        }
    }
}

#[test]
fn greedy_stream_under_f16_tracks_f32_wherever_the_margin_is_decisive() {
    // both sessions are fed the f32 greedy stream; at every step where
    // the f32 top-2 margin clears twice the documented f16 logit
    // tolerance, the f16 argmax must agree — and enough steps must be
    // decisive for the test to mean anything
    let cfg = tiny_cfg();
    let f32_model = model_at(&cfg, 42, WeightDtype::F32);
    let f16_model = model_at(&cfg, 42, WeightDtype::F16);
    let (_, abs) = tolerance(WeightDtype::F16);
    let margin_floor = 2.0 * abs;
    let prompt = stream(8, cfg.vocab, 4242);
    let mut fs = f32_model.session();
    let mut qs = f16_model.session();
    let mut logits_f32 = Vec::new();
    let mut logits_f16 = Vec::new();
    for &t in &prompt {
        logits_f32 = fs.step(t);
        logits_f16 = qs.step(t);
    }
    let mut decisive = 0usize;
    for _ in 0..24 {
        let top = linear_transformer::sampling::argmax(&logits_f32);
        let best = logits_f32[top as usize];
        let runner_up = logits_f32
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != top as usize)
            .map(|(_, &v)| v)
            .fold(f32::NEG_INFINITY, f32::max);
        if best - runner_up > margin_floor {
            decisive += 1;
            assert_eq!(
                linear_transformer::sampling::argmax(&logits_f16),
                top,
                "f16 greedy flipped on a decisive step (margin {})",
                best - runner_up
            );
        }
        logits_f32 = fs.step(top);
        logits_f16 = qs.step(top);
    }
    assert!(
        decisive >= 8,
        "only {decisive}/24 steps were decisive — geometry too flat to test"
    );
}

#[test]
fn engine_under_weight_dtype_matches_direct_cast_generation() {
    // serving with ServeConfig.weight_dtype = f16 (pooled, batched,
    // chunked prefill) must reproduce direct generation on an explicitly
    // cast model token-for-token: the packed kernels give every output
    // element one accumulator in k order, so batching and chunking don't
    // move the bits
    let cfg = wide_cfg();
    let direct_model = model_at(&cfg, 99, WeightDtype::F16);
    let cases: Vec<(Vec<u32>, usize)> = vec![
        (stream(100, cfg.vocab, 5100), 6), // crosses a PREFILL_CHUNK
        (stream(2, cfg.vocab, 5101), 12),
        (stream(70, cfg.vocab, 5102), 4),
        (stream(33, cfg.vocab, 5103), 8),
    ];
    let direct: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| direct_model.generate(p, *n, 0.0, 0))
        .collect();
    // the engine casts for itself at spawn from the same seed weights
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 99);
    let mut handle = NativeEngine::spawn(
        model,
        ServeConfig {
            max_batch: 2,
            max_wait_us: 500,
            num_threads: 4,
            weight_dtype: Some(WeightDtype::F16),
            ..Default::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, (p, n))| {
            handle.submit(GenerateRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new: *n,
                temperature: 0.0,
                top_k: 0,
            })
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(
            resp.tokens, direct[resp.id as usize],
            "request {}: f16 serving diverged from direct f16 generation",
            resp.id
        );
    }
    handle.shutdown();
}

#[test]
fn cast_bundle_roundtrip_is_exactly_the_in_memory_cast() {
    // what `lintra cast` does: save_as with the quantized_param chooser,
    // reload, serve. quantize(dequantize(x)) == quantize(x), so the
    // round-tripped model must produce bitwise-identical logits and
    // greedy streams to casting the original weights in memory
    let cfg = tiny_cfg();
    let mut rng = Rng::new(314);
    let bundle = WeightBundle::new(random_param_tensors(&cfg, &mut rng));
    let dir = std::env::temp_dir().join(format!("ltw_cast_rt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let f32_path = dir.join("model.ltw");
    let f16_path = dir.join("model.f16.ltw");
    bundle.save(&f32_path).unwrap();
    bundle
        .save_as(&f16_path, |t| {
            if quantized_param(&t.name) {
                WeightDtype::F16
            } else {
                WeightDtype::F32
            }
        })
        .unwrap();
    let f32_bytes = std::fs::metadata(&f32_path).unwrap().len();
    let f16_bytes = std::fs::metadata(&f16_path).unwrap().len();
    assert!(
        f16_bytes < f32_bytes,
        "cast bundle must shrink ({f16_bytes} vs {f32_bytes} bytes)"
    );

    let reloaded = WeightBundle::load(&f16_path).unwrap();
    let mut from_cast = TransformerLM::from_bundle(&cfg, AttentionKind::Linear, &reloaded).unwrap();
    let mut in_memory = TransformerLM::from_bundle(&cfg, AttentionKind::Linear, &bundle).unwrap();
    // normalize both to an explicit f16 cast (idempotent for the
    // round-tripped weights) so the ambient LINTRA_WEIGHT_DTYPE of the
    // CI f16 leg can't skew one side
    from_cast.cast_weights(WeightDtype::F16);
    in_memory.cast_weights(WeightDtype::F16);

    let tokens = stream(12, cfg.vocab, 2718);
    let a = from_cast.forward(&tokens);
    let b = in_memory.forward(&tokens);
    assert_eq!(a.data, b.data, "cast-bundle forward logits not bitwise");
    let prompt = stream(6, cfg.vocab, 2719);
    assert_eq!(
        from_cast.generate(&prompt, 10, 0.0, 0),
        in_memory.generate(&prompt, 10, 0.0, 0),
        "cast-bundle greedy stream not identical"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
