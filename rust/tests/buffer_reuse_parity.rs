//! Scratch-buffer reuse must be invisible in the bits.
//!
//! PR motivation: the `alloc` analysis rule pushed the per-tick logits
//! allocations out of the serving hot path — `step_batch` /
//! `prefill_row_partial` gained `_into` forms that fill a caller-owned
//! buffer the engine keeps alive across ticks. The contract is that a
//! *reused, dirty* buffer (stale values, NaN poison, wrong length) hits
//! exactly the same bits as the allocating forms, for every chunking of
//! a prompt and across batch-width changes — otherwise buffer reuse
//! would be an observable behaviour change, not an optimisation.

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::ModelConfig;
use linear_transformer::nn::TransformerLM;
use linear_transformer::rng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 17,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        max_len: 64,
        d_ff: 64,
        chunk: 16,
        causal: true,
        lsh_rounds: 1,
        lsh_buckets: 8,
        lsh_chunk: 8,
    }
}

fn stream(len: usize, vocab: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(vocab as u64) as u32).collect()
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: logit {i} differs ({x} vs {y})"
        );
    }
}

/// Fill with NaN poison so stale contents would be detected the moment
/// an `_into` path failed to overwrite every element.
fn poison(buf: &mut Vec<f32>, len: usize) {
    buf.clear();
    buf.resize(len, f32::NAN);
}

#[test]
fn step_batch_into_reused_dirty_buffer_is_bitwise_identical() {
    let cfg = tiny_cfg();
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 7);
    let vocab = cfg.vocab;

    let mut fresh = model.batched_session(3);
    let mut reused = model.batched_session(3);
    for _ in 0..3 {
        fresh.alloc_row().expect("capacity 3");
        reused.alloc_row().expect("capacity 3");
    }

    let streams: Vec<Vec<u32>> = (0..3).map(|i| stream(20, vocab, 50 + i)).collect();
    // one buffer for the whole run, never cleared between ticks, and
    // poisoned oversized before the first — reuse must overwrite it all
    let mut buf: Vec<f32> = Vec::new();
    poison(&mut buf, 5 * vocab);
    for t in 0..20 {
        let tokens: Vec<u32> = streams.iter().map(|s| s[t]).collect();
        let expect = fresh.step_batch(&tokens);
        reused.step_batch_into(&tokens, &mut buf);
        assert_bitwise(&buf, &expect, "decode tick");
    }
}

#[test]
fn step_batch_into_survives_batch_width_changes() {
    let cfg = tiny_cfg();
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 9);
    let vocab = cfg.vocab;

    let mut fresh = model.batched_session(3);
    let mut reused = model.batched_session(3);
    for _ in 0..3 {
        fresh.alloc_row().expect("capacity 3");
        reused.alloc_row().expect("capacity 3");
    }
    let s = stream(40, vocab, 77);
    let mut buf: Vec<f32> = Vec::new();

    // wide tick (3 lanes), then shrink to 1 lane: the reused buffer must
    // shrink to exactly [1 * vocab] — stale rows must not survive
    let expect = fresh.step_batch(&[s[0], s[1], s[2]]);
    reused.step_batch_into(&[s[0], s[1], s[2]], &mut buf);
    assert_bitwise(&buf, &expect, "wide tick");

    fresh.free_row(1);
    reused.free_row(1);
    fresh.free_row(1);
    reused.free_row(1);
    let expect = fresh.step_batch(&[s[3]]);
    reused.step_batch_into(&[s[3]], &mut buf);
    assert_eq!(buf.len(), vocab, "buffer must shrink with the batch");
    assert_bitwise(&buf, &expect, "narrow tick");

    // and back up to 2 lanes: the buffer regrows
    fresh.alloc_row().expect("freed above");
    reused.alloc_row().expect("freed above");
    let expect = fresh.step_batch(&[s[4], s[5]]);
    reused.step_batch_into(&[s[4], s[5]], &mut buf);
    assert_bitwise(&buf, &expect, "regrown tick");
}

#[test]
fn prefill_into_matches_allocating_for_every_chunking() {
    let cfg = tiny_cfg();
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 11);
    let vocab = cfg.vocab;
    let prompt = stream(23, vocab, 123);

    let mut one_shot = model.batched_session(1);
    one_shot.alloc_row().expect("capacity 1");
    let expect = one_shot.prefill_row(0, &prompt);

    for pattern in [vec![23], vec![1, 22], vec![7, 7, 9], vec![16, 6, 1]] {
        assert_eq!(pattern.iter().sum::<usize>(), prompt.len());
        let mut sess = model.batched_session(1);
        sess.alloc_row().expect("capacity 1");
        let mut out: Vec<f32> = Vec::new();
        poison(&mut out, 3 * vocab);
        let mut off = 0;
        for (i, &n) in pattern.iter().enumerate() {
            let finish = i + 1 == pattern.len();
            let got = sess.prefill_row_partial_into(0, &prompt[off..off + n], finish, &mut out);
            assert_eq!(got, finish, "only the finishing slice yields logits");
            if !finish {
                assert!(out.is_empty(), "interior slices leave the buffer cleared");
                // re-poison so the finishing slice faces a dirty buffer
                poison(&mut out, 2 * vocab + 3);
            }
            off += n;
        }
        assert_bitwise(&out, &expect, "finishing prefill logits");

        // the lane state must also be identical: greedy continuations
        // from both sessions stay bitwise-locked for a few ticks
        let mut a = expect.clone();
        let mut buf: Vec<f32> = Vec::new();
        for _ in 0..5 {
            let ta = argmax(&a);
            let tb = argmax(&out);
            assert_eq!(ta, tb, "greedy continuation diverged");
            a = one_shot.step_batch(&[ta]);
            sess.step_batch_into(&[tb], &mut buf);
            assert_bitwise(&buf, &a, "greedy continuation tick");
            std::mem::swap(&mut out, &mut buf);
        }
        // rewind the shared reference session for the next pattern
        one_shot.free_row(0);
        one_shot.alloc_row().expect("capacity 1");
        let again = one_shot.prefill_row(0, &prompt);
        assert_bitwise(&again, &expect, "reference session rewind");
    }
}

fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as u32
}
