//! Batched-vs-sequential decode parity under slot churn.
//!
//! The contract of the batched decode subsystem: for every slot, the
//! logits coming out of `BatchedDecodeSession::step_batch` must match the
//! per-slot `DecodeSession::step` path within 1e-4 — including ragged
//! admission (a slot joins at tick t), early finish, and swap-remove
//! compaction of the freed lane — and the serving engine built on it must
//! produce the same greedy generations as direct per-request decoding.
//!
//! The prefill path carries a stronger contract: ingesting a prompt via
//! `prefill_row` must be *bit-identical* to feeding it token-by-token —
//! same final logits, same lane state, same greedy continuation — under
//! the same ragged admission/eviction churn. The engine's incremental
//! prefill scheduler (bounded chunks per tick, interleaved with decode)
//! is a third ingestion schedule and must hit the same bits as both.
//! Snapshot/restore (`export_lane`/`import_lane`, the substrate of the
//! prefix-reuse state cache) is a fourth: a prefix ingested in one
//! session, restored in another, and finished there must also hit the
//! same bits — under churn on both sides.

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::{ModelConfig, ServeConfig};
use linear_transformer::coordinator::engine::NativeEngine;
use linear_transformer::coordinator::request::GenerateRequest;
use linear_transformer::nn::TransformerLM;
use linear_transformer::rng::Rng;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 17,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        max_len: 64,
        d_ff: 64,
        chunk: 16,
        causal: true,
        lsh_rounds: 1,
        lsh_buckets: 8,
        lsh_chunk: 8,
    }
}

fn stream(len: usize, vocab: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    (0..len).map(|_| rng.below(vocab as u64) as u32).collect()
}

#[test]
fn batched_matches_per_slot_under_ragged_churn() {
    let cfg = tiny_cfg();
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 42);
    let vocab = cfg.vocab;

    // five streams of ragged length joining at different ticks, through a
    // 4-lane batched session: forces waiting admission, early finishes,
    // and lane compaction while other slots are mid-stream
    let lens = [18usize, 6, 12, 9, 15];
    let joins = [0usize, 0, 3, 5, 8];
    let streams: Vec<Vec<u32>> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| stream(n, vocab, 1000 + i as u64))
        .collect();

    let mut batched = model.batched_session(4);
    let mut refs: Vec<_> = streams.iter().map(|_| model.session()).collect();
    // lane -> (stream id, tokens consumed)
    let mut lanes: Vec<(usize, usize)> = Vec::new();
    let mut pending: Vec<usize> = (0..streams.len()).collect();
    let mut completed = 0usize;

    for tick in 0..200 {
        // admit pending streams whose join tick has arrived, capacity permitting
        pending.retain(|&sid| {
            if joins[sid] <= tick && batched.rows() < batched.capacity() {
                let row = batched.alloc_row().expect("capacity checked");
                assert_eq!(row, lanes.len(), "lanes must stay dense");
                lanes.push((sid, 0));
                false
            } else {
                true
            }
        });
        if lanes.is_empty() {
            if pending.is_empty() {
                break;
            }
            continue;
        }

        let tokens: Vec<u32> = lanes.iter().map(|&(sid, c)| streams[sid][c]).collect();
        let logits = batched.step_batch(&tokens);
        for (lane, (sid, c)) in lanes.iter_mut().enumerate() {
            let expect = refs[*sid].step(streams[*sid][*c]);
            let row = &logits[lane * vocab..(lane + 1) * vocab];
            let max_diff = row
                .iter()
                .zip(&expect)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_diff < 1e-4,
                "stream {sid} at token {c}: batched/per-slot divergence {max_diff}"
            );
            *c += 1;
        }

        // retire finished streams in descending lane order (swap-remove)
        for lane in (0..lanes.len()).rev() {
            let (sid, c) = lanes[lane];
            if c == streams[sid].len() {
                batched.free_row(lane);
                lanes.swap_remove(lane);
                completed += 1;
            }
        }
    }
    assert_eq!(completed, streams.len(), "every stream must run to completion");
}

#[test]
fn prefill_matches_stepwise_under_ragged_churn() {
    // streams join by prefill at different ticks into a compacting
    // 3-lane session; every lane's decode logits must equal (bitwise) a
    // per-slot reference session that ingested the same prompt
    // token-by-token
    let cfg = tiny_cfg();
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 77);
    let vocab = cfg.vocab;
    let prompt_lens = [9usize, 4, 14, 6, 11];
    let decode_lens = [7usize, 12, 3, 9, 5];
    let joins = [0usize, 0, 2, 4, 6];
    let prompts: Vec<Vec<u32>> = prompt_lens
        .iter()
        .enumerate()
        .map(|(i, &n)| stream(n, vocab, 3000 + i as u64))
        .collect();

    // per-slot references: prompt fed one token at a time
    let mut ref_logits: Vec<Vec<f32>> = Vec::new();
    for p in &prompts {
        let mut sess = model.session();
        let mut logits = Vec::new();
        for &t in p {
            logits = sess.step(t);
        }
        ref_logits.push(logits);
    }

    let mut batched = model.batched_session(3);
    // lane -> (stream id, last logits row, tokens decoded)
    let mut lanes: Vec<(usize, Vec<f32>, usize)> = Vec::new();
    let mut ref_sessions: Vec<Option<linear_transformer::nn::DecodeSession>> =
        prompts.iter().map(|_| None).collect();
    let mut pending: Vec<usize> = (0..prompts.len()).collect();
    let mut completed = 0usize;

    for tick in 0..100 {
        pending.retain(|&sid| {
            if joins[sid] <= tick && batched.rows() < batched.capacity() {
                let row = batched.alloc_row().expect("capacity checked");
                assert_eq!(row, lanes.len(), "lanes must stay dense");
                let logits = batched.prefill_row(row, &prompts[sid]);
                assert_eq!(
                    logits, ref_logits[sid],
                    "stream {sid}: prefill logits differ from stepwise ingestion"
                );
                // reference continues from its own stepwise prompt feed
                let mut sess = model.session();
                for &t in &prompts[sid] {
                    sess.step(t);
                }
                ref_sessions[sid] = Some(sess);
                lanes.push((sid, logits, 0));
                false
            } else {
                true
            }
        });
        if lanes.is_empty() {
            if pending.is_empty() {
                break;
            }
            continue;
        }

        // greedy-advance every lane one token
        let tokens: Vec<u32> = lanes
            .iter()
            .map(|(_, logits, _)| linear_transformer::sampling::argmax(logits))
            .collect();
        let out = batched.step_batch(&tokens);
        for (lane, (sid, logits, done)) in lanes.iter_mut().enumerate() {
            let expect = ref_sessions[*sid].as_mut().unwrap().step(tokens[lane]);
            let row = &out[lane * vocab..(lane + 1) * vocab];
            assert_eq!(row, &expect[..], "stream {sid} diverged after prefill admission");
            *logits = expect;
            *done += 1;
        }

        // retire finished streams (descending lane order: swap-remove)
        for lane in (0..lanes.len()).rev() {
            let (sid, _, done) = &lanes[lane];
            if *done == decode_lens[*sid] {
                batched.free_row(lane);
                lanes.swap_remove(lane);
                completed += 1;
            }
        }
    }
    assert_eq!(completed, prompts.len(), "every stream must run to completion");
}

#[test]
fn engine_prefill_matches_direct_generation_with_long_prompts() {
    // prompts longer than one PREFILL_CHUNK, mixed with short ones, under
    // a small max_batch (forcing queued admission while lanes decode):
    // the engine must still reproduce direct per-request greedy decoding
    let cfg = ModelConfig {
        max_len: 192,
        ..tiny_cfg()
    };
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 88);
    let cases: Vec<(Vec<u32>, usize)> = vec![
        (stream(100, cfg.vocab, 4000), 6),
        (stream(2, cfg.vocab, 4001), 10),
        (stream(70, cfg.vocab, 4002), 4),
        (stream(33, cfg.vocab, 4003), 8),
        (stream(129, cfg.vocab, 4004), 3),
    ];
    let direct: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| model.generate(p, *n, 0.0, 0))
        .collect();
    let mut handle = NativeEngine::spawn(
        model,
        ServeConfig {
            max_batch: 2,
            max_wait_us: 500,
            ..Default::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, (p, n))| {
            handle.submit(GenerateRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new: *n,
                temperature: 0.0,
                top_k: 0,
            })
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(!resp.truncated);
        assert_eq!(
            resp.tokens, direct[resp.id as usize],
            "request {} diverged from direct generation",
            resp.id
        );
    }
    handle.shutdown();
}

#[test]
fn incremental_prefill_matches_oneshot_and_per_tick_paths_under_churn() {
    // the acceptance bar for incremental prefill scheduling: prompts
    // longer than prefill_chunks_per_tick * PREFILL_CHUNK admit over
    // multiple ticks (budget 1 chunk/tick, max_batch 2 forcing churn:
    // slots retire while others are mid-prefill) and every request's
    // greedy tokens are IDENTICAL to both reference ingestion paths —
    // (a) per-tick feeding (model.generate walks the prompt one step at
    // a time) and (b) one-shot prefill_row + greedy continuation
    let cfg = ModelConfig {
        max_len: 192,
        ..tiny_cfg()
    };
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 123);
    let vocab = cfg.vocab;
    // 100- and 129-token prompts span 2-3 chunks; the 1-token max_new
    // retires inside the prefill phase itself
    let cases: Vec<(Vec<u32>, usize)> = vec![
        (stream(100, vocab, 9000), 6),
        (stream(3, vocab, 9001), 12),
        (stream(129, vocab, 9002), 4),
        (stream(65, vocab, 9003), 1),
        (stream(40, vocab, 9004), 8),
    ];

    // reference (a): per-tick feeding
    let per_tick: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| model.generate(p, *n, 0.0, 0))
        .collect();

    // reference (b): one-shot prefill + greedy continuation
    let one_shot: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| {
            let mut sess = model.batched_session(1);
            sess.alloc_row().unwrap();
            let mut logits = sess.prefill_row(0, p);
            let mut out = vec![linear_transformer::sampling::argmax(&logits)];
            while out.len() < *n {
                logits = sess.step_batch(&[*out.last().unwrap()]);
                out.push(linear_transformer::sampling::argmax(&logits));
            }
            out
        })
        .collect();
    assert_eq!(per_tick, one_shot, "the two reference ingestion paths disagree");

    // the engine: incremental prefill, 1 chunk per tick, heavy churn
    let mut handle = NativeEngine::spawn(
        model,
        ServeConfig {
            max_batch: 2,
            max_wait_us: 300,
            prefill_chunks_per_tick: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, (p, n))| {
            handle.submit(GenerateRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new: *n,
                temperature: 0.0,
                top_k: 0,
            })
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(
            resp.tokens, per_tick[resp.id as usize],
            "request {}: incremental prefill diverged from the reference paths",
            resp.id
        );
    }
    let st = handle.stats();
    assert_eq!(st.completed, cases.len() as u64);
    assert!(
        st.prefill_ticks >= 3,
        "the 129-token prompt alone needs three 1-chunk ticks to admit \
         (prefill_ticks = {})",
        st.prefill_ticks
    );
    assert_eq!(
        st.prompt_tokens_ingested,
        cases.iter().map(|(p, _)| p.len() as u64).sum::<u64>(),
        "every prompt token must be ingested through the prefill path"
    );
    handle.shutdown();
}

#[test]
fn restored_prefix_decode_is_bitwise_full_prefill_under_ragged_churn() {
    // the snapshot/restore contract under slot churn: a lane state
    // exported mid-prefill from a busy session and imported into a
    // *different* busy session (different lane index, neighbours joining
    // and retiring throughout) must finish its prompt and decode
    // bit-identically to a fresh one-shot full prefill
    let cfg = ModelConfig {
        max_len: 192,
        ..tiny_cfg()
    };
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 55);
    let vocab = cfg.vocab;
    let prefix = stream(96, vocab, 7000); // crosses a PREFILL_CHUNK boundary
    let suffix = stream(21, vocab, 7001);
    let full: Vec<u32> = prefix.iter().chain(&suffix).copied().collect();

    // reference: cold one-shot prefill of the whole prompt
    let mut cold = model.batched_session(1);
    cold.alloc_row().unwrap();
    let cold_logits = cold.prefill_row(0, &full);

    // donor: a churning 3-lane session ingests the prefix into lane 1
    let mut donor = model.batched_session(3);
    donor.alloc_row().unwrap(); // lane 0: a decoding neighbour
    for t in 0..6 {
        donor.step_batch(&[(t % vocab) as u32]);
    }
    donor.alloc_row().unwrap(); // lane 1: the prefix carrier
    donor.alloc_row().unwrap(); // lane 2: joins, then retires mid-way
    donor.prefill_row_partial(1, &prefix[..40], false);
    donor.free_row(2); // churn: swap-remove around the mid-prefill lane
    donor.step_batch(&[(7 % vocab) as u32]); // neighbour keeps decoding
    donor.prefill_row_partial(1, &prefix[40..], false);
    let snap = donor.export_lane(1);
    assert_eq!(snap.pos, prefix.len());

    // recipient: another churning session; the snapshot lands in lane 2
    let mut recipient = model.batched_session(3);
    for _ in 0..3 {
        recipient.alloc_row().unwrap();
    }
    for t in 0..4 {
        recipient.step_batch(&[(t % vocab) as u32, ((t + 1) % vocab) as u32]); // prefix step
    }
    recipient.import_lane(2, &snap);
    let warm_logits = recipient
        .prefill_row_partial(2, &suffix, true)
        .expect("finishing slice returns logits");
    assert_eq!(
        warm_logits, cold_logits,
        "restored-prefix prefill must be bit-identical to a cold full prefill"
    );

    // greedy decode stays in bitwise lockstep while neighbours churn
    let mut a = linear_transformer::sampling::argmax(&cold_logits);
    let mut b = a;
    for i in 0..6 {
        let la = cold.step_batch(&[a]);
        if i == 2 {
            // retire neighbour lane 0 mid-decode: swap-remove compaction
            // moves the last lane — the restored one — into its place
            assert_eq!(recipient.free_row(0), Some(2));
        }
        let ours = if i < 2 { 2 } else { 0 };
        let width = if i < 2 { 3 } else { 2 };
        let mut tick = vec![0u32; width];
        for (r, t) in tick.iter_mut().enumerate() {
            *t = if r == ours { b } else { ((i + r) % vocab) as u32 };
        }
        let lb = recipient.step_batch(&tick);
        assert_eq!(
            &lb[ours * vocab..(ours + 1) * vocab],
            &la[..],
            "restored lane diverged at decode step {i} under churn"
        );
        a = linear_transformer::sampling::argmax(&la);
        b = a;
    }
}

#[test]
fn pooled_session_is_bitwise_identical_to_serial_session() {
    // same model, same token streams: a session on a 4-thread pool must
    // produce the exact bits of a session with no pool, for both the
    // decode tick and chunk-crossing prefill
    let cfg = ModelConfig {
        vocab: 32,
        d_model: 128,
        n_heads: 4,
        n_layers: 2,
        max_len: 192,
        d_ff: 256,
        chunk: 16,
        causal: true,
        lsh_rounds: 1,
        lsh_buckets: 8,
        lsh_chunk: 8,
    };
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 7);
    let pool = std::sync::Arc::new(linear_transformer::parallel::ThreadPool::new(4));
    let mut serial = model.batched_session_with_pool(3, None);
    let mut pooled = model.batched_session_with_pool(3, Some(pool));
    assert_eq!(pooled.pool_threads(), 4);
    for _ in 0..3 {
        serial.alloc_row().unwrap();
        pooled.alloc_row().unwrap();
    }
    // prefill lane 1 across a PREFILL_CHUNK boundary
    let prompt = stream(100, cfg.vocab, 6000);
    let a = serial.prefill_row(1, &prompt);
    let b = pooled.prefill_row(1, &prompt);
    assert_eq!(a, b, "pooled prefill logits must be bit-identical");
    // then tick all three lanes together for a while
    for t in 0..12 {
        let mut tick = Vec::new();
        for r in 0..3usize {
            tick.push(((t * 3 + r) % cfg.vocab) as u32);
        }
        let la = serial.step_batch(&tick);
        let lb = pooled.step_batch(&tick);
        assert_eq!(la, lb, "pooled decode tick {t} must be bit-identical");
    }
}

#[test]
fn engine_with_worker_pool_matches_direct_generation_under_churn() {
    // num_threads = 4, on a geometry wide enough that the decode tick and
    // the prefill chunk pass actually cross the pooled kernels' fan-out
    // threshold (d_model 128: a [3, 128] x [128, 128] projection GEMM is
    // ~49k mul-adds). Ragged prompt/decode lengths against max_batch = 3
    // force queued admission, early finishes, and lane compaction while
    // the pool is live; greedy outputs must equal direct generation
    // bit-for-bit because pooled kernels partition output rows only.
    let cfg = ModelConfig {
        vocab: 32,
        d_model: 128,
        n_heads: 4,
        n_layers: 2,
        max_len: 192,
        d_ff: 256,
        chunk: 16,
        causal: true,
        lsh_rounds: 1,
        lsh_buckets: 8,
        lsh_chunk: 8,
    };
    let model = TransformerLM::init(&cfg, AttentionKind::Linear, 99);
    let cases: Vec<(Vec<u32>, usize)> = vec![
        (stream(100, cfg.vocab, 5000), 6), // crosses a PREFILL_CHUNK boundary
        (stream(2, cfg.vocab, 5001), 12),
        (stream(70, cfg.vocab, 5002), 4),
        (stream(9, cfg.vocab, 5003), 9),
        (stream(33, cfg.vocab, 5004), 1),
    ];
    let direct: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| model.generate(p, *n, 0.0, 0))
        .collect();
    let mut handle = NativeEngine::spawn(
        model,
        ServeConfig {
            max_batch: 3,
            max_wait_us: 500,
            num_threads: 4,
            ..Default::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, (p, n))| {
            handle.submit(GenerateRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new: *n,
                temperature: 0.0,
                top_k: 0,
            })
        })
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(
            resp.tokens, direct[resp.id as usize],
            "request {} diverged from direct generation under a 4-thread pool",
            resp.id
        );
    }
    handle.shutdown();
}

#[test]
fn engine_greedy_outputs_invariant_to_batch_size() {
    // the same request mix must produce identical greedy generations at
    // max_batch 1 (fully sequential) and max_batch 8 (fully batched)
    let cfg = tiny_cfg();
    let prompts: Vec<Vec<u32>> = (0..6)
        .map(|i| stream(1 + (i * 3) % 7, cfg.vocab, 2000 + i as u64))
        .collect();
    let mut per_batch: Vec<Vec<Vec<u32>>> = Vec::new();
    for max_batch in [1usize, 8] {
        let model = TransformerLM::init(&cfg, AttentionKind::Linear, 42);
        let mut handle = NativeEngine::spawn(
            model,
            ServeConfig {
                max_batch,
                max_wait_us: 500,
                ..Default::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                handle.submit(GenerateRequest {
                    id: i as u64,
                    prompt: p.clone(),
                    max_new: 5 + i,
                    temperature: 0.0,
                    top_k: 0,
                })
            })
            .collect();
        let mut outs = vec![Vec::new(); prompts.len()];
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            outs[resp.id as usize] = resp.tokens;
        }
        handle.shutdown();
        per_batch.push(outs);
    }
    assert_eq!(
        per_batch[0], per_batch[1],
        "greedy generations must not depend on batch size"
    );
}
