//! Scalar-vs-SIMD tier parity (the `rust/src/simd.rs` microkernel
//! contract).
//!
//! The contract under test, in order of strictness:
//!
//! * Every f32 kernel (`axpy`, `vecmat_into`, `matmul_into`, the
//!   `WeightMat` dispatchers, the batched attention kernels) is
//!   **bitwise identical** across ISA tiers — the SIMD variants
//!   vectorize across output columns, so each output element is still
//!   one accumulator walking k in ascending order.
//! * The widened-dtype kernels (f16/bf16/int8) are *also* bitwise
//!   identical across tiers, because the 8-wide conversions are exact —
//!   and their outputs track the f32 reference within the documented
//!   per-dtype `(rel_tol, abs_tol)` envelopes of `dtype_parity`.
//! * The pooled column-split kernels stay bitwise at any thread count
//!   on the SIMD tier, not just the scalar one.
//! * At the engine level, a greedy decode stream is identical with the
//!   tier forced to scalar (`LINTRA_SIMD=0`) and with auto detection.
//!
//! Tier forcing is process-global (`simd::force_tier` flips one atomic),
//! so every test here serializes on one mutex and restores the
//! ambient-configured tier on exit — including on panic — via a drop
//! guard. On hardware without AVX2 the force clamps to scalar and the
//! cross-tier assertions hold trivially; the suite stays green.

use std::sync::{Mutex, MutexGuard};

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::{ModelConfig, ServeConfig, SimdMode};
use linear_transformer::coordinator::engine::NativeEngine;
use linear_transformer::coordinator::request::GenerateRequest;
use linear_transformer::nn::TransformerLM;
use linear_transformer::parallel::ThreadPool;
use linear_transformer::propcheck::{assert_close_ulp, check, default_cases, Gen};
use linear_transformer::rng::Rng;
use linear_transformer::simd::{self, IsaTier};
use linear_transformer::tensor::{
    axpy, batched_contract, batched_outer_acc, matmul_into, matmul_into_w, matmul_into_w_pooled,
    vecmat_into, vecmat_into_cols_pooled, vecmat_into_w, vecmat_into_w_cols_pooled, WeightDtype,
    WeightMat,
};

static TIER_LOCK: Mutex<()> = Mutex::new(());

/// Serializes tier forcing across the (parallel) test harness and
/// restores the ambient-configured tier when dropped, panic included.
struct TierGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for TierGuard {
    fn drop(&mut self) {
        simd::configure(None);
    }
}

fn tier_guard() -> TierGuard {
    TierGuard(TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner()))
}

/// Bit patterns of a float slice: the comparison the bitwise contract
/// is actually phrased in (`==` on f32 would blur -0.0 and NaN).
fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|f| f.to_bits()).collect()
}

/// Run every f32 kernel family on the *current* tier and return the
/// outputs as bit patterns, one entry per kernel.
#[allow(clippy::too_many_arguments)]
fn f32_kernel_outputs(
    x: &[f32],
    bmat: &[f32],
    a: &[f32],
    m: usize,
    k: usize,
    n: usize,
    kv: &[f32],
    vv: &[f32],
    qv: &[f32],
    s0: &[f32],
    lanes: usize,
    d: usize,
    md: usize,
) -> Vec<Vec<u32>> {
    let mut outs: Vec<Vec<u32>> = Vec::new();

    // axpy: the shared inner loop, on its own
    let mut y: Vec<f32> = x.iter().map(|v| v * 0.5).collect();
    axpy(&mut y, 1.5, x);
    outs.push(bits(&y));

    // B=1 GEMV, dense f32 matrix
    let mut yv = vec![0.0f32; n];
    vecmat_into(&mut yv, x, bmat, k, n);
    outs.push(bits(&yv));

    // B=1 GEMV through the WeightMat f32 dispatcher (gemv_cols_f32)
    let w = WeightMat::quantize(bmat, k, n, WeightDtype::F32);
    let mut yw = vec![0.0f32; n];
    vecmat_into_w(&mut yw, x, &w, k, n);
    outs.push(bits(&yw));

    // prefill GEMMs: dense and WeightMat (packed path when m >= 4)
    let mut c = vec![0.0f32; m * n];
    matmul_into(&mut c, a, bmat, m, k, n);
    outs.push(bits(&c));
    let mut cw = vec![0.0f32; m * n];
    matmul_into_w(&mut cw, a, &w, m, k, n);
    outs.push(bits(&cw));

    // batched linear-attention kernels
    let mut s = s0.to_vec();
    batched_outer_acc(&mut s, kv, vv, lanes, d, md);
    outs.push(bits(&s));
    let mut out = vec![0.0f32; lanes * md];
    batched_contract(&mut out, qv, &s, lanes, d, md);
    outs.push(bits(&out));

    outs
}

/// The documented per-dtype decode-logit tolerances vs the f32
/// reference `(rel_tol, abs_tol)` — the same numbers `dtype_parity`
/// tests and ARCHITECTURE.md states.
fn tolerance(dtype: WeightDtype) -> (f32, f32) {
    match dtype {
        WeightDtype::F32 => (0.0, 0.0),
        WeightDtype::F16 => (5e-2, 5e-2),
        WeightDtype::Bf16 => (1e-1, 1e-1),
        WeightDtype::Int8 => (2e-1, 2e-1),
    }
}

#[test]
fn f32_kernels_are_bitwise_identical_across_tiers() {
    let _tier = tier_guard();
    // awkward shapes on purpose: cols not a multiple of the 8-lane
    // width, k below the unroll, single-row, and empty on both axes
    const KS: [usize; 6] = [0, 1, 3, 5, 17, 64];
    const NS: [usize; 7] = [0, 1, 7, 8, 9, 33, 65];
    const MS: [usize; 3] = [1, 4, 6];
    check("f32 scalar/simd tier parity", default_cases(), |g: &mut Gen| {
        let k = KS[g.usize_in(0, KS.len() - 1)];
        let n = NS[g.usize_in(0, NS.len() - 1)];
        let m = MS[g.usize_in(0, MS.len() - 1)];
        let (lanes, d, md) = (g.usize_in(1, 4), g.usize_in(1, 9), g.usize_in(1, 17));

        let mut x = g.vec_f32(k, 1.0);
        let bmat = g.vec_f32(k * n, 1.0);
        let a = g.vec_f32(m * k, 1.0);
        let mut kv = g.vec_f32(lanes * d, 1.0);
        let vv = g.vec_f32(lanes * md, 1.0);
        let mut qv = g.vec_f32(lanes * d, 1.0);
        let s0 = g.vec_f32(lanes * d * md, 1.0);
        // inject exact zeros: the f32 kernels' zero-skip must fire (or
        // not fire) identically on every tier
        for v in x.iter_mut().chain(kv.iter_mut()).chain(qv.iter_mut()) {
            if g.bool() && g.bool() {
                *v = 0.0;
            }
        }

        assert_eq!(simd::force_tier(IsaTier::Scalar), IsaTier::Scalar);
        let want = f32_kernel_outputs(&x, &bmat, &a, m, k, n, &kv, &vv, &qv, &s0, lanes, d, md);
        // clamps to scalar without AVX2 — trivially equal there
        simd::force_tier(IsaTier::Avx2);
        let got = f32_kernel_outputs(&x, &bmat, &a, m, k, n, &kv, &vv, &qv, &s0, lanes, d, md);

        const NAMES: [&str; 7] = [
            "axpy",
            "vecmat_into",
            "vecmat_into_w[f32]",
            "matmul_into",
            "matmul_into_w[f32]",
            "batched_outer_acc",
            "batched_contract",
        ];
        for ((g_bits, w_bits), name) in got.iter().zip(&want).zip(NAMES) {
            if g_bits != w_bits {
                return Err(format!("{name}: tier changed bits at m={m} k={k} n={n}"));
            }
        }
        Ok(())
    });
}

#[test]
fn widened_dtype_kernels_are_bitwise_across_tiers_and_inside_envelope() {
    let _tier = tier_guard();
    const KS: [usize; 4] = [1, 5, 17, 32];
    const NS: [usize; 5] = [1, 7, 8, 9, 48];
    for dtype in [WeightDtype::F16, WeightDtype::Bf16, WeightDtype::Int8] {
        check(&format!("{} tier parity", dtype.name()), default_cases(), |g: &mut Gen| {
            let k = KS[g.usize_in(0, KS.len() - 1)];
            let n = NS[g.usize_in(0, NS.len() - 1)];
            let m = 5; // past GEMM_PACK_MIN_ROWS: the packed panels run
            let data = g.vec_f32(k * n, 1.0);
            // modest activations keep the quantization-error sum far
            // inside the documented envelope at these k
            let x = g.vec_f32(k, 0.25);
            let a = g.vec_f32(m * k, 0.25);
            let w = WeightMat::quantize(&data, k, n, dtype);

            assert_eq!(simd::force_tier(IsaTier::Scalar), IsaTier::Scalar);
            let mut y_want = vec![0.0f32; n];
            vecmat_into_w(&mut y_want, &x, &w, k, n);
            let mut c_want = vec![0.0f32; m * n];
            matmul_into_w(&mut c_want, &a, &w, m, k, n);

            simd::force_tier(IsaTier::Avx2);
            let mut y_got = vec![0.0f32; n];
            vecmat_into_w(&mut y_got, &x, &w, k, n);
            let mut c_got = vec![0.0f32; m * n];
            matmul_into_w(&mut c_got, &a, &w, m, k, n);

            // the conversions are exact, so even the narrow dtypes are
            // *bitwise* across tiers — stronger than the envelope
            if bits(&y_got) != bits(&y_want) {
                return Err(format!("{} GEMV: tier changed bits k={k} n={n}", dtype.name()));
            }
            if bits(&c_got) != bits(&c_want) {
                return Err(format!("{} GEMM: tier changed bits k={k} n={n}", dtype.name()));
            }

            // and the widened output tracks the f32 source within the
            // documented dtype envelope (quantization error only)
            let (rel, abs) = tolerance(dtype);
            let mut y32 = vec![0.0f32; n];
            vecmat_into(&mut y32, &x, &data, k, n);
            for (j, (&got, &want)) in y_got.iter().zip(&y32).enumerate() {
                assert_close_ulp(
                    got,
                    want,
                    16,
                    rel,
                    abs,
                    &format!("{} GEMV col {j} vs f32 (k={k} n={n})", dtype.name()),
                );
            }
            Ok(())
        });
    }
}

#[test]
fn pooled_kernels_stay_bitwise_on_simd_tier() {
    let _tier = tier_guard();
    // on the SIMD tier (clamped to scalar without AVX2), the pooled
    // column split must still be invisible at any thread count
    simd::force_tier(IsaTier::Avx2);
    let mut rng = Rng::new(4242);

    // GEMV gate: n == PAR_MIN_GEMV_COLS and k*n == PAR_MIN_WORK exactly
    let (k, n) = (256usize, 64usize);
    let data = rng.normal_vec(k * n, 1.0);
    let x = rng.normal_vec(k, 1.0);
    // GEMM gate: m >= 2 and m*k2*n >= PAR_MIN_WORK
    let (m, k2) = (6usize, 64usize);
    let data2 = rng.normal_vec(k2 * n, 1.0);
    let a = rng.normal_vec(m * k2, 1.0);

    let mut y_serial = vec![0.0f32; n];
    vecmat_into(&mut y_serial, &x, &data, k, n);

    for threads in [2usize, 3, 4] {
        let pool = ThreadPool::new(threads);

        let mut y = vec![0.0f32; n];
        vecmat_into_cols_pooled(Some(&pool), &mut y, &x, &data, k, n);
        assert_eq!(bits(&y), bits(&y_serial), "{threads}-thread f32 GEMV split moved bits");

        for dtype in [
            WeightDtype::F32,
            WeightDtype::F16,
            WeightDtype::Bf16,
            WeightDtype::Int8,
        ] {
            let w = WeightMat::quantize(&data, k, n, dtype);
            let mut want = vec![0.0f32; n];
            vecmat_into_w(&mut want, &x, &w, k, n);
            let mut got = vec![0.0f32; n];
            vecmat_into_w_cols_pooled(Some(&pool), &mut got, &x, &w, k, n);
            assert_eq!(
                bits(&got),
                bits(&want),
                "{threads}-thread {} GEMV split moved bits",
                dtype.name()
            );

            let w2 = WeightMat::quantize(&data2, k2, n, dtype);
            let mut c_want = vec![0.0f32; m * n];
            matmul_into_w(&mut c_want, &a, &w2, m, k2, n);
            let mut c_got = vec![0.0f32; m * n];
            matmul_into_w_pooled(Some(&pool), &mut c_got, &a, &w2, m, k2, n);
            assert_eq!(
                bits(&c_got),
                bits(&c_want),
                "{threads}-thread {} GEMM row split moved bits",
                dtype.name()
            );
        }
    }
}

#[test]
fn simd_mode_resolution_drives_the_tier() {
    let _tier = tier_guard();
    // `--simd off` / LINTRA_SIMD=0 always lands on scalar; auto lands
    // on AVX2 exactly when the CPU has it; forcing clamps the same way
    assert_eq!(simd::configure(Some(SimdMode::Off)), IsaTier::Scalar);
    assert_eq!(simd::active_tier(), IsaTier::Scalar);
    let auto = simd::configure(Some(SimdMode::Auto));
    assert_eq!(auto == IsaTier::Avx2, simd::avx2_supported());
    assert_eq!(simd::force_tier(IsaTier::Avx2) == IsaTier::Avx2, simd::avx2_supported());
    assert_eq!(simd::force_tier(IsaTier::Scalar), IsaTier::Scalar);
}

/// Wide enough that both the SIMD gate (len >= 8) and the pooled gates
/// engage inside the engine's decode ticks.
fn engine_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 32,
        d_model: 128,
        n_heads: 4,
        n_layers: 2,
        max_len: 160,
        d_ff: 256,
        chunk: 16,
        causal: true,
        lsh_rounds: 1,
        lsh_buckets: 8,
        lsh_chunk: 8,
    }
}

fn engine_greedy_streams(cfg: &ModelConfig, cases: &[(Vec<u32>, usize)]) -> Vec<Vec<u32>> {
    let model = TransformerLM::init(cfg, AttentionKind::Linear, 77);
    let mut handle = NativeEngine::spawn(
        model,
        ServeConfig {
            max_batch: 2,
            max_wait_us: 500,
            num_threads: 4,
            ..Default::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> = cases
        .iter()
        .enumerate()
        .map(|(i, (p, n))| {
            handle.submit(GenerateRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new: *n,
                temperature: 0.0,
                top_k: 0,
            })
        })
        .collect();
    let streams: Vec<Vec<u32>> = rxs
        .into_iter()
        .map(|rx| {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            resp.tokens
        })
        .collect();
    handle.shutdown();
    streams
}

#[test]
fn engine_greedy_stream_identical_with_simd_off_and_auto() {
    let _tier = tier_guard();
    let cfg = engine_cfg();
    let mut rng = Rng::new(9000);
    let cases: Vec<(Vec<u32>, usize)> = [(20usize, 12usize), (33, 8)]
        .iter()
        .map(|&(len, n)| {
            let p: Vec<u32> = (0..len).map(|_| rng.below(cfg.vocab as u64) as u32).collect();
            (p, n)
        })
        .collect();

    // the engine worker threads read the same process-global tier, so
    // forcing here governs their kernels too (the lock is held)
    assert_eq!(simd::configure(Some(SimdMode::Off)), IsaTier::Scalar);
    let scalar_streams = engine_greedy_streams(&cfg, &cases);
    // direct single-stream reference on the scalar tier
    let direct_model = TransformerLM::init(&cfg, AttentionKind::Linear, 77);
    let direct: Vec<Vec<u32>> = cases
        .iter()
        .map(|(p, n)| direct_model.generate(p, *n, 0.0, 0))
        .collect();
    assert_eq!(scalar_streams, direct, "scalar engine diverged from direct decode");

    let auto_tier = simd::configure(Some(SimdMode::Auto));
    let auto_streams = engine_greedy_streams(&cfg, &cases);
    assert_eq!(
        auto_streams,
        scalar_streams,
        "greedy stream changed between LINTRA_SIMD=0 and auto (tier {})",
        auto_tier.label()
    );
}
