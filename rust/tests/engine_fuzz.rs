//! Seeded engine fuzzing: random serving scripts replayed against both
//! decode backends under varying thread counts and scheduler knobs.
//!
//! A *script* is a batch of requests with randomized prompt lengths (1 to
//! well past `PREFILL_CHUNK`, so admission spans multiple chunked-prefill
//! ticks), randomized `max_new` (tiny values retire lanes early while
//! longer prompts are still mid-prefill), and randomized greedy styles.
//! Each script replays under every serving configuration in the sweep —
//! GEMM-pool threads {1, 4}, per-slot/global prefill budgets, decode
//! batch sizes — and every replay must reproduce `model.generate`'s
//! output for every request exactly.
//!
//! Requests are restricted to *effectively greedy* sampling
//! (`temperature == 0` or `top_k == 1`, both of which reduce to argmax
//! in `sample_logits_topk`): the engine's documented contract is that
//! logits are bit-identical under any thread count or scheduling knob,
//! but with a temperature the worker's sampling RNG draws in schedule
//! order, so sampled (non-greedy) streams legitimately differ with batch
//! composition. Greedy streams are the schedule-invariant observable.
//!
//! `propcheck::engine_invariants::check_tick` runs inside the engine's
//! tick loop whenever `debug_assertions` are on (the default test
//! profile), so every replay here also sweeps the lane/slot/cache
//! invariants; any trip aborts the test. Scripts come from
//! `propcheck::check`, so failures print the seed for replay.

use linear_transformer::attention::AttentionKind;
use linear_transformer::config::{ModelConfig, ServeConfig};
use linear_transformer::coordinator::engine::NativeEngine;
use linear_transformer::coordinator::request::GenerateRequest;
use linear_transformer::nn::TransformerLM;
use linear_transformer::propcheck;

fn fuzz_cfg() -> ModelConfig {
    ModelConfig {
        vocab: 11,
        d_model: 32,
        n_heads: 2,
        n_layers: 2,
        d_ff: 64,
        // room for a 150-token prompt + decode without truncation, and
        // prompts past PREFILL_CHUNK (64) so admission is multi-tick
        max_len: 224,
        ..ModelConfig::small_copy()
    }
}

struct ScriptReq {
    prompt: Vec<u32>,
    max_new: usize,
    temperature: f32,
    top_k: usize,
}

/// Draw a random serving script from the generator.
fn gen_script(g: &mut propcheck::Gen, vocab: usize) -> Vec<ScriptReq> {
    let n_req = g.usize_in(3, 6);
    (0..n_req)
        .map(|_| {
            let len = g.usize_in(1, 150);
            let prompt = g.vec_usize(len, 0, vocab - 1).into_iter().map(|t| t as u32).collect();
            // both styles are argmax; the second also exercises the
            // top-k plumbing end to end
            let (temperature, top_k) = if g.bool() { (0.0, 0) } else { (0.7, 1) };
            ScriptReq {
                prompt,
                max_new: g.usize_in(1, 8),
                temperature,
                top_k,
            }
        })
        .collect()
}

/// Replay `script` on a fresh engine with the given knobs; return each
/// request's token stream, in script order.
fn replay(
    kind: AttentionKind,
    script: &[ScriptReq],
    threads: usize,
    max_batch: usize,
    chunks_per_tick: usize,
    chunk_budget: usize,
) -> Result<Vec<Vec<u32>>, String> {
    let cfg = fuzz_cfg();
    let mut handle = NativeEngine::spawn(
        TransformerLM::init(&cfg, kind, 23),
        ServeConfig {
            max_batch,
            max_wait_us: 100,
            num_threads: threads,
            prefill_chunks_per_tick: chunks_per_tick,
            prefill_chunk_budget: chunk_budget,
            ..Default::default()
        },
    )
    .map_err(|e| format!("spawn failed: {e}"))?;
    let rxs: Vec<_> = script
        .iter()
        .enumerate()
        .map(|(i, r)| {
            handle.submit(GenerateRequest {
                id: i as u64,
                prompt: r.prompt.clone(),
                max_new: r.max_new,
                temperature: r.temperature,
                top_k: r.top_k,
            })
        })
        .collect();
    let mut outs = Vec::with_capacity(script.len());
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().map_err(|e| format!("request {i}: recv failed: {e}"))?;
        if let Some(err) = resp.error {
            return Err(format!("request {i} errored: {err}"));
        }
        if resp.truncated {
            return Err(format!("request {i} truncated (script should fit max_len)"));
        }
        outs.push(resp.tokens);
    }
    let completed = handle.stats().completed;
    handle.shutdown();
    if completed as usize != script.len() {
        return Err(format!("completed {completed} of {} requests", script.len()));
    }
    Ok(outs)
}

/// The serving-knob sweep every script replays under: varies the pool
/// thread count, the decode batch, and both prefill budgets (per-slot
/// and global), covering each axis at least twice.
const SWEEP: [(usize, usize, usize, usize); 4] = [
    // (threads, max_batch, prefill_chunks_per_tick, prefill_chunk_budget)
    (1, 2, 1, 0),
    (4, 4, 1, 0),
    (1, 4, 8, 1),
    (4, 2, 1_000_000, 0),
];

fn fuzz_backend(kind: AttentionKind) {
    let cfg = fuzz_cfg();
    let oracle_model = TransformerLM::init(&cfg, kind, 23);
    // few cases: each replays 4 engine configs; scripts stay small
    propcheck::check(&format!("engine_fuzz_{}", kind.label()), 4, |g| {
        let script = gen_script(g, cfg.vocab);
        // the schedule-independent oracle: direct greedy generation
        let oracle: Vec<Vec<u32>> = script
            .iter()
            .map(|r| oracle_model.generate(&r.prompt, r.max_new, 0.0, 0))
            .collect();
        for &(threads, max_batch, chunks, budget) in SWEEP.iter() {
            let outs = replay(kind, &script, threads, max_batch, chunks, budget)?;
            for (i, (got, want)) in outs.iter().zip(oracle.iter()).enumerate() {
                if got != want {
                    return Err(format!(
                        "request {i} (prompt len {}, max_new {}): tokens diverged from \
                         direct generation under threads={threads} max_batch={max_batch} \
                         chunks_per_tick={chunks} chunk_budget={budget}: {got:?} vs {want:?}",
                        script[i].prompt.len(),
                        script[i].max_new,
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fuzzed_scripts_are_schedule_invariant_on_the_linear_backend() {
    fuzz_backend(AttentionKind::Linear);
}

#[test]
fn fuzzed_scripts_are_schedule_invariant_on_the_softmax_backend() {
    fuzz_backend(AttentionKind::Softmax);
}
