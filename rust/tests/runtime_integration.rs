//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These prove the three layers compose: Pallas kernels (L1) lowered inside
//! the jax model (L2) execute through the rust PJRT client (L3), and agree
//! numerically with the pure-rust native model running the same weights.
//!
//! All tests skip gracefully when `artifacts/` hasn't been built.

use linear_transformer::attention::AttentionKind;
use linear_transformer::nn::TransformerLM;
use linear_transformer::runtime::{Runtime, Value};
use linear_transformer::trainer::{self, Trainer};

fn artifacts_dir() -> Option<String> {
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    if std::path::Path::new(&dir).join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn decode_artifact_executes_and_preserves_state_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let art = rt.load("copy_decode_linear_b1").unwrap();
    let weights = rt.load_weights("copy_linear").unwrap();
    let spec = rt.bundle.model("copy_linear").unwrap().clone();

    let mut inputs: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let cfg = &spec.config;
    let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_model / cfg.n_heads);
    inputs.push(Value::I32(vec![1], vec![12])); // BOS
    inputs.push(Value::I32(vec![1], vec![0])); // pos
    inputs.push(Value::F32(vec![l, 1, h, dh, dh], vec![0.0; l * h * dh * dh]));
    inputs.push(Value::F32(vec![l, 1, h, dh], vec![0.0; l * h * dh]));
    let out = art.run(&inputs).unwrap();
    assert_eq!(out.len(), 3);
    assert_eq!(out[0].shape(), &[1, cfg.vocab]);
    assert_eq!(out[1].shape(), &[l, 1, h, dh, dh]);
    // state must have changed (phi(k) v^T is nonzero almost surely)
    assert!(out[1].as_f32().unwrap().iter().any(|&x| x != 0.0));
    assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn pjrt_decode_matches_native_model_on_same_weights() {
    // The core cross-layer parity check: the jax/Pallas decode step and the
    // rust-native RNN decode produce the same logits from the same weights.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let art = rt.load("copy_decode_linear_b1").unwrap();
    let weights = rt.load_weights("copy_linear").unwrap();
    let spec = rt.bundle.model("copy_linear").unwrap().clone();
    let cfg = &spec.config;
    let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_model / cfg.n_heads);

    let native = TransformerLM::from_bundle(cfg, AttentionKind::Linear, &weights).unwrap();
    let mut sess = native.session();

    let params: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let mut s = vec![0.0f32; l * h * dh * dh];
    let mut z = vec![0.0f32; l * h * dh];
    let tokens = [12u32, 5, 3, 7, 1, 5, 3, 7];
    for (pos, &tok) in tokens.iter().enumerate() {
        let mut inputs = params.clone();
        inputs.push(Value::I32(vec![1], vec![tok as i32]));
        inputs.push(Value::I32(vec![1], vec![pos as i32]));
        inputs.push(Value::F32(vec![l, 1, h, dh, dh], s.clone()));
        inputs.push(Value::F32(vec![l, 1, h, dh], z.clone()));
        let out = art.run(&inputs).unwrap();
        let pjrt_logits = out[0].as_f32().unwrap().to_vec();
        s = out[1].as_f32().unwrap().to_vec();
        z = out[2].as_f32().unwrap().to_vec();

        let native_logits = sess.step(tok);
        let max_diff = pjrt_logits
            .iter()
            .zip(&native_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            max_diff < 2e-2,
            "native/pjrt diverged at pos {pos}: max |Δlogit| = {max_diff}"
        );
    }
}

#[test]
fn eval_artifact_runs_and_matches_native_nll() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let eval = rt.load("copy_linear_eval").unwrap();
    let weights = rt.load_weights("copy_linear").unwrap();
    let spec = rt.bundle.model("copy_linear").unwrap().clone();
    let cfg = &spec.config;

    let params: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let batch_shape = eval.spec.inputs[params.len()].shape.clone();
    let (b, n) = (batch_shape[0], batch_shape[1]);
    let mut gen = linear_transformer::data::CopyTask::new(n, 7);
    let lm = gen.batch(b);
    let mut inputs = params.clone();
    inputs.push(Value::I32(vec![b, n], lm.inputs.iter().map(|&t| t as i32).collect()));
    inputs.push(Value::I32(vec![b, n], lm.targets.iter().map(|&t| t as i32).collect()));
    inputs.push(Value::F32(vec![b, n], vec![1.0; b * n])); // full mask
    let loss = eval.run(&inputs).unwrap()[0].scalar().unwrap();
    assert!(loss.is_finite() && loss > 0.0);

    // native NLL of the same batch with the same weights
    let native = TransformerLM::from_bundle(cfg, AttentionKind::Linear, &weights).unwrap();
    let mut total = 0.0f64;
    for s in 0..b {
        total += native.sequence_nll(
            &lm.inputs[s * n..(s + 1) * n],
            &lm.targets[s * n..(s + 1) * n],
        );
    }
    let native_nll = total / b as f64;
    assert!(
        (native_nll - loss as f64).abs() < 0.02,
        "native {native_nll} vs pjrt {loss}"
    );
}

#[test]
fn trainer_reduces_copy_loss() {
    // End-to-end: the train artifact (fwd+bwd through the Pallas
    // constant-memory kernel + RAdam) actually learns.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let mut tr = Trainer::new(&mut rt, "copy", "linear").unwrap();
    let specs = tr.batch_specs().to_vec();
    let (b, n) = (specs[0].shape[0], specs[0].shape[1]);
    let mut batch_fn = trainer::copy_batch_fn(n, b, 0);
    let mut first = None;
    let mut last = 0.0;
    for step in 0..25 {
        let stats = tr.step(1e-3, batch_fn(step)).unwrap();
        if first.is_none() {
            first = Some(stats.loss);
        }
        last = stats.loss;
    }
    let first = first.unwrap();
    assert!(
        last < first,
        "training did not reduce loss: {first} -> {last}"
    );
    // checkpoint roundtrip: weights load into the native model
    let w = tr.weights().unwrap();
    let spec = rt.bundle.model("copy_linear").unwrap();
    let native = TransformerLM::from_bundle(&spec.config, AttentionKind::Linear, &w).unwrap();
    let logits = native.forward(&[12, 3, 4]);
    assert!(logits.data.iter().all(|x| x.is_finite()));
}

#[test]
fn prefill_state_feeds_decode() {
    // image-completion path: prefill 384 pixels, continue decoding
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let prefill = rt.load("mnist_prefill_b1").unwrap();
    let decode = rt.load("mnist_decode_linear_b1").unwrap();
    let weights = rt.load_weights("mnist_linear").unwrap();
    let spec = rt.bundle.model("mnist_linear").unwrap().clone();
    let cfg = &spec.config;
    let (l, h, dh) = (cfg.n_layers, cfg.n_heads, cfg.d_model / cfg.n_heads);

    let params: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let plen = prefill.spec.inputs.last().unwrap().shape[1];
    let mut img = linear_transformer::data::ImageDataset::new(
        linear_transformer::data::ImageKind::MnistLike,
        3,
    );
    let (px, _) = img.sample();
    // model inputs are shifted: [0, px0, px1, ...]
    let mut prompt: Vec<i32> = vec![0];
    prompt.extend(px[..plen - 1].iter().map(|&p| p as i32));

    let mut inputs = params.clone();
    inputs.push(Value::I32(vec![1, plen], prompt));
    let out = prefill.run(&inputs).unwrap();
    assert_eq!(out[0].shape(), &[1, plen, cfg.vocab]);
    let s = out[1].as_f32().unwrap().to_vec();
    let z = out[2].as_f32().unwrap().to_vec();
    assert_eq!(s.len(), l * h * dh * dh);

    // continue decoding one step from the prefilled state
    let mut dec_inputs = params.clone();
    dec_inputs.push(Value::I32(vec![1], vec![px[plen - 1] as i32]));
    dec_inputs.push(Value::I32(vec![1], vec![plen as i32]));
    dec_inputs.push(Value::F32(vec![l, 1, h, dh, dh], s));
    dec_inputs.push(Value::F32(vec![l, 1, h, dh], z));
    let dout = decode.run(&dec_inputs).unwrap();
    assert!(dout[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}
