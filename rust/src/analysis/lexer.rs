//! Line-oriented lexical pre-pass for the `lintra analyze` rule engine.
//!
//! The rules in [`super::rules`] are textual, but naive substring matching
//! would fire on comments, doc examples, and string literals. This module
//! splits a Rust source file into per-line *views*: the `code` view keeps
//! only real code (string/char literal bodies blanked, comments removed),
//! and the `comment` view keeps only comment text (where pragmas like
//! `lintra: allow(...)` and `SAFETY:` annotations live).
//!
//! This is a deliberately small scanner, not a full lexer: it understands
//! line comments, nested block comments, string escapes, raw strings
//! (`r#".."#`, any hash count), byte strings, char literals, and the
//! char-literal/lifetime ambiguity (`'a'` vs `&'a str`). That is enough
//! for every rule to match on token text without being fooled by quoted
//! or commented occurrences.

/// One source line, split into its code and comment content.
#[derive(Debug, Default, Clone)]
pub struct Line {
    /// Code text with comments removed and literal bodies blanked. String
    /// literals collapse to `""`, char literals to `' '`; their structure
    /// survives so brace/bracket matching still works.
    pub code: String,
    /// Concatenated text of every comment on the line (without `//`,
    /// `/*`, `*/` markers). Multi-line block comments contribute to each
    /// line they span.
    pub comment: String,
}

/// Scanner state carried across characters (and lines, for multi-line
/// constructs).
enum State {
    Code,
    LineComment,
    /// Nested block comment; the value is the nesting depth.
    BlockComment(u32),
    /// Ordinary (escaped) string literal.
    Str,
    /// Raw string literal terminated by `"` followed by this many `#`s.
    RawStr(u32),
}

/// Split `src` into per-line code/comment views. Always returns one
/// [`Line`] per input line (empty lines included), so indices into the
/// result are 0-based line numbers.
pub fn split_source(src: &str) -> Vec<Line> {
    let chars: Vec<char> = src.chars().collect();
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line::default();
    let mut state = State::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                match c {
                    '/' if next == Some('/') => {
                        state = State::LineComment;
                        i += 2;
                        continue;
                    }
                    '/' if next == Some('*') => {
                        state = State::BlockComment(1);
                        i += 2;
                        continue;
                    }
                    '"' => {
                        cur.code.push('"');
                        state = State::Str;
                    }
                    'r' | 'b' if is_raw_string_start(&chars, i) => {
                        // consume the prefix (`r`, `br`) and opening hashes
                        let mut j = i;
                        while chars.get(j) == Some(&'b') || chars.get(j) == Some(&'r') {
                            j += 1;
                        }
                        let mut hashes = 0u32;
                        while chars.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        // chars[j] is the opening quote
                        cur.code.push('"');
                        state = State::RawStr(hashes);
                        i = j + 1;
                        continue;
                    }
                    '\'' => {
                        if let Some(end) = char_literal_end(&chars, i) {
                            cur.code.push_str("' '");
                            // blank the body but keep line structure
                            i = end + 1;
                            continue;
                        }
                        // lifetime marker: keep the quote so `&'a` stays
                        // distinguishable from `&a`
                        cur.code.push('\'');
                    }
                    _ => cur.code.push(c),
                }
                i += 1;
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // skip the escaped character — but a line-continuation
                    // escape (`\` at end of line) must still emit the line
                    // break, or every later line in the file would shift
                    // by one and findings would point at the wrong code
                    if chars.get(i + 1) == Some(&'\n') {
                        lines.push(std::mem::take(&mut cur));
                    }
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && raw_string_closes(&chars, i, hashes) {
                    cur.code.push('"');
                    state = State::Code;
                    i += 1 + hashes as usize;
                } else {
                    i += 1;
                }
            }
        }
    }
    lines.push(cur);
    lines
}

/// Does `chars[i..]` start a raw (or raw byte) string literal? Accepts
/// `r"`, `r#"`, `br"`, `br#"` (any hash count). Requires the previous
/// character not to be part of an identifier, so `zr"..` inside an
/// identifier-adjacent position cannot misfire.
fn is_raw_string_start(chars: &[char], i: usize) -> bool {
    if i > 0 && is_ident_char(chars[i - 1]) {
        return false;
    }
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return false;
    }
    j += 1;
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}

/// Does the `"` at `chars[i]` close a raw string opened with `hashes`
/// hashes (i.e. is it followed by that many `#`s)?
fn raw_string_closes(chars: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| chars.get(i + k) == Some(&'#'))
}

/// If the `'` at `chars[i]` opens a char literal, return the index of the
/// closing `'`. Otherwise (a lifetime like `'a` or `<'static>`, a loop
/// label like `'outer:`, or the anonymous `'_`) return None.
///
/// Rust's own disambiguation rule: `'X'` (any single char, closing quote
/// right after) is a char literal; a tick followed by an identifier
/// without that immediate closing quote is a lifetime/label. Earlier
/// versions of this scanner got two edges wrong — `'\''` reported the
/// *escaped* quote as the closing one (leaving a stray quote in the code
/// view), and the escaped-literal lookahead ran across newlines, so a
/// malformed tick could swallow a line boundary and shift every later
/// finding's line number. Both are pinned by fixtures now.
fn char_literal_end(chars: &[char], i: usize) -> Option<usize> {
    match chars.get(i + 1)? {
        '\\' => {
            // escaped literal: the char after the backslash belongs to the
            // escape (it may itself be a quote, as in '\''), then scan to
            // the closing quote. Bounded — the longest escape is
            // '\u{10FFFF}' — and never across a line break.
            if chars.get(i + 2) == Some(&'\n') {
                return None;
            }
            let mut j = i + 3;
            let limit = (i + 13).min(chars.len());
            while j < limit {
                match chars[j] {
                    '\'' => return Some(j),
                    '\n' => return None,
                    _ => j += 1,
                }
            }
            None
        }
        '\n' => None, // a tick at end of line is never a literal opener
        _ => {
            // one-character literal: 'x'. A tick NOT closed two chars
            // later is a lifetime or label (`'a`, `'static`, `'outer:`)
            // and stays in the code view as-is.
            if chars.get(i + 2) == Some(&'\'') {
                Some(i + 2)
            } else {
                None
            }
        }
    }
}

/// Identifier-ish character (used for word-boundary checks here and by
/// the rules).
pub fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Iterate identifiers in a code view, yielding `(start_byte, ident)`.
/// Skips numeric literals (tokens starting with a digit).
pub fn idents(code: &str) -> impl Iterator<Item = (usize, &str)> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c.is_ascii_alphabetic() || c == '_' {
            let start = i;
            while i < bytes.len() && is_ident_char(bytes[i] as char) {
                i += 1;
            }
            out.push((start, &code[start..i]));
        } else if c.is_ascii_digit() {
            while i < bytes.len() && is_ident_char(bytes[i] as char) {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out.into_iter()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        split_source(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strips_line_comments() {
        let lines = split_source("let x = 1; // unwrap() here is comment\n");
        assert!(lines[0].code.contains("let x = 1;"));
        assert!(!lines[0].code.contains("unwrap"));
        assert!(lines[0].comment.contains("unwrap() here is comment"));
    }

    #[test]
    fn blanks_string_literals() {
        let lines = code_of("let s = \"call .unwrap() now\";\n");
        assert!(!lines[0].contains("unwrap"));
        assert!(lines[0].contains("\"\""));
    }

    #[test]
    fn nested_block_comments() {
        let lines = code_of("a /* x /* y */ z */ b\n");
        assert!(lines[0].contains('a'));
        assert!(lines[0].contains('b'));
        assert!(!lines[0].contains('z'));
    }

    #[test]
    fn raw_strings_with_hashes() {
        let lines = code_of("let s = r#\"env::var(\"X\") \"#; tail()\n");
        assert!(!lines[0].contains("env::var"));
        assert!(lines[0].contains("tail()"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let lines = code_of("fn f<'a>(x: &'a str) { let c = '\"'; g(x) }\n");
        // the quote char literal must not open a string state
        assert!(lines[0].contains("g(x)"));
        let lines = code_of("let c = 'x'; h()\n");
        assert!(lines[0].contains("h()"));
    }

    #[test]
    fn multiline_string_blanks_interior() {
        let lines = code_of("let s = \"line one\nunwrap() inside\";\nafter()\n");
        assert!(!lines[1].contains("unwrap"));
        assert!(lines[2].contains("after()"));
    }

    #[test]
    fn ident_iterator_skips_numbers() {
        let toks: Vec<&str> = idents("foo(1.0f32, bar_2)").map(|(_, s)| s).collect();
        assert_eq!(toks, vec!["foo", "bar_2"]);
    }

    #[test]
    fn lifetime_ticks_never_open_char_literals() {
        // a battery of lifetime/label positions; in every case the code
        // after the tick must survive into the code view (a misread tick
        // would blank it as a literal body and hide findings)
        for (src, keep) in [
            ("fn f<'a>(x: &'a str) -> &'a str { x.trim() }\n", "trim()"),
            ("struct S<'s> { field: &'s [f32] }\n", "[f32]"),
            ("impl<'m> Iterator for It<'m> { fn next(&mut self) { self.go() } }\n", "go()"),
            ("fn g<'static_like, T: 'static>(v: Vec<&'static_like T>) { v.len(); }\n", "len()"),
            ("fn h(p: &'_ str) { p.len(); }\n", "len()"),
            ("fn lanes<'a, 'b>(x: &'a u32, y: &'b u32) { use_them(x, y) }\n", "use_them"),
            ("'outer: loop { break 'outer; }\n", "break"),
            ("for<'de> fn deserialize(d: &'de str) { d.probe() }\n", "probe()"),
        ] {
            let lines = code_of(src);
            let joined = lines.join("\n");
            assert!(
                joined.contains(keep),
                "code view lost {keep:?} for {src:?}: {joined:?}"
            );
            // none of the inputs contain a char literal, so nothing may
            // have been blanked to the literal placeholder
            assert!(
                !joined.contains("' '"),
                "lifetime misread as char literal in {src:?}: {joined:?}"
            );
        }
    }

    #[test]
    fn lifetime_heavy_line_keeps_trailing_violations_visible() {
        // regression shape for the rule engine: a panicking call after a
        // lifetime-rich signature must stay in the code view
        let lines = code_of("fn f<'a>(x: &'a str) -> u32 { x.parse().unwrap() }\n");
        assert!(lines[0].contains("unwrap"), "got {:?}", lines[0]);
    }

    #[test]
    fn escaped_quote_char_literal_ends_at_real_closing_quote() {
        // `'\''` previously "closed" at the escaped quote, leaving the
        // real closing quote behind as a stray in the code view
        let lines = code_of("let q = '\\''; x.unwrap();\n");
        assert!(lines[0].contains("unwrap"), "got {:?}", lines[0]);
        assert!(
            !lines[0].contains("''"),
            "stray quote from mis-closed '\\'' literal: {:?}",
            lines[0]
        );
        // and the other escapes still close where they should
        for src in ["let c = '\\\\'; t()\n", "let c = '\\n'; t()\n", "let c = '\\u{10FFFF}'; t()\n"] {
            let lines = code_of(src);
            assert!(lines[0].contains("t()"), "{src:?} -> {:?}", lines[0]);
        }
    }

    #[test]
    fn char_escape_lookahead_never_crosses_a_line_break() {
        // a malformed tick at end of line must not swallow the newline —
        // that would shift every later line's number
        let src = "let bad = '\\\nfn next_line() { x.unwrap() }\n";
        let lines = split_source(src);
        assert_eq!(lines.len(), 3, "line boundaries must be preserved");
        assert!(lines[1].code.contains("unwrap"), "got {:?}", lines[1].code);
    }

    #[test]
    fn string_line_continuation_keeps_line_count() {
        // `"...\` + newline is a string continuation; the escape skip must
        // still emit the line break so later findings stay on their lines
        let src = "let s = \"one \\\n two\";\nx.unwrap();\n";
        let lines = split_source(src);
        assert_eq!(lines.len(), 4);
        assert!(lines[2].code.contains("unwrap"), "got {:?}", lines[2].code);
    }
}
