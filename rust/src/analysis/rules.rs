//! The repo-invariant rules behind `lintra analyze`.
//!
//! Each rule inspects the per-line code/comment views produced by
//! [`super::lexer`], plus two kinds of region context computed here:
//! `#[cfg(test)]` modules (all rules skip them — the invariants guard
//! production code, and tests deliberately poison locks and index
//! wildly), and functions tagged bitwise-critical (rule `bitwise` only
//! fires inside them).
//!
//! Suppression grammar (see [`super`] for the rule list): a comment of
//! the form `lintra: allow(<rule>) -- <reason>` suppresses `<rule>` on
//! its own line, or on the next code-bearing line when the pragma has a
//! line to itself; a comment of the form `lintra: bitwise-critical` tags
//! the next `fn` for the `bitwise` rule.
//!
//! A pragma without a reason after `--` is itself a finding: the point of
//! the pass is that every surviving hot-path hazard carries a written
//! justification, so a bare suppression defeats it. A comment is only
//! treated as a pragma when it *starts* with `lintra:` (after doc-comment
//! markers), so prose that merely mentions the grammar does not misfire.

use super::lexer::{idents, is_ident_char, split_source, Line};
use super::{Finding, Rule};

/// Per-file context: line views plus region and suppression maps.
pub(crate) struct FileCtx {
    pub lines: Vec<Line>,
    /// Line is inside a `#[cfg(test)]` region.
    pub in_test: Vec<bool>,
    /// Line is inside a `// lintra: bitwise-critical` tagged function.
    pub tagged: Vec<bool>,
    /// Tagged function regions as inclusive (start, end) line ranges.
    pub tagged_regions: Vec<(usize, usize)>,
    /// Rules suppressed per line by a reasoned allow pragma.
    pub allows: Vec<Vec<Rule>>,
    /// Malformed pragmas (missing reason / unknown rule), as findings.
    pub bad_pragmas: Vec<(usize, String)>,
}

impl FileCtx {
    pub fn build(src: &str) -> FileCtx {
        let lines = split_source(src);
        let n = lines.len();
        let mut ctx = FileCtx {
            in_test: vec![false; n],
            tagged: vec![false; n],
            tagged_regions: Vec::new(),
            allows: vec![Vec::new(); n],
            bad_pragmas: Vec::new(),
            lines,
        };
        ctx.scan_regions();
        ctx.scan_pragmas();
        ctx
    }

    /// One pass of brace tracking to mark `#[cfg(test)]` modules and
    /// bitwise-critical function bodies. The `cfg(test)` attribute (or a
    /// tag comment) arms a pending marker that attaches to the next `{`;
    /// the region closes when brace depth returns to its opening level.
    fn scan_regions(&mut self) {
        let mut depth: i32 = 0;
        let mut test_stack: Vec<i32> = Vec::new();
        let mut pending_test = false;
        let mut pending_tag = false;
        let mut tag_open: Option<i32> = None;
        let mut tag_start = 0usize;
        for i in 0..self.lines.len() {
            if pragma_body(&self.lines[i].comment)
                .map(|p| p.trim_start().starts_with("bitwise-critical"))
                .unwrap_or(false)
            {
                pending_tag = true;
                tag_start = i;
            }
            if self.lines[i].code.contains("cfg(test)") {
                pending_test = true;
            }
            self.in_test[i] = !test_stack.is_empty();
            self.tagged[i] = tag_open.is_some() || pending_tag;
            for c in self.lines[i].code.chars() {
                match c {
                    '{' => {
                        depth += 1;
                        if pending_test {
                            test_stack.push(depth);
                            pending_test = false;
                            self.in_test[i] = true;
                        }
                        if pending_tag && tag_open.is_none() {
                            tag_open = Some(depth);
                            pending_tag = false;
                        }
                    }
                    '}' => {
                        if test_stack.last() == Some(&depth) {
                            test_stack.pop();
                        }
                        if tag_open == Some(depth) {
                            tag_open = None;
                            self.tagged_regions.push((tag_start, i));
                        }
                        depth -= 1;
                    }
                    _ => {}
                }
            }
        }
    }

    /// Collect `lintra: allow(...)` pragmas. An inline pragma suppresses
    /// on its own line; a pragma on a comment-only line suppresses on the
    /// next line that has code.
    fn scan_pragmas(&mut self) {
        for i in 0..self.lines.len() {
            let Some(body) = pragma_body(&self.lines[i].comment) else {
                continue;
            };
            let body = body.trim();
            if body.starts_with("bitwise-critical") {
                continue; // handled by scan_regions
            }
            let Some(rest) = body.strip_prefix("allow(") else {
                self.bad_pragmas
                    .push((i, format!("unknown lintra pragma {body:?}")));
                continue;
            };
            let Some((slug, after)) = rest.split_once(')') else {
                self.bad_pragmas
                    .push((i, "malformed allow pragma: missing `)`".into()));
                continue;
            };
            let Some(rule) = Rule::from_slug(slug.trim()) else {
                self.bad_pragmas
                    .push((i, format!("allow pragma names unknown rule {:?}", slug.trim())));
                continue;
            };
            let reason_ok = after
                .trim_start()
                .strip_prefix("--")
                .map(|r| !r.trim().is_empty())
                .unwrap_or(false);
            if !reason_ok {
                self.bad_pragmas.push((
                    i,
                    format!(
                        "allow({}) pragma requires a reason: `-- <why this is safe>`",
                        rule.slug()
                    ),
                ));
                continue;
            }
            let target = if self.lines[i].code.trim().is_empty() {
                // own-line pragma: applies to the next code-bearing line
                (i + 1..self.lines.len()).find(|&j| !self.lines[j].code.trim().is_empty())
            } else {
                Some(i)
            };
            if let Some(t) = target {
                self.allows[t].push(rule);
            }
        }
    }

    fn allowed(&self, line: usize, rule: Rule) -> bool {
        self.allows[line].contains(&rule)
    }
}

/// Extract a pragma body from a comment view: doc markers (`/`, `!`) and
/// whitespace are trimmed, then the comment must *begin* with `lintra:`.
fn pragma_body(comment: &str) -> Option<&str> {
    let t = comment.trim_start_matches(['/', '!', ' ', '\t']);
    t.strip_prefix("lintra:")
}

/// Whitespace-stripped copy of a code view, for multi-token patterns like
/// `.lock().unwrap()` that may be spaced freely.
fn despace(code: &str) -> String {
    code.chars().filter(|c| !c.is_whitespace()).collect()
}

/// Does `hay` contain `needle` at a non-identifier boundary (the char
/// before the match is not part of an identifier)?
fn contains_bounded(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let at = from + pos;
        let pre_ok = at == 0
            || !is_ident_char(hay[..at].chars().next_back().unwrap_or(' '));
        if pre_ok {
            return true;
        }
        from = at + needle.len();
    }
    false
}

/// Rule `panic`: panicking constructs in serving hot-path files.
/// Flags `.unwrap()` / `.expect(..)` method calls, the panicking macros
/// (`panic!`, `todo!`, `unimplemented!`, `unreachable!`), and *fallible*
/// slice indexing — ranges (`x[a..b]`) and arithmetic indices
/// (`x[i + 1]`). Plain variable indexing (`x[i]`) is accepted: flagging
/// every subscript would bury the signal in pragmas, and the arithmetic
/// forms are where the off-by-one / stale-length bugs live.
pub(crate) fn check_panic(ctx: &FileCtx, path: &str, out: &mut Vec<Finding>) {
    const MACROS: [&str; 4] = ["panic", "todo", "unimplemented", "unreachable"];
    for (i, line) in ctx.lines.iter().enumerate() {
        if ctx.in_test[i] || ctx.allowed(i, Rule::Panic) {
            continue;
        }
        let code = &line.code;
        for (start, id) in idents(code) {
            let before = code[..start].trim_end().chars().next_back();
            let after = code[start + id.len()..].trim_start().chars().next();
            if (id == "unwrap" || id == "expect") && before == Some('.') && after == Some('(') {
                push(out, path, i, Rule::Panic, format!(".{id}() in serving hot path"));
            }
            if MACROS.contains(&id) && after == Some('!') {
                push(out, path, i, Rule::Panic, format!("{id}! in serving hot path"));
            }
        }
        for msg in fallible_indexing(code) {
            push(out, path, i, Rule::Panic, msg);
        }
    }
}

/// Scan a code view for index expressions whose contents can go out of
/// bounds non-obviously: any range (`..`) or arithmetic on the index.
fn fallible_indexing(code: &str) -> Vec<String> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        if bytes[i] != b'[' {
            i += 1;
            continue;
        }
        // an *index* bracket follows a value: identifier, `)`, or `]` —
        // but not a keyword (`let [a, ..] = x` is a slice pattern, and
        // `&mut [f32]` / `in [..]` are type/expr positions)
        let before = code[..i].trim_end();
        let prev = before.chars().next_back();
        let prev_word: String = before
            .chars()
            .rev()
            .take_while(|&c| is_ident_char(c))
            .collect::<String>()
            .chars()
            .rev()
            .collect();
        const KEYWORDS: [&str; 8] =
            ["let", "mut", "ref", "in", "return", "break", "else", "match"];
        let is_index = matches!(prev, Some(c) if is_ident_char(c) || c == ')' || c == ']')
            && !KEYWORDS.contains(&prev_word.as_str());
        // find the matching close bracket
        let mut depth = 1i32;
        let mut j = i + 1;
        while j < bytes.len() && depth > 0 {
            match bytes[j] {
                b'[' => depth += 1,
                b']' => depth -= 1,
                _ => {}
            }
            j += 1;
        }
        if depth != 0 {
            break; // unbalanced on this line (multi-line index): skip
        }
        let inner = &code[i + 1..j - 1];
        if is_index && !inner.trim().is_empty() {
            if inner.contains("..") && inner.trim() != ".." {
                out.push(format!("range slice indexing `[{}]` can panic", inner.trim()));
            } else if inner.chars().any(|c| matches!(c, '+' | '-' | '*' | '/' | '%')) {
                out.push(format!("computed index `[{}]` can panic", inner.trim()));
            }
        }
        i += 1; // step inside: nested brackets get their own scan
    }
    out
}

/// Rule `bitwise`: numeric hygiene inside tagged kernels. `mul_add`
/// contracts rounding differently than mul-then-add and is not used by
/// the serial reference kernels; HashMap/HashSet iteration order is
/// unspecified, so reducing over it breaks run-to-run determinism; and
/// more than one scalar accumulator feeding the same output element
/// implies a reduction-order split that will not match the serial kernel
/// bit-for-bit.
pub(crate) fn check_bitwise(ctx: &FileCtx, path: &str, out: &mut Vec<Finding>) {
    for (i, line) in ctx.lines.iter().enumerate() {
        if !ctx.tagged[i] || ctx.in_test[i] || ctx.allowed(i, Rule::Bitwise) {
            continue;
        }
        for (_, id) in idents(&line.code) {
            match id {
                "mul_add" => push(
                    out,
                    path,
                    i,
                    Rule::Bitwise,
                    "mul_add in bitwise-critical kernel (fused rounding differs from mul-then-add)"
                        .into(),
                ),
                "HashMap" | "HashSet" => push(
                    out,
                    path,
                    i,
                    Rule::Bitwise,
                    format!("{id} in bitwise-critical kernel (unordered iteration)"),
                ),
                _ => {}
            }
        }
    }
    for &(start, end) in &ctx.tagged_regions {
        let mut names: Vec<(usize, String)> = Vec::new();
        for i in start..=end {
            if ctx.in_test[i] {
                continue;
            }
            for name in zero_init_accumulators(&ctx.lines[i].code) {
                if !names.iter().any(|(_, n)| *n == name) {
                    names.push((i, name));
                }
            }
        }
        if names.len() >= 2 {
            let (line, _) = names[1];
            if !ctx.allowed(line, Rule::Bitwise) {
                let list: Vec<&str> = names.iter().map(|(_, n)| n.as_str()).collect();
                push(
                    out,
                    path,
                    line,
                    Rule::Bitwise,
                    format!(
                        "multiple scalar accumulators in one bitwise-critical fn ({}): \
                         reductions must keep one accumulator per output element",
                        list.join(", ")
                    ),
                );
            }
        }
    }
}

/// Find `let mut <acc-ish> = 0.0...;` scalar float zero-inits. Array
/// accumulators (`[0.0; NR]` — one slot per output column) are fine and
/// skipped.
fn zero_init_accumulators(code: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(pos) = code[from..].find("let mut ") {
        let rest = &code[from + pos + "let mut ".len()..];
        from += pos + "let mut ".len();
        let name: String = rest.chars().take_while(|&c| is_ident_char(c)).collect();
        let acc_ish = ["acc", "sum", "partial", "total"]
            .iter()
            .any(|p| name.starts_with(p));
        if !acc_ish {
            continue;
        }
        let after = rest[name.len()..].trim_start();
        let Some(init) = after.strip_prefix('=') else { continue };
        let init = init.trim_start();
        let lit: String = init
            .chars()
            .take_while(|&c| is_ident_char(c) || c == '.')
            .collect();
        let float_zero = lit.starts_with('0')
            && (lit.contains('.') || lit.contains("f32") || lit.contains("f64"));
        if float_zero {
            out.push(name);
        }
    }
    out
}

/// Rule `env`: `std::env::var` reads outside the config/parallel
/// resolvers. Scattered env reads make serving behaviour depend on where
/// a code path happens to run; the crate's contract is that every knob
/// resolves in exactly one place (`config.rs`, `parallel.rs`).
pub(crate) fn check_env(ctx: &FileCtx, path: &str, out: &mut Vec<Finding>) {
    for (i, line) in ctx.lines.iter().enumerate() {
        if ctx.in_test[i] || ctx.allowed(i, Rule::Env) {
            continue;
        }
        let flat = despace(&line.code);
        if contains_bounded(&flat, "env::var(") || contains_bounded(&flat, "env::var_os(") {
            push(
                out,
                path,
                i,
                Rule::Env,
                "env read outside config.rs/parallel.rs resolvers".into(),
            );
        }
    }
}

/// Rule `safety`: every `unsafe` must be immediately preceded by a
/// `// SAFETY:` comment (same line, or the contiguous comment block
/// directly above) stating the invariant that makes it sound.
pub(crate) fn check_safety(ctx: &FileCtx, path: &str, out: &mut Vec<Finding>) {
    for (i, line) in ctx.lines.iter().enumerate() {
        if ctx.in_test[i] || ctx.allowed(i, Rule::Safety) {
            continue;
        }
        if !idents(&line.code).any(|(_, id)| id == "unsafe") {
            continue;
        }
        let mut justified = line.comment.contains("SAFETY:");
        let mut j = i;
        while !justified && j > 0 {
            j -= 1;
            let above = &ctx.lines[j];
            if !above.code.trim().is_empty() || above.comment.is_empty() {
                break; // contiguity ends at code or a blank line
            }
            justified = above.comment.contains("SAFETY:");
        }
        if !justified {
            push(
                out,
                path,
                i,
                Rule::Safety,
                "unsafe without an immediately preceding // SAFETY: comment".into(),
            );
        }
    }
}

/// Rule `lock`: `.lock().unwrap()` / `.lock().expect(..)` propagate a
/// peer thread's panic into this one (mutex poisoning), so one dead
/// connection thread could cascade into the engine. All lock
/// acquisitions go through `parallel::lock_unpoisoned`, which takes the
/// data even when poisoned.
pub(crate) fn check_lock(ctx: &FileCtx, path: &str, out: &mut Vec<Finding>) {
    for (i, line) in ctx.lines.iter().enumerate() {
        if ctx.in_test[i] || ctx.allowed(i, Rule::Lock) {
            continue;
        }
        let flat = despace(&line.code);
        if flat.contains(".lock().unwrap()") || flat.contains(".lock().expect(") {
            push(
                out,
                path,
                i,
                Rule::Lock,
                "use parallel::lock_unpoisoned instead of .lock().unwrap()".into(),
            );
        }
    }
}

/// A tick-reachable function's body range inside one file, as computed
/// by [`super::callgraph`]: the scope the interprocedural rules
/// ([`check_panic_reachable`], [`check_alloc`]) apply to.
pub(crate) struct FnScope<'a> {
    pub name: &'a str,
    /// Inclusive 0-based line range (signature through closing brace).
    pub start: usize,
    pub end: usize,
}

/// Interprocedural extension of rule `panic`: panicking constructs
/// (`.unwrap()` / `.expect(..)` / panicking macros — not the indexing
/// heuristic, which stays file-scoped) inside functions the engine tick
/// loop reaches *outside* the serving file set. A panic here unwinds the
/// engine worker exactly like one in `engine.rs` would; the call graph
/// is what makes a helper in `tensor.rs` or `nn/mod.rs` visible.
pub(crate) fn check_panic_reachable(
    ctx: &FileCtx,
    path: &str,
    fns: &[FnScope],
    out: &mut Vec<Finding>,
) {
    const MACROS: [&str; 4] = ["panic", "todo", "unimplemented", "unreachable"];
    let mut seen = vec![false; ctx.lines.len()];
    for scope in fns {
        for i in scope.start..=scope.end.min(ctx.lines.len().saturating_sub(1)) {
            if seen[i] || ctx.in_test[i] || ctx.allowed(i, Rule::Panic) {
                continue;
            }
            seen[i] = true;
            let code = &ctx.lines[i].code;
            for (start, id) in idents(code) {
                let before = code[..start].trim_end().chars().next_back();
                let after = code[start + id.len()..].trim_start().chars().next();
                if (id == "unwrap" || id == "expect") && before == Some('.') && after == Some('(')
                {
                    push(
                        out,
                        path,
                        i,
                        Rule::Panic,
                        format!(".{id}() in tick-reachable fn `{}`", scope.name),
                    );
                }
                if MACROS.contains(&id) && after == Some('!') {
                    push(
                        out,
                        path,
                        i,
                        Rule::Panic,
                        format!("{id}! in tick-reachable fn `{}`", scope.name),
                    );
                }
            }
        }
    }
}

/// Heap-allocating types whose constructors the `alloc` rule flags.
const ALLOC_TYPES: [&str; 8] = [
    "Vec", "VecDeque", "String", "Box", "HashMap", "HashSet", "BTreeMap", "BTreeSet",
];

/// Allocating constructors flagged on qualified form (`Vec::new(..)`).
const ALLOC_CTORS: [&str; 3] = ["new", "with_capacity", "from"];

/// Allocating method calls (`.collect()`, `.to_vec()`, ...).
const ALLOC_METHODS: [&str; 4] = ["to_vec", "to_owned", "to_string", "collect"];

/// Rule `alloc`: allocation constructs inside tick-reachable functions.
/// The paper's constant-per-token claim only survives serving if the
/// tick loop does constant work per token; a `Vec::new` or `format!` on
/// the tick path is a per-token heap round-trip the type system will
/// never surface. Flags: `vec![..]` / `format!(..)`, allocating
/// constructors on the container types, allocating method calls, and
/// growing `push`/`push_str` into locals declared with an empty
/// constructor in the same fn. Buffer *reuse* (`clear` + `resize`,
/// `extend_from_slice` into a caller-owned buffer) is deliberately not
/// flagged — that is the sanctioned fix.
pub(crate) fn check_alloc(ctx: &FileCtx, path: &str, fns: &[FnScope], out: &mut Vec<Finding>) {
    let mut seen = vec![false; ctx.lines.len()];
    for scope in fns {
        let hi = scope.end.min(ctx.lines.len().saturating_sub(1));
        // locals declared with an empty growable constructor in this fn
        let mut grow_locals: Vec<String> = Vec::new();
        for i in scope.start..=hi {
            let flat = despace(&ctx.lines[i].code);
            if let Some(pos) = flat.find("letmut") {
                let name: String = flat[pos + "letmut".len()..]
                    .chars()
                    .take_while(|&c| is_ident_char(c))
                    .collect();
                let rest = &flat[pos + "letmut".len() + name.len()..];
                let empty_ctor = rest.starts_with("=Vec::new()")
                    || rest.starts_with("=String::new()")
                    || rest.starts_with(":Vec<") && rest.contains("=Vec::new()")
                    || rest.starts_with(":String=String::new()");
                if !name.is_empty() && empty_ctor && !grow_locals.contains(&name) {
                    grow_locals.push(name);
                }
            }
        }
        for i in scope.start..=hi {
            if seen[i] || ctx.in_test[i] || ctx.allowed(i, Rule::Alloc) {
                continue;
            }
            seen[i] = true;
            let code = &ctx.lines[i].code;
            let flat = despace(code);
            for (start, id) in idents(code) {
                let before = code[..start].trim_end().chars().next_back();
                let after = code[start + id.len()..].trim_start().chars().next();
                if (id == "vec" || id == "format") && after == Some('!') && before != Some('.') {
                    push(
                        out,
                        path,
                        i,
                        Rule::Alloc,
                        format!("{id}! allocates in tick-reachable fn `{}`", scope.name),
                    );
                }
                // `(` directly, or a `::<..>(` turbofish as in
                // `.collect::<Vec<_>>()`
                if ALLOC_METHODS.contains(&id)
                    && before == Some('.')
                    && (after == Some('(') || after == Some(':'))
                {
                    push(
                        out,
                        path,
                        i,
                        Rule::Alloc,
                        format!(".{id}() allocates in tick-reachable fn `{}`", scope.name),
                    );
                }
            }
            for ty in ALLOC_TYPES {
                for ctor in ALLOC_CTORS {
                    if contains_bounded(&flat, &format!("{ty}::{ctor}(")) {
                        push(
                            out,
                            path,
                            i,
                            Rule::Alloc,
                            format!(
                                "{ty}::{ctor} allocates in tick-reachable fn `{}`",
                                scope.name
                            ),
                        );
                    }
                }
            }
            for name in &grow_locals {
                if flat.contains(&format!("{name}.push(")) || flat.contains(&format!("{name}.push_str("))
                {
                    push(
                        out,
                        path,
                        i,
                        Rule::Alloc,
                        format!(
                            "growing push into unreserved local `{name}` in tick-reachable fn `{}`",
                            scope.name
                        ),
                    );
                }
            }
        }
    }
}

fn push(out: &mut Vec<Finding>, path: &str, line0: usize, rule: Rule, message: String) {
    out.push(Finding {
        path: path.to_string(),
        line: line0 + 1,
        rule,
        message,
    });
}

/// Emit malformed-pragma findings (never suppressible: a pragma that
/// cannot be parsed cannot earn its own suppression).
pub(crate) fn check_pragmas(ctx: &FileCtx, path: &str, out: &mut Vec<Finding>) {
    for (line0, msg) in &ctx.bad_pragmas {
        push(out, path, *line0, Rule::Pragma, msg.clone());
    }
}
