//! Call graph + reachability over the items parsed by [`super::items`].
//!
//! The resolver is *conservative for reachability*: whenever the text
//! does not pin down a callee, every plausible in-crate target gets an
//! edge, so the computed hot/tick closures over-approximate — a
//! panicking or allocating helper can hide from a too-small set, never
//! from a too-big one. Concretely:
//!
//! * `Owner::name(..)` — fns whose impl owner is `Owner` (with `Self`
//!   rewritten to the caller's owner); failing that, fns whose module
//!   path ends in `Owner` (`engine_invariants::check_tick`); failing
//!   that the call is *unresolved-external* (`Vec::with_capacity`,
//!   `Instant::now`) and gets no edges but is tallied;
//! * `.name(..)` — every in-crate fn named `name` that takes a `self`
//!   receiver (the receiver's type is unknown to a line-level parser);
//! * `name(..)` — every in-crate fn named `name` without a receiver.
//!
//! `#[cfg(test)]` fns are excluded as both callers and callees: tests
//! deliberately panic and allocate, and nothing in serving reaches them.

use std::collections::{BTreeSet, HashMap};

use super::items::FnItem;

pub(crate) struct CallGraph {
    pub fns: Vec<FnItem>,
    /// Adjacency: caller index -> sorted, deduped callee indices.
    pub edges: Vec<Vec<usize>>,
    /// Call sites with no in-crate target (std/external or dynamic).
    pub unresolved_calls: usize,
}

impl CallGraph {
    pub fn build(fns: Vec<FnItem>) -> CallGraph {
        let mut by_name: HashMap<&str, Vec<usize>> = HashMap::new();
        for (i, f) in fns.iter().enumerate() {
            if !f.in_test {
                by_name.entry(f.name.as_str()).or_default().push(i);
            }
        }
        let mut edges: Vec<Vec<usize>> = vec![Vec::new(); fns.len()];
        let mut unresolved = 0usize;
        for (i, f) in fns.iter().enumerate() {
            if f.in_test {
                continue;
            }
            let mut out: BTreeSet<usize> = BTreeSet::new();
            for call in &f.calls {
                let cands: &[usize] = by_name
                    .get(call.name.as_str())
                    .map(|v| v.as_slice())
                    .unwrap_or(&[]);
                let mut hit = false;
                if let Some(q) = call.qualifier.as_deref() {
                    // `Self::helper(..)` means the enclosing impl's type
                    let q = if q == "Self" {
                        f.owner.as_deref().unwrap_or(q)
                    } else {
                        q
                    };
                    for &c in cands {
                        if fns[c].owner.as_deref() == Some(q) {
                            out.insert(c);
                            hit = true;
                        }
                    }
                    if !hit {
                        for &c in cands {
                            if module_ends_with(&fns[c].module, q) {
                                out.insert(c);
                                hit = true;
                            }
                        }
                    }
                } else if call.method {
                    for &c in cands {
                        if fns[c].takes_self {
                            out.insert(c);
                            hit = true;
                        }
                    }
                } else {
                    for &c in cands {
                        if !fns[c].takes_self {
                            out.insert(c);
                            hit = true;
                        }
                    }
                }
                if !hit {
                    unresolved += 1;
                }
            }
            out.remove(&i); // self-recursion adds nothing to reachability
            edges[i] = out.into_iter().collect();
        }
        CallGraph {
            fns,
            edges,
            unresolved_calls: unresolved,
        }
    }

    /// Indices of non-test fns defined in files matching `files`
    /// (suffix-tolerant, see [`super::path_matches`]).
    pub fn roots_in_files(&self, files: &[&str]) -> Vec<usize> {
        self.fns
            .iter()
            .enumerate()
            .filter(|(_, f)| !f.in_test && super::in_set(&f.file, files))
            .map(|(i, _)| i)
            .collect()
    }

    /// Indices of non-test fns with the given name.
    pub fn roots_named(&self, name: &str) -> Vec<usize> {
        self.fns
            .iter()
            .enumerate()
            .filter(|(_, f)| !f.in_test && f.name == name)
            .map(|(i, _)| i)
            .collect()
    }

    /// Transitive closure (roots included), as sorted fn indices.
    pub fn reachable(&self, roots: &[usize]) -> Vec<usize> {
        let mut seen: BTreeSet<usize> = roots.iter().copied().collect();
        let mut work: Vec<usize> = roots.to_vec();
        while let Some(i) = work.pop() {
            for &j in &self.edges[i] {
                if seen.insert(j) {
                    work.push(j);
                }
            }
        }
        seen.into_iter().collect()
    }
}

/// Does `module` end with path segment `seg` (`propcheck::engine_invariants`
/// ends with `engine_invariants`)?
fn module_ends_with(module: &str, seg: &str) -> bool {
    module == seg
        || module
            .rsplit("::")
            .next()
            .is_some_and(|last| last == seg)
}

#[cfg(test)]
mod tests {
    use super::super::items::parse_items;
    use super::super::rules::FileCtx;
    use super::*;

    fn graph(files: &[(&str, &str)]) -> CallGraph {
        let mut fns = Vec::new();
        for (path, src) in files {
            let ctx = FileCtx::build(src);
            fns.extend(parse_items(path, &ctx));
        }
        CallGraph::build(fns)
    }

    fn names_of(g: &CallGraph, idxs: &[usize]) -> Vec<String> {
        idxs.iter().map(|&i| g.fns[i].name.clone()).collect()
    }

    #[test]
    fn cross_module_qualified_calls_resolve() {
        let g = graph(&[
            (
                "rust/src/a.rs",
                "pub fn caller() {\n    helpers::assist();\n}\n",
            ),
            ("rust/src/helpers.rs", "pub fn assist() {}\n"),
        ]);
        let roots = g.roots_named("caller");
        let reach = g.reachable(&roots);
        assert!(names_of(&g, &reach).contains(&"assist".to_string()));
    }

    #[test]
    fn impl_method_ownership_disambiguates_qualified_calls() {
        let g = graph(&[(
            "rust/src/m.rs",
            "\
struct A;
struct B;
impl A {
    fn go(x: u32) { a_only(); }
}
impl B {
    fn go(x: u32) { b_only(); }
}
fn a_only() {}
fn b_only() {}
fn caller() { A::go(1); }
",
        )]);
        let reach = g.reachable(&g.roots_named("caller"));
        let names = names_of(&g, &reach);
        assert!(names.contains(&"a_only".to_string()));
        assert!(
            !names.contains(&"b_only".to_string()),
            "A::go must not resolve to B::go: {names:?}"
        );
    }

    #[test]
    fn shadowed_names_make_method_calls_conservative() {
        // two self-taking fns share a name; a method call reaches both
        let g = graph(&[(
            "rust/src/m.rs",
            "\
struct A;
struct B;
impl A {
    fn step(&mut self) { from_a(); }
}
impl B {
    fn step(&mut self) { from_b(); }
}
fn from_a() {}
fn from_b() {}
fn caller(x: &mut A) { x.step(); }
",
        )]);
        let reach = g.reachable(&g.roots_named("caller"));
        let names = names_of(&g, &reach);
        assert!(names.contains(&"from_a".to_string()));
        assert!(names.contains(&"from_b".to_string()));
    }

    #[test]
    fn method_calls_do_not_reach_receiverless_fns() {
        let g = graph(&[(
            "rust/src/m.rs",
            "\
fn push(out: &mut Vec<u32>, v: u32) { deep(); }
fn deep() {}
fn caller(v: &mut Vec<u32>) { v.push(1); }
",
        )]);
        let reach = g.reachable(&g.roots_named("caller"));
        assert!(
            !names_of(&g, &reach).contains(&"deep".to_string()),
            "Vec::push method call must not edge into the free fn `push`"
        );
    }

    #[test]
    fn unresolved_external_calls_are_tallied_not_edged() {
        let g = graph(&[(
            "rust/src/m.rs",
            "fn caller() {\n    let v: Vec<u32> = Vec::with_capacity(4);\n    std::mem::drop(v);\n}\n",
        )]);
        assert!(g.unresolved_calls >= 1, "Vec::with_capacity is external");
        let reach = g.reachable(&g.roots_named("caller"));
        assert_eq!(reach.len(), 1, "only the root itself: {:?}", names_of(&g, &reach));
    }

    #[test]
    fn self_qualified_calls_use_the_enclosing_owner() {
        let g = graph(&[(
            "rust/src/m.rs",
            "\
struct S;
impl S {
    fn new() -> S { Self::seed(); S }
    fn seed() {}
}
",
        )]);
        let reach = g.reachable(&g.roots_named("new"));
        assert!(names_of(&g, &reach).contains(&"seed".to_string()));
    }

    #[test]
    fn closure_bodies_keep_pool_dispatched_kernels_reachable() {
        let g = graph(&[(
            "rust/src/tensor.rs",
            "\
pub fn matmul(p: &Pool) {
    p.for_row_blocks(4, |row0, rows| {
        kernel_block(row0, rows);
    });
}
fn kernel_block(a: usize, b: usize) {}
",
        )]);
        let reach = g.reachable(&g.roots_named("matmul"));
        assert!(names_of(&g, &reach).contains(&"kernel_block".to_string()));
    }

    #[test]
    fn test_fns_are_neither_roots_nor_targets() {
        let g = graph(&[(
            "rust/src/m.rs",
            "\
fn prod() {}
#[cfg(test)]
mod tests {
    fn helper_with_unique_name() { prod(); }
}
",
        )]);
        assert!(g.roots_named("helper_with_unique_name").is_empty());
        let reach = g.reachable(&g.roots_named("prod"));
        assert_eq!(names_of(&g, &reach), vec!["prod".to_string()]);
    }
}
