//! Baseline diff-gating for `lintra analyze`.
//!
//! The `alloc` rule (and the interprocedural `panic` extension) land on
//! a codebase that already carries debt; failing CI on day one for all
//! of it would force either a hundred pragmas or turning the gate off.
//! Instead the known findings live in a committed `analysis_baseline.json`
//! and the gate fails only on *fresh* findings — the ratchet can then be
//! tightened entry by entry as debt is paid down.
//!
//! Entries are keyed by `(path, rule, message)` with a count — **no line
//! numbers** — so unrelated edits to a file do not invalidate the
//! baseline; messages carry the enclosing fn name, which keeps keys
//! stable and specific. Paths match suffix-tolerantly at `/` boundaries
//! (the committed file uses repo-relative paths; tests pass absolute
//! ones).
//!
//! The serialized form is deliberately one entry object per line so
//! ratchet commits show as clean per-entry diffs.

use crate::json::{obj, Json};

use super::{path_matches, Finding, Rule};

/// One baseline entry: up to `count` findings with this key are debt.
#[derive(Debug, Clone)]
pub struct BaselineEntry {
    pub path: String,
    pub rule: Rule,
    pub message: String,
    pub count: usize,
}

/// A committed set of known findings.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

/// Result of diffing current findings against a baseline.
#[derive(Debug, Clone, Default)]
pub struct BaselineDiff {
    /// Findings not covered by the baseline — these fail `--deny`.
    pub fresh: Vec<Finding>,
    /// How many findings the baseline absorbed.
    pub suppressed: usize,
    /// Baseline entries (rendered) whose findings no longer all exist:
    /// debt was paid down; the entry should be ratcheted.
    pub resolved: Vec<String>,
}

impl Baseline {
    /// Build a baseline that covers exactly the given findings.
    pub fn from_findings(findings: &[Finding]) -> Baseline {
        let mut counts: std::collections::BTreeMap<(String, Rule, String), usize> =
            Default::default();
        for f in findings {
            *counts
                .entry((f.path.clone(), f.rule, f.message.clone()))
                .or_insert(0) += 1;
        }
        Baseline {
            entries: counts
                .into_iter()
                .map(|((path, rule, message), count)| BaselineEntry {
                    path,
                    rule,
                    message,
                    count,
                })
                .collect(),
        }
    }

    /// Parse the committed JSON form. Unknown rule slugs are an error —
    /// a typo'd baseline entry would otherwise silently suppress
    /// nothing forever.
    pub fn parse(text: &str) -> anyhow::Result<Baseline> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("baseline: {e}"))?;
        let entries = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| anyhow::anyhow!("baseline: missing \"entries\" array"))?;
        let mut out = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let field = |k: &str| -> anyhow::Result<&str> {
                e.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| anyhow::anyhow!("baseline entry {i}: missing \"{k}\""))
            };
            let slug = field("rule")?;
            let rule = Rule::from_slug(slug)
                .ok_or_else(|| anyhow::anyhow!("baseline entry {i}: unknown rule {slug:?}"))?;
            out.push(BaselineEntry {
                path: field("path")?.to_string(),
                message: field("message")?.to_string(),
                rule,
                count: e
                    .get("count")
                    .and_then(|c| c.as_usize())
                    .ok_or_else(|| anyhow::anyhow!("baseline entry {i}: missing \"count\""))?,
            });
        }
        Ok(Baseline { entries: out })
    }

    /// Serialize: one entry object per line, entries sorted, so the
    /// committed file diffs cleanly.
    pub fn to_json(&self) -> String {
        let mut entries = self.entries.clone();
        entries.sort_by(|a, b| {
            (a.path.as_str(), a.rule, a.message.as_str())
                .cmp(&(b.path.as_str(), b.rule, b.message.as_str()))
        });
        let mut s = String::from("{\"version\": 1, \"entries\": [\n");
        for (i, e) in entries.iter().enumerate() {
            let line = obj(vec![
                ("path", Json::from(e.path.as_str())),
                ("rule", Json::from(e.rule.slug())),
                ("message", Json::from(e.message.as_str())),
                ("count", Json::from(e.count)),
            ])
            .to_string();
            s.push_str(&line);
            if i + 1 < entries.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("]}\n");
        s
    }

    /// Diff findings against this baseline. Findings are grouped by
    /// `(path, rule, message)`; each group draws down the matching
    /// entry's count (paths matched suffix-tolerantly) and anything
    /// beyond it is fresh. Groups are processed in finding order, so the
    /// fresh list points at the *last* occurrences — the ones most
    /// likely to be the newly added sites.
    pub fn diff(&self, findings: &[Finding]) -> BaselineDiff {
        let mut used: Vec<usize> = vec![0; self.entries.len()];
        // group indices of findings by key, preserving order
        let mut groups: std::collections::BTreeMap<(&str, Rule, &str), Vec<usize>> =
            Default::default();
        for (i, f) in findings.iter().enumerate() {
            groups
                .entry((f.path.as_str(), f.rule, f.message.as_str()))
                .or_default()
                .push(i);
        }
        let mut diff = BaselineDiff::default();
        for ((path, rule, message), idxs) in groups {
            let entry = self.entries.iter().position(|e| {
                e.rule == rule
                    && e.message == message
                    && (path_matches(path, &e.path) || path_matches(&e.path, path))
            });
            let allowed = match entry {
                Some(ei) => {
                    let remaining = self.entries[ei].count.saturating_sub(used[ei]);
                    let take = remaining.min(idxs.len());
                    used[ei] += take;
                    take
                }
                None => 0,
            };
            diff.suppressed += allowed;
            for &i in &idxs[allowed..] {
                diff.fresh.push(findings[i].clone());
            }
        }
        for (ei, e) in self.entries.iter().enumerate() {
            if used[ei] < e.count {
                diff.resolved.push(format!(
                    "{} [{}] {} ({} of {} remain)",
                    e.path,
                    e.rule.slug(),
                    e.message,
                    used[ei],
                    e.count
                ));
            }
        }
        diff.fresh.sort_by(|a, b| {
            (a.path.as_str(), a.line, a.rule, a.message.as_str())
                .cmp(&(b.path.as_str(), b.line, b.rule, b.message.as_str()))
        });
        diff
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(path: &str, line: usize, rule: Rule, message: &str) -> Finding {
        Finding {
            path: path.to_string(),
            line,
            rule,
            message: message.to_string(),
        }
    }

    #[test]
    fn roundtrip_through_json() {
        let fs = vec![
            finding("rust/src/a.rs", 3, Rule::Alloc, "vec! allocates in tick-reachable fn `f`"),
            finding("rust/src/a.rs", 9, Rule::Alloc, "vec! allocates in tick-reachable fn `f`"),
            finding("rust/src/b.rs", 1, Rule::Panic, ".unwrap() in tick-reachable fn `g`"),
        ];
        let b = Baseline::from_findings(&fs);
        let text = b.to_json();
        let b2 = Baseline::parse(&text).unwrap();
        assert_eq!(b2.entries.len(), 2);
        let d = b2.diff(&fs);
        assert!(d.fresh.is_empty());
        assert_eq!(d.suppressed, 3);
        assert!(d.resolved.is_empty());
    }

    #[test]
    fn one_entry_per_line() {
        let fs = vec![
            finding("a.rs", 1, Rule::Alloc, "m1"),
            finding("b.rs", 1, Rule::Alloc, "m2"),
        ];
        let text = Baseline::from_findings(&fs).to_json();
        let entry_lines = text.lines().filter(|l| l.contains("\"path\"")).count();
        assert_eq!(entry_lines, 2, "{text}");
    }

    #[test]
    fn fresh_findings_exceed_the_count() {
        let baseline = Baseline::from_findings(&[finding("a.rs", 1, Rule::Alloc, "m")]);
        let now = vec![
            finding("a.rs", 1, Rule::Alloc, "m"),
            finding("a.rs", 7, Rule::Alloc, "m"),
        ];
        let d = baseline.diff(&now);
        assert_eq!(d.suppressed, 1);
        assert_eq!(d.fresh.len(), 1);
        assert_eq!(d.fresh[0].line, 7, "the later occurrence is the fresh one");
    }

    #[test]
    fn line_moves_do_not_invalidate() {
        let baseline = Baseline::from_findings(&[finding("a.rs", 10, Rule::Alloc, "m")]);
        let d = baseline.diff(&[finding("a.rs", 99, Rule::Alloc, "m")]);
        assert!(d.fresh.is_empty());
        assert_eq!(d.suppressed, 1);
    }

    #[test]
    fn relative_baseline_matches_absolute_findings() {
        let baseline =
            Baseline::from_findings(&[finding("rust/src/nn/mod.rs", 1, Rule::Alloc, "m")]);
        let d = baseline.diff(&[finding("/root/repo/rust/src/nn/mod.rs", 5, Rule::Alloc, "m")]);
        assert!(d.fresh.is_empty(), "{:?}", d.fresh);
        // and a different mod.rs must NOT match
        let d2 = baseline.diff(&[finding("/root/repo/rust/src/analysis/mod.rs", 5, Rule::Alloc, "m")]);
        assert_eq!(d2.fresh.len(), 1);
    }

    #[test]
    fn resolved_entries_are_reported() {
        let baseline = Baseline::from_findings(&[
            finding("a.rs", 1, Rule::Alloc, "m"),
            finding("a.rs", 2, Rule::Alloc, "m"),
        ]);
        let d = baseline.diff(&[finding("a.rs", 1, Rule::Alloc, "m")]);
        assert!(d.fresh.is_empty());
        assert_eq!(d.resolved.len(), 1);
        assert!(d.resolved[0].contains("1 of 2"), "{:?}", d.resolved);
    }

    #[test]
    fn unknown_rule_slug_is_an_error() {
        let text = r#"{"version": 1, "entries": [
{"path":"a.rs","rule":"nope","message":"m","count":1}
]}"#;
        assert!(Baseline::parse(text).is_err());
    }
}
