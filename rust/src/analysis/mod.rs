//! `lintra analyze` — a repo-invariant static-analysis pass.
//!
//! Six PRs of engine growth rest on invariants that existed only as
//! prose: the serving worker must never panic, pooled kernels must stay
//! bitwise-identical to serial, every tunable resolves its env fallback
//! in exactly one place, and `unsafe` is only as sound as its written
//! justification. All of them are checkable by inspecting source text,
//! so this module checks them — a lightweight lexer ([`lexer`]) feeding
//! a line-oriented rule engine ([`rules`]), no external dependencies,
//! run by CI as a hard gate (`lintra analyze --deny rust/src examples`).
//!
//! ## Rules
//!
//! | rule     | scope                          | forbids |
//! |----------|--------------------------------|---------|
//! | `panic`  | serving hot-path files         | `.unwrap()`, `.expect()`, panicking macros, range/computed slice indexing |
//! | `bitwise`| fns tagged `bitwise-critical`  | `mul_add`, unordered containers, multiple scalar accumulators |
//! | `env`    | everywhere but config/parallel | `std::env::var` reads |
//! | `safety` | everywhere                     | `unsafe` without an immediately preceding `SAFETY:` comment |
//! | `lock`   | everywhere but parallel        | `.lock().unwrap()` / `.lock().expect()` |
//!
//! The hot-path file set for `panic` is the serving worker's transitive
//! tick loop: `coordinator/{engine,server,batcher,sessions,state_cache}.rs`
//! and `parallel.rs` (the dispatch path pooled kernels run on).
//!
//! Suppression: an inline comment `lintra: allow(<rule>) -- <reason>`
//! (reason mandatory — a bare allow is itself reported). `#[cfg(test)]`
//! regions are skipped entirely: the invariants guard production code,
//! and tests deliberately poison locks and index out of bounds.

pub mod lexer;
mod rules;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::Context;

use rules::FileCtx;

/// The rules `lintra analyze` enforces. `Pragma` is a meta-rule for
/// malformed suppressions and cannot itself be suppressed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Panicking constructs in serving hot-path files.
    Panic,
    /// Numeric-determinism hygiene in tagged kernels.
    Bitwise,
    /// `std::env::var` outside the config/parallel resolvers.
    Env,
    /// `unsafe` without a `SAFETY:` justification.
    Safety,
    /// `.lock().unwrap()` outside the approved wrapper.
    Lock,
    /// Malformed `lintra:` pragma.
    Pragma,
}

impl Rule {
    pub fn slug(self) -> &'static str {
        match self {
            Rule::Panic => "panic",
            Rule::Bitwise => "bitwise",
            Rule::Env => "env",
            Rule::Safety => "safety",
            Rule::Lock => "lock",
            Rule::Pragma => "pragma",
        }
    }

    pub fn from_slug(s: &str) -> Option<Rule> {
        Some(match s {
            "panic" => Rule::Panic,
            "bitwise" => Rule::Bitwise,
            "env" => Rule::Env,
            "safety" => Rule::Safety,
            "lock" => Rule::Lock,
            _ => return None,
        })
    }
}

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path,
            self.line,
            self.rule.slug(),
            self.message
        )
    }
}

/// Serving hot-path files: rule `panic` applies only to these. Matched
/// by path suffix at a `/` boundary, so `tensor.rs` (which has sized
/// asserts by design) is out while every file the engine tick loop can
/// reach is in.
const HOT_PATH_FILES: &[&str] = &[
    "coordinator/engine.rs",
    "coordinator/server.rs",
    "coordinator/batcher.rs",
    "coordinator/sessions.rs",
    "coordinator/state_cache.rs",
    "parallel.rs",
];

/// Files whose job is env resolution (rule `env` allowlist).
const ENV_FILES: &[&str] = &["config.rs", "parallel.rs"];

/// Home of the approved lock wrapper (rule `lock` allowlist).
const LOCK_FILES: &[&str] = &["parallel.rs"];

fn path_matches(path: &str, suffix: &str) -> bool {
    let p = path.replace('\\', "/");
    p == suffix || p.ends_with(&format!("/{suffix}"))
}

fn in_set(path: &str, set: &[&str]) -> bool {
    set.iter().any(|s| path_matches(path, s))
}

/// Analyze one file's source text. `path` determines which file-scoped
/// rules apply (hot-path, env allowlist, lock allowlist); findings carry
/// it verbatim.
pub fn analyze_source(path: &str, src: &str) -> Vec<Finding> {
    let ctx = FileCtx::build(src);
    let mut out = Vec::new();
    if in_set(path, HOT_PATH_FILES) {
        rules::check_panic(&ctx, path, &mut out);
    }
    rules::check_bitwise(&ctx, path, &mut out);
    if !in_set(path, ENV_FILES) {
        rules::check_env(&ctx, path, &mut out);
    }
    rules::check_safety(&ctx, path, &mut out);
    if !in_set(path, LOCK_FILES) {
        rules::check_lock(&ctx, path, &mut out);
    }
    rules::check_pragmas(&ctx, path, &mut out);
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Analyze every `.rs` file under the given paths (files or directories,
/// walked recursively in sorted order). Returns all findings sorted by
/// path and line.
pub fn analyze_paths<P: AsRef<Path>>(paths: &[P]) -> crate::Result<Vec<Finding>> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        collect_rs_files(p.as_ref(), &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut out = Vec::new();
    for f in &files {
        let src = std::fs::read_to_string(f)
            .with_context(|| format!("reading {}", f.display()))?;
        let name = f.to_string_lossy().replace('\\', "/");
        out.extend(analyze_source(&name, &src));
    }
    Ok(out)
}

fn collect_rs_files(p: &Path, out: &mut Vec<PathBuf>) -> crate::Result<()> {
    let meta = std::fs::metadata(p).with_context(|| format!("stat {}", p.display()))?;
    if meta.is_file() {
        if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(p)
        .with_context(|| format!("reading dir {}", p.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for e in entries {
        let name = e.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name == "target" || name.starts_with('.') {
            continue;
        }
        if e.is_dir() {
            collect_rs_files(&e, out)?;
        } else if e.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(e);
        }
    }
    Ok(())
}

/// Render findings for the CLI: one line per finding plus a summary.
pub fn report(findings: &[Finding]) -> String {
    let mut s = String::new();
    for f in findings {
        s.push_str(&f.to_string());
        s.push('\n');
    }
    let files: std::collections::BTreeSet<&str> =
        findings.iter().map(|f| f.path.as_str()).collect();
    s.push_str(&format!(
        "analyze: {} finding(s) in {} file(s)\n",
        findings.len(),
        files.len()
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hot_path_suffix_matching() {
        assert!(in_set("rust/src/coordinator/engine.rs", HOT_PATH_FILES));
        assert!(in_set("rust/src/parallel.rs", HOT_PATH_FILES));
        // suffix must sit at a path-component boundary
        assert!(!in_set("rust/src/data_parallel.rs", HOT_PATH_FILES));
        assert!(!in_set("rust/src/tensor.rs", HOT_PATH_FILES));
    }

    #[test]
    fn rule_slug_roundtrip() {
        for r in [Rule::Panic, Rule::Bitwise, Rule::Env, Rule::Safety, Rule::Lock] {
            assert_eq!(Rule::from_slug(r.slug()), Some(r));
        }
        assert_eq!(Rule::from_slug("pragma"), None, "meta-rule is not suppressible");
    }

    #[test]
    fn clean_file_has_no_findings() {
        let src = "fn main() {\n    let x = 1 + 2;\n    println!(\"{x}\");\n}\n";
        assert!(analyze_source("rust/src/coordinator/engine.rs", src).is_empty());
    }
}
