//! `lintra analyze` — a repo-invariant static-analysis pass.
//!
//! Seven PRs of engine growth rest on invariants that existed only as
//! prose: the serving worker must never panic, the tick loop must do
//! constant work per token (the paper's O(1) claim, operationalized),
//! pooled kernels must stay bitwise-identical to serial, every tunable
//! resolves its env fallback in exactly one place, and `unsafe` is only
//! as sound as its written justification. All of them are checkable by
//! inspecting source text, so this module checks them — a lightweight
//! lexer ([`lexer`]) feeding an item parser ([`items`]) and a call
//! graph ([`callgraph`]), driving a line-oriented rule engine
//! ([`rules`]); no external dependencies, run by CI as a hard gate
//! (`lintra analyze --deny --baseline analysis_baseline.json rust/src
//! examples`).
//!
//! ## Rules
//!
//! | rule     | scope                          | forbids |
//! |----------|--------------------------------|---------|
//! | `panic`  | serving files (full rule) + tick-reachable fns everywhere (no indexing heuristic) | `.unwrap()`, `.expect()`, panicking macros; in serving files also range/computed slice indexing |
//! | `alloc`  | tick-reachable fns             | `vec![..]`/`format!`, allocating constructors (`Vec::new`, `with_capacity`, …), `.collect()`/`.to_vec()`/…, growing `push` into unreserved locals |
//! | `bitwise`| fns tagged `bitwise-critical`  | `mul_add`, unordered containers, multiple scalar accumulators |
//! | `env`    | everywhere but config/parallel | `std::env::var` reads |
//! | `safety` | everywhere                     | `unsafe` without an immediately preceding `SAFETY:` comment |
//! | `lock`   | everywhere but parallel        | `.lock().unwrap()` / `.lock().expect()` |
//!
//! ## Reachability
//!
//! Two closures are computed over the call graph, both conservative
//! over-approximations (unresolvable calls fan out to every plausible
//! in-crate target; see [`callgraph`]):
//!
//! * the **hot** closure — everything reachable from any function
//!   defined in the serving file set ([`SERVING_FILES`]). By
//!   construction it is a superset of what the hand-maintained file
//!   list used to cover.
//! * the **tick** closure — everything reachable from `run_engine`,
//!   the engine worker's tick loop. A panic here kills the engine (the
//!   connection threads are individually panic-proofed, the worker is
//!   not), and an allocation here is per-token work; so the
//!   interprocedural `panic` extension and the `alloc` rule scope to
//!   this closure. This is how a panicking or allocating helper in
//!   `tensor.rs` or `nn/mod.rs`, invisible to a file list, becomes a
//!   finding.
//!
//! ## Baseline gating
//!
//! The `alloc` rule lands on a codebase with ~a hundred pre-existing
//! allocation sites, so findings diff against a committed baseline
//! ([`Baseline`], `analysis_baseline.json`): a finding matching a
//! baseline entry (by path/rule/message — line numbers excluded, so
//! unrelated edits don't invalidate it) is *suppressed debt*; anything
//! beyond the baseline is *fresh* and fails `--deny`. Fixing debt shows
//! up as *resolved* entries; regenerate with `--write-baseline` to
//! ratchet the file down.
//!
//! Suppression: an inline comment `lintra: allow(<rule>) -- <reason>`
//! (reason mandatory — a bare allow is itself reported). `#[cfg(test)]`
//! regions are skipped entirely: the invariants guard production code,
//! and tests deliberately poison locks, allocate, and index out of
//! bounds.

mod baseline;
mod callgraph;
mod items;
pub mod lexer;
mod rules;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::Context;

pub use baseline::{Baseline, BaselineDiff};
use rules::{FileCtx, FnScope};

/// The rules `lintra analyze` enforces. `Pragma` is a meta-rule for
/// malformed suppressions and cannot itself be suppressed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// Panicking constructs in serving files / tick-reachable fns.
    Panic,
    /// Heap allocation inside tick-reachable fns.
    Alloc,
    /// Numeric-determinism hygiene in tagged kernels.
    Bitwise,
    /// `std::env::var` outside the config/parallel resolvers.
    Env,
    /// `unsafe` without a `SAFETY:` justification.
    Safety,
    /// `.lock().unwrap()` outside the approved wrapper.
    Lock,
    /// Malformed `lintra:` pragma.
    Pragma,
}

impl Rule {
    pub fn slug(self) -> &'static str {
        match self {
            Rule::Panic => "panic",
            Rule::Alloc => "alloc",
            Rule::Bitwise => "bitwise",
            Rule::Env => "env",
            Rule::Safety => "safety",
            Rule::Lock => "lock",
            Rule::Pragma => "pragma",
        }
    }

    pub fn from_slug(s: &str) -> Option<Rule> {
        Some(match s {
            "panic" => Rule::Panic,
            "alloc" => Rule::Alloc,
            "bitwise" => Rule::Bitwise,
            "env" => Rule::Env,
            "safety" => Rule::Safety,
            "lock" => Rule::Lock,
            _ => return None,
        })
    }
}

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.path,
            self.line,
            self.rule.slug(),
            self.message
        )
    }
}

/// Serving files: the full `panic` rule (including the fallible-indexing
/// heuristic) applies file-wide here, and every function defined here
/// roots the hot closure. Matched by path suffix at a `/` boundary.
pub const SERVING_FILES: &[&str] = &[
    "coordinator/engine.rs",
    "coordinator/server.rs",
    "coordinator/batcher.rs",
    "coordinator/sessions.rs",
    "coordinator/state_cache.rs",
    "parallel.rs",
];

/// The function whose body is the engine tick loop; the tick closure is
/// everything reachable from fns with this name.
const TICK_ROOT: &str = "run_engine";

/// Files whose job is env resolution (rule `env` allowlist).
const ENV_FILES: &[&str] = &["config.rs", "parallel.rs"];

/// Home of the approved lock wrapper (rule `lock` allowlist).
const LOCK_FILES: &[&str] = &["parallel.rs"];

pub(crate) fn path_matches(path: &str, suffix: &str) -> bool {
    let p = path.replace('\\', "/");
    p == suffix || p.ends_with(&format!("/{suffix}"))
}

pub(crate) fn in_set(path: &str, set: &[&str]) -> bool {
    set.iter().any(|s| path_matches(path, s))
}

/// What the interprocedural pass computed: closure sizes and members,
/// for reporting and for tests pinning coverage.
#[derive(Debug, Clone)]
pub struct ScopeSummary {
    /// Total non-test `fn` items parsed.
    pub fn_count: usize,
    /// Hot closure (reachable from any serving-file fn): sorted
    /// `(file, fn name)` pairs.
    pub hot_fns: Vec<(String, String)>,
    /// Tick closure (reachable from `run_engine`): sorted pairs.
    pub tick_fns: Vec<(String, String)>,
    /// Call sites with no in-crate target (external or dynamic) —
    /// reported so a resolver regression is visible as a count swing.
    pub unresolved_calls: usize,
}

impl ScopeSummary {
    /// Is `(file, fn)` in the tick closure? Suffix-tolerant on the file.
    pub fn tick_contains(&self, file: &str, name: &str) -> bool {
        self.tick_fns
            .iter()
            .any(|(f, n)| n == name && (path_matches(f, file) || path_matches(file, f)))
    }

    /// Is `(file, fn)` in the hot closure? Suffix-tolerant on the file.
    pub fn hot_contains(&self, file: &str, name: &str) -> bool {
        self.hot_fns
            .iter()
            .any(|(f, n)| n == name && (path_matches(f, file) || path_matches(file, f)))
    }
}

/// Result of an analysis run: findings plus the computed scope.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub findings: Vec<Finding>,
    pub scope: ScopeSummary,
}

/// Analyze a set of files given as `(path, source)` pairs. The call
/// graph spans all of them, so cross-file reachability works exactly as
/// it does for an on-disk tree; tests use this to build multi-file
/// fixtures in memory.
pub fn analyze_sources(files: &[(String, String)]) -> Analysis {
    let mut ctxs: Vec<(&str, FileCtx)> = Vec::with_capacity(files.len());
    let mut all_items = Vec::new();
    for (path, src) in files {
        let ctx = FileCtx::build(src);
        all_items.extend(items::parse_items(path, &ctx));
        ctxs.push((path.as_str(), ctx));
    }
    let graph = callgraph::CallGraph::build(all_items);
    let hot = graph.reachable(&graph.roots_in_files(SERVING_FILES));
    let tick = graph.reachable(&graph.roots_named(TICK_ROOT));

    // tick-closure fn body ranges, grouped per file
    let mut tick_scopes: std::collections::HashMap<&str, Vec<FnScope<'_>>> =
        std::collections::HashMap::new();
    for &i in &tick {
        let f = &graph.fns[i];
        tick_scopes.entry(f.file.as_str()).or_default().push(FnScope {
            name: f.name.as_str(),
            start: f.span.0,
            end: f.span.1,
        });
    }

    let mut findings = Vec::new();
    for (path, ctx) in &ctxs {
        if in_set(path, SERVING_FILES) {
            rules::check_panic(ctx, path, &mut findings);
        } else if let Some(scopes) = tick_scopes.get(path) {
            rules::check_panic_reachable(ctx, path, scopes, &mut findings);
        }
        if let Some(scopes) = tick_scopes.get(path) {
            rules::check_alloc(ctx, path, scopes, &mut findings);
        }
        rules::check_bitwise(ctx, path, &mut findings);
        if !in_set(path, ENV_FILES) {
            rules::check_env(ctx, path, &mut findings);
        }
        rules::check_safety(ctx, path, &mut findings);
        if !in_set(path, LOCK_FILES) {
            rules::check_lock(ctx, path, &mut findings);
        }
        rules::check_pragmas(ctx, path, &mut findings);
    }
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule, a.message.as_str())
            .cmp(&(b.path.as_str(), b.line, b.rule, b.message.as_str()))
    });

    let pair = |i: &usize| {
        (
            graph.fns[*i].file.clone(),
            graph.fns[*i].name.clone(),
        )
    };
    let mut hot_fns: Vec<(String, String)> = hot.iter().map(pair).collect();
    let mut tick_fns: Vec<(String, String)> = tick.iter().map(pair).collect();
    hot_fns.sort();
    tick_fns.sort();
    Analysis {
        findings,
        scope: ScopeSummary {
            fn_count: graph.fns.iter().filter(|f| !f.in_test).count(),
            hot_fns,
            tick_fns,
            unresolved_calls: graph.unresolved_calls,
        },
    }
}

/// Analyze one file's source text (single-file view: reachability roots
/// only exist if this file itself defines them). Findings carry `path`
/// verbatim.
pub fn analyze_source(path: &str, src: &str) -> Vec<Finding> {
    analyze_sources(&[(path.to_string(), src.to_string())]).findings
}

/// Analyze every `.rs` file under the given paths (files or directories,
/// walked recursively in sorted order). The call graph spans the whole
/// set.
pub fn analyze_paths<P: AsRef<Path>>(paths: &[P]) -> crate::Result<Analysis> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        collect_rs_files(p.as_ref(), &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut sources: Vec<(String, String)> = Vec::with_capacity(files.len());
    for f in &files {
        let src = std::fs::read_to_string(f)
            .with_context(|| format!("reading {}", f.display()))?;
        let name = f.to_string_lossy().replace('\\', "/");
        sources.push((name, src));
    }
    Ok(analyze_sources(&sources))
}

fn collect_rs_files(p: &Path, out: &mut Vec<PathBuf>) -> crate::Result<()> {
    let meta = std::fs::metadata(p).with_context(|| format!("stat {}", p.display()))?;
    if meta.is_file() {
        if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(p)
        .with_context(|| format!("reading dir {}", p.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for e in entries {
        let name = e.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name == "target" || name.starts_with('.') {
            continue;
        }
        if e.is_dir() {
            collect_rs_files(&e, out)?;
        } else if e.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(e);
        }
    }
    Ok(())
}

/// Render an analysis for the CLI: one line per finding plus summary
/// lines (and the baseline verdict, when one was applied).
pub fn report(a: &Analysis, diff: Option<&BaselineDiff>) -> String {
    let mut s = String::new();
    let shown: Vec<&Finding> = match diff {
        Some(d) => d.fresh.iter().collect(),
        None => a.findings.iter().collect(),
    };
    for f in &shown {
        s.push_str(&f.to_string());
        s.push('\n');
    }
    let files: std::collections::BTreeSet<&str> =
        shown.iter().map(|f| f.path.as_str()).collect();
    s.push_str(&format!(
        "analyze: {} finding(s) in {} file(s)\n",
        shown.len(),
        files.len()
    ));
    s.push_str(&format!(
        "scope: {} fns; hot closure {} fns; tick closure {} fns; {} unresolved call sites\n",
        a.scope.fn_count,
        a.scope.hot_fns.len(),
        a.scope.tick_fns.len(),
        a.scope.unresolved_calls
    ));
    if let Some(d) = diff {
        s.push_str(&format!(
            "baseline: {} suppressed, {} fresh, {} resolved\n",
            d.suppressed,
            d.fresh.len(),
            d.resolved.len()
        ));
        for r in &d.resolved {
            s.push_str(&format!("baseline entry resolved (ratchet it down): {r}\n"));
        }
    }
    s
}

/// Render an analysis (plus optional baseline verdict) as JSON for
/// `--format json` / the CI artifact. Deterministic: object keys are
/// sorted (BTreeMap) and findings are pre-sorted.
pub fn to_json(a: &Analysis, diff: Option<&BaselineDiff>) -> String {
    use crate::json::{obj, Json};
    let findings: Vec<Json> = a
        .findings
        .iter()
        .map(|f| {
            obj(vec![
                ("path", Json::from(f.path.as_str())),
                ("line", Json::from(f.line)),
                ("rule", Json::from(f.rule.slug())),
                ("message", Json::from(f.message.as_str())),
            ])
        })
        .collect();
    let mut by_rule: std::collections::BTreeMap<String, Json> = Default::default();
    for f in &a.findings {
        let e = by_rule.entry(f.rule.slug().to_string()).or_insert(Json::Num(0.0));
        if let Json::Num(n) = e {
            *n += 1.0;
        }
    }
    let mut root = vec![
        ("findings", Json::Arr(findings)),
        (
            "summary",
            obj(vec![
                ("total", Json::from(a.findings.len())),
                ("by_rule", Json::Obj(by_rule)),
            ]),
        ),
        (
            "scope",
            obj(vec![
                ("fns", Json::from(a.scope.fn_count)),
                ("hot_fns", Json::from(a.scope.hot_fns.len())),
                ("tick_fns", Json::from(a.scope.tick_fns.len())),
                ("unresolved_calls", Json::from(a.scope.unresolved_calls)),
            ]),
        ),
    ];
    if let Some(d) = diff {
        root.push((
            "baseline",
            obj(vec![
                ("suppressed", Json::from(d.suppressed)),
                ("fresh", Json::from(d.fresh.len())),
                ("resolved", Json::from(d.resolved.len())),
            ]),
        ));
    }
    let mut s = obj(root).to_string();
    s.push('\n');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serving_suffix_matching() {
        assert!(in_set("rust/src/coordinator/engine.rs", SERVING_FILES));
        assert!(in_set("rust/src/parallel.rs", SERVING_FILES));
        // suffix must sit at a path-component boundary
        assert!(!in_set("rust/src/data_parallel.rs", SERVING_FILES));
        assert!(!in_set("rust/src/tensor.rs", SERVING_FILES));
    }

    #[test]
    fn rule_slug_roundtrip() {
        for r in [
            Rule::Panic,
            Rule::Alloc,
            Rule::Bitwise,
            Rule::Env,
            Rule::Safety,
            Rule::Lock,
        ] {
            assert_eq!(Rule::from_slug(r.slug()), Some(r));
        }
        assert_eq!(Rule::from_slug("pragma"), None, "meta-rule is not suppressible");
    }

    #[test]
    fn clean_file_has_no_findings() {
        let src = "fn main() {\n    let x = 1 + 2;\n    println!(\"{x}\");\n}\n";
        assert!(analyze_source("rust/src/coordinator/engine.rs", src).is_empty());
    }

    #[test]
    fn tick_reachable_helper_outside_serving_files_is_found() {
        // the PR 7 blind spot: a panicking, allocating helper in a
        // kernel file, called (transitively) from the tick loop
        let files = vec![
            (
                "rust/src/coordinator/engine.rs".to_string(),
                "pub fn run_engine() {\n    crate::tensor::helper(1);\n}\n".to_string(),
            ),
            (
                "rust/src/tensor.rs".to_string(),
                "pub fn helper(x: u32) {\n    let v = vec![0.0; 4];\n    v.first().unwrap();\n}\n"
                    .to_string(),
            ),
        ];
        let a = analyze_sources(&files);
        assert!(a.scope.tick_contains("tensor.rs", "helper"));
        assert!(a
            .findings
            .iter()
            .any(|f| f.rule == Rule::Panic && f.path.ends_with("tensor.rs")));
        assert!(a
            .findings
            .iter()
            .any(|f| f.rule == Rule::Alloc && f.path.ends_with("tensor.rs")));
    }

    #[test]
    fn unreachable_helper_gets_no_interprocedural_findings() {
        let files = vec![
            (
                "rust/src/coordinator/engine.rs".to_string(),
                "pub fn run_engine() {\n    let t = 1 + 1;\n}\n".to_string(),
            ),
            (
                "rust/src/tensor.rs".to_string(),
                "pub fn cold(x: u32) {\n    let v = vec![0.0; 4];\n    v.first().unwrap();\n}\n"
                    .to_string(),
            ),
        ];
        let a = analyze_sources(&files);
        assert!(!a.scope.tick_contains("tensor.rs", "cold"));
        assert!(a.findings.is_empty(), "{:?}", a.findings);
    }

    #[test]
    fn hot_closure_contains_every_serving_file_fn() {
        let files = vec![
            (
                "rust/src/coordinator/server.rs".to_string(),
                "pub fn handle_conn() {}\n".to_string(),
            ),
            (
                "rust/src/coordinator/engine.rs".to_string(),
                "pub fn run_engine() {}\n".to_string(),
            ),
        ];
        let a = analyze_sources(&files);
        assert!(a.scope.hot_contains("coordinator/server.rs", "handle_conn"));
        assert!(a.scope.hot_contains("coordinator/engine.rs", "run_engine"));
        // tick closure is the narrower set
        assert!(!a.scope.tick_contains("coordinator/server.rs", "handle_conn"));
    }

    #[test]
    fn allow_alloc_pragma_suppresses() {
        let files = vec![(
            "rust/src/coordinator/engine.rs".to_string(),
            "pub fn run_engine() {\n    // lintra: allow(alloc) -- one-time setup\n    let v: Vec<u32> = Vec::new();\n}\n"
                .to_string(),
        )];
        let a = analyze_sources(&files);
        assert!(
            a.findings.iter().all(|f| f.rule != Rule::Alloc),
            "{:?}",
            a.findings
        );
    }

    #[test]
    fn json_rendering_is_parseable_and_counts_match() {
        let files = vec![(
            "rust/src/coordinator/engine.rs".to_string(),
            "pub fn run_engine() {\n    let v: Vec<u32> = Vec::new();\n}\n".to_string(),
        )];
        let a = analyze_sources(&files);
        assert_eq!(a.findings.len(), 1);
        let js = to_json(&a, None);
        let v = crate::json::Json::parse(&js).expect("analysis json must parse");
        assert_eq!(
            v.get("summary").unwrap().get("total").unwrap().as_usize(),
            Some(1)
        );
        assert_eq!(
            v.get("findings").unwrap().as_arr().unwrap().len(),
            1
        );
    }
}
