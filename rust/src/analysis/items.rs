//! Item-level parse on top of [`super::lexer`]: function definitions
//! (with body line spans and impl-block owners), inline `mod` blocks,
//! and call sites. This is the front half of the interprocedural pass —
//! [`super::callgraph`] turns every file's items into a symbol table and
//! a call graph, from which the hot-path and tick-loop closures are
//! computed.
//!
//! Like the lexer, this is deliberately a *lightweight* parser: it walks
//! the per-line code views (literals blanked, comments gone) with brace
//! tracking, so it cannot be fooled by strings or comments, but it does
//! not attempt full Rust syntax. The simplifications all lean the
//! conservative direction for reachability:
//!
//! * a call site is any identifier directly followed by `(` — plain
//!   calls (`helper(x)`), method calls (`.helper(x)`, receiver type
//!   unknown), and qualified calls (`Owner::helper(x)`) are kept apart
//!   so the resolver can be precise where the text allows and
//!   over-approximate where it does not (tuple-struct patterns like
//!   `State::Str(d)` also parse as calls; they resolve to nothing and
//!   only pad the unresolved tally);
//! * closures have no item identity — calls inside a closure body are
//!   attributed to the enclosing `fn`, which is exactly right for
//!   reachability (the pool dispatch in `parallel.rs` runs closure
//!   bodies on behalf of the calling kernel);
//! * macro invocations are not calls (`vec![..]`, `format!(..)` are
//!   handled textually by the rules that care about them).

use super::lexer::is_ident_char;
use super::rules::FileCtx;

/// One call site inside a function body.
#[derive(Debug, Clone)]
pub struct CallSite {
    /// 0-based line of the call.
    pub line: usize,
    /// Last path segment of the callee (`bar` for `Foo::bar(..)`).
    pub name: String,
    /// `Foo` for `Foo::bar(..)` / `a::Foo::bar(..)`; None for plain and
    /// method calls.
    pub qualifier: Option<String>,
    /// True for `.bar(..)` method-call form (receiver type unknown).
    pub method: bool,
}

/// One `fn` item: identity, body span, and every call site inside it.
#[derive(Debug, Clone)]
pub struct FnItem {
    pub name: String,
    /// Self type of the enclosing `impl` block, if any.
    pub owner: Option<String>,
    /// Module path: derived from the file path, extended by inline
    /// `mod` blocks (e.g. `propcheck::engine_invariants`).
    pub module: String,
    /// The file the item was parsed from (as handed to the analyzer).
    pub file: String,
    /// Inclusive 0-based line span, signature line through closing brace.
    pub span: (usize, usize),
    /// Defined inside a `#[cfg(test)]` region.
    pub in_test: bool,
    /// The parameter list contains a `self` receiver. Used by the
    /// resolver: `.name(..)` method calls can only dispatch to fns
    /// *with* a receiver, plain `name(..)` calls only to fns *without*
    /// one — without this split, every `.push(..)` on a Vec would edge
    /// into any free fn that happens to be named `push`.
    pub takes_self: bool,
    pub calls: Vec<CallSite>,
}

/// Words that look like calls when followed by `(` but never are.
const NON_CALL_WORDS: &[&str] = &[
    "if", "while", "for", "match", "loop", "return", "fn", "impl", "struct", "enum", "trait",
    "use", "pub", "let", "mut", "ref", "in", "move", "as", "where", "unsafe", "else", "dyn",
    "Some", "None", "Ok", "Err",
];

/// Derive a module path from a file path: strip a leading `**/src/`,
/// drop the `.rs` suffix, fold `mod.rs`/`lib.rs`/`main.rs` into their
/// directory, join with `::`. Files outside a `src/` tree (tests,
/// examples) use their stem.
pub(crate) fn module_of(file: &str) -> String {
    let norm = file.replace('\\', "/");
    let rel = match norm.rfind("/src/") {
        Some(pos) => &norm[pos + "/src/".len()..],
        None => match norm.strip_prefix("src/") {
            Some(r) => r,
            None => norm.rsplit('/').next().unwrap_or(norm.as_str()),
        },
    };
    let rel = rel.strip_suffix(".rs").unwrap_or(rel);
    let mut parts: Vec<&str> = rel.split('/').filter(|p| !p.is_empty()).collect();
    if matches!(parts.last().copied(), Some("mod") | Some("lib") | Some("main")) {
        parts.pop();
    }
    parts.join("::")
}

/// A code-view token: an identifier (with its byte offset) or a single
/// non-whitespace punctuation character. Digit-led tokens (numeric
/// literals) are skipped, matching [`super::lexer::idents`].
enum Tok<'a> {
    Id { start: usize, text: &'a str },
    Ch { pos: usize, c: char },
}

fn toks(code: &str) -> Vec<Tok<'_>> {
    let bytes = code.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c.is_ascii_alphabetic() || c == '_' {
            let start = i;
            while i < bytes.len() && is_ident_char(bytes[i] as char) {
                i += 1;
            }
            out.push(Tok::Id { start, text: &code[start..i] });
        } else if c.is_ascii_digit() {
            while i < bytes.len() && is_ident_char(bytes[i] as char) {
                i += 1;
            }
        } else {
            if !c.is_whitespace() {
                out.push(Tok::Ch { pos: i, c });
            }
            i += 1;
        }
    }
    out
}

/// Which multi-line header construct the walk is inside, if any. The
/// header's working data lives in [`Parser`] fields — keeping the enum
/// data-free keeps every state transition a plain assignment.
#[derive(PartialEq)]
enum Mode {
    Normal,
    /// After `fn`, before its body `{` or a declaration-ending `;`.
    FnHeader,
    /// After `impl`, before the block `{`.
    ImplHeader,
    /// After `mod`, before `{` (inline) or `;` (out-of-line).
    ModHeader,
}

struct Parser {
    base_module: String,
    file: String,
    items: Vec<FnItem>,
    depth: i32,
    /// (depth the block body lives at, owner type) — innermost wins.
    impl_stack: Vec<(i32, Option<String>)>,
    /// (depth the block body lives at, mod name).
    mod_stack: Vec<(i32, String)>,
    /// (depth the body lives at, index into `items`).
    fn_stack: Vec<(i32, usize)>,
    mode: Mode,
    // FnHeader working data
    fn_name: Option<String>,
    fn_sig_line: usize,
    /// Paren/bracket depth inside the header, so `;` in `[u8; 4]` or a
    /// nested fn-pointer parameter does not end it early.
    fn_pb: i32,
    fn_takes_self: bool,
    // ImplHeader working data
    impl_owner: Option<String>,
    impl_angle: i32,
    /// Set once `where` is seen: the self type is settled.
    impl_done: bool,
    // ModHeader working data
    mod_name: Option<String>,
}

impl Parser {
    fn module_here(&self) -> String {
        let mut module = self.base_module.clone();
        for (_, m) in &self.mod_stack {
            if !module.is_empty() {
                module.push_str("::");
            }
            module.push_str(m);
        }
        module
    }

    /// A `{` opened a fn body: record the item and push it on the stack.
    fn open_fn(&mut self, lineno: usize, in_test: bool) {
        self.depth += 1;
        let name = self.fn_name.take().unwrap_or_else(|| "<fn>".to_string());
        self.items.push(FnItem {
            name,
            owner: self.impl_stack.last().and_then(|(_, o)| o.clone()),
            module: self.module_here(),
            file: self.file.clone(),
            span: (self.fn_sig_line, lineno),
            in_test,
            takes_self: self.fn_takes_self,
            calls: Vec::new(),
        });
        self.fn_stack.push((self.depth, self.items.len() - 1));
        self.mode = Mode::Normal;
    }

    /// A `}` in Normal mode: close whichever blocks live at this depth.
    fn close_brace(&mut self, lineno: usize) {
        if let Some(&(d, fn_idx)) = self.fn_stack.last() {
            if d == self.depth {
                self.items[fn_idx].span.1 = lineno;
                self.fn_stack.pop();
            }
        }
        if self.impl_stack.last().map(|&(d, _)| d) == Some(self.depth) {
            self.impl_stack.pop();
        }
        if self.mod_stack.last().map(|&(d, _)| d) == Some(self.depth) {
            self.mod_stack.pop();
        }
        self.depth -= 1;
    }
}

/// Parse the `fn` items of one file. `ctx` supplies the code views and
/// the `#[cfg(test)]` region map.
pub(crate) fn parse_items(file: &str, ctx: &FileCtx) -> Vec<FnItem> {
    let mut p = Parser {
        base_module: module_of(file),
        file: file.to_string(),
        items: Vec::new(),
        depth: 0,
        impl_stack: Vec::new(),
        mod_stack: Vec::new(),
        fn_stack: Vec::new(),
        mode: Mode::Normal,
        fn_name: None,
        fn_sig_line: 0,
        fn_pb: 0,
        fn_takes_self: false,
        impl_owner: None,
        impl_angle: 0,
        impl_done: false,
        mod_name: None,
    };

    for (lineno, line) in ctx.lines.iter().enumerate() {
        let code = line.code.as_str();
        let bytes = code.as_bytes();
        for tok in toks(code) {
            match p.mode {
                Mode::Normal => match tok {
                    Tok::Id { start, text } => match text {
                        "fn" => {
                            p.mode = Mode::FnHeader;
                            p.fn_name = None;
                            p.fn_sig_line = lineno;
                            p.fn_pb = 0;
                            p.fn_takes_self = false;
                        }
                        "impl" => {
                            p.mode = Mode::ImplHeader;
                            p.impl_owner = None;
                            p.impl_angle = 0;
                            p.impl_done = false;
                        }
                        "mod" => {
                            p.mode = Mode::ModHeader;
                            p.mod_name = None;
                        }
                        _ => {
                            if let Some(&(_, fn_idx)) = p.fn_stack.last() {
                                if let Some(call) = call_at(code, start, text, lineno) {
                                    p.items[fn_idx].calls.push(call);
                                }
                            }
                        }
                    },
                    Tok::Ch { c: '{', .. } => p.depth += 1,
                    Tok::Ch { c: '}', .. } => p.close_brace(lineno),
                    Tok::Ch { .. } => {}
                },
                Mode::FnHeader => match tok {
                    Tok::Id { text, .. } => {
                        if p.fn_name.is_none() {
                            p.fn_name = Some(text.to_string());
                        } else if text == "self" && p.fn_pb >= 1 {
                            p.fn_takes_self = true;
                        }
                    }
                    Tok::Ch { c: '(', .. } | Tok::Ch { c: '[', .. } => p.fn_pb += 1,
                    Tok::Ch { c: ')', .. } | Tok::Ch { c: ']', .. } => p.fn_pb -= 1,
                    Tok::Ch { c: '{', .. } if p.fn_pb == 0 => {
                        p.open_fn(lineno, ctx.in_test[p.fn_sig_line]);
                    }
                    Tok::Ch { c: ';', .. } if p.fn_pb == 0 => {
                        // trait method declaration / extern fn: no body
                        p.mode = Mode::Normal;
                        p.fn_name = None;
                    }
                    Tok::Ch { c: '}', .. } if p.fn_pb == 0 => {
                        // not a real fn header (e.g. an `fn(..)` pointer
                        // type in a struct field): bail out and process
                        // the brace normally so depth stays balanced
                        p.mode = Mode::Normal;
                        p.fn_name = None;
                        p.close_brace(lineno);
                    }
                    Tok::Ch { .. } => {}
                },
                Mode::ImplHeader => match tok {
                    Tok::Id { start, text } => {
                        if text == "for" && p.impl_angle == 0 {
                            // `impl Trait for Type`: the type wins
                            p.impl_owner = None;
                        } else if text == "where" {
                            p.impl_done = true;
                        } else if p.impl_angle == 0
                            && !p.impl_done
                            && p.impl_owner.is_none()
                            && !(start > 0 && bytes[start - 1] == b'\'')
                            && !matches!(text, "dyn" | "mut" | "const" | "unsafe" | "crate")
                        {
                            p.impl_owner = Some(text.to_string());
                        }
                    }
                    Tok::Ch { c: '<', .. } => p.impl_angle += 1,
                    Tok::Ch { c: '>', pos } => {
                        // `->` only shows up in Fn-trait sugar; its `>` is
                        // not an angle closer
                        if !(pos > 0 && bytes[pos - 1] == b'-') {
                            p.impl_angle -= 1;
                        }
                    }
                    Tok::Ch { c: ':', .. } => {
                        if p.impl_angle == 0 && !p.impl_done {
                            // path-qualified self type (`impl a::b::Foo`):
                            // clear so the final segment wins
                            p.impl_owner = None;
                        }
                    }
                    Tok::Ch { c: '{', .. } => {
                        p.depth += 1;
                        let owner = p.impl_owner.take();
                        p.impl_stack.push((p.depth, owner));
                        p.mode = Mode::Normal;
                    }
                    Tok::Ch { .. } => {}
                },
                Mode::ModHeader => match tok {
                    Tok::Id { text, .. } => {
                        if p.mod_name.is_none() {
                            p.mod_name = Some(text.to_string());
                        }
                    }
                    Tok::Ch { c: '{', .. } => {
                        p.depth += 1;
                        let name = p.mod_name.take().unwrap_or_default();
                        p.mod_stack.push((p.depth, name));
                        p.mode = Mode::Normal;
                    }
                    Tok::Ch { c: ';', .. } => {
                        // out-of-line `mod x;` — that file carries it
                        p.mode = Mode::Normal;
                        p.mod_name = None;
                    }
                    Tok::Ch { .. } => {}
                },
            }
        }
    }
    p.items
}

/// Classify the identifier at `start` as a call site, if it is one: the
/// next non-space char must be `(` (or a `::<` turbofish leading to
/// one), and the word must not be a keyword.
fn call_at(code: &str, start: usize, word: &str, lineno: usize) -> Option<CallSite> {
    if NON_CALL_WORDS.contains(&word) {
        return None;
    }
    let after = code[start + word.len()..].trim_start();
    if !(after.starts_with('(') || after.starts_with("::<")) {
        return None;
    }
    let before = code[..start].trim_end();
    if before.ends_with('.') {
        return Some(CallSite {
            line: lineno,
            name: word.to_string(),
            qualifier: None,
            method: true,
        });
    }
    if before.ends_with('\'') {
        return None; // lifetime tick glued to the word: not a call
    }
    let qualifier = before.strip_suffix("::").and_then(|head| {
        // the path segment before `::` — an owner type or module name.
        // `<Foo as Trait>::bar(` leaves no ident here; the call then
        // resolves by bare name, the conservative over-approximation.
        let seg: String = head
            .trim_end()
            .chars()
            .rev()
            .take_while(|&c| is_ident_char(c))
            .collect::<String>()
            .chars()
            .rev()
            .collect();
        if seg.is_empty() || seg.chars().next().is_some_and(|c| c.is_ascii_digit()) {
            None
        } else {
            Some(seg)
        }
    });
    Some(CallSite {
        line: lineno,
        name: word.to_string(),
        qualifier,
        method: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn items_of(file: &str, src: &str) -> Vec<FnItem> {
        let ctx = FileCtx::build(src);
        parse_items(file, &ctx)
    }

    #[test]
    fn module_paths_from_file_paths() {
        assert_eq!(module_of("rust/src/coordinator/engine.rs"), "coordinator::engine");
        assert_eq!(module_of("rust/src/nn/mod.rs"), "nn");
        assert_eq!(module_of("rust/src/lib.rs"), "");
        assert_eq!(module_of("examples/perf_decode.rs"), "perf_decode");
        assert_eq!(module_of("src/tensor.rs"), "tensor");
    }

    #[test]
    fn fn_spans_and_owners() {
        let src = "\
struct Foo;
impl Foo {
    fn a(&self) {
        self.b();
    }
}
fn free() {
    Foo::a(&Foo);
}
";
        let items = items_of("x/src/m.rs", src);
        assert_eq!(items.len(), 2);
        assert_eq!(items[0].name, "a");
        assert_eq!(items[0].owner.as_deref(), Some("Foo"));
        assert_eq!(items[0].span, (2, 4));
        assert_eq!(items[1].name, "free");
        assert_eq!(items[1].owner, None);
        assert_eq!(items[1].span, (6, 8));
        assert_eq!(items[1].calls.len(), 1);
        assert_eq!(items[1].calls[0].qualifier.as_deref(), Some("Foo"));
    }

    #[test]
    fn impl_trait_for_type_owner_is_the_type() {
        let src = "\
impl<'m> Backend for Session<'m> {
    fn tick(&mut self) {}
}
impl<'m> Session<'m> {
    fn own(&self) {}
}
impl fmt::Display for Rule {
    fn fmt(&self) {}
}
";
        let items = items_of("x/src/m.rs", src);
        assert_eq!(items[0].owner.as_deref(), Some("Session"));
        assert_eq!(items[1].owner.as_deref(), Some("Session"));
        assert_eq!(items[2].owner.as_deref(), Some("Rule"));
    }

    #[test]
    fn generic_impl_owner_skips_type_params() {
        let items = items_of(
            "x/src/m.rs",
            "impl<T: Clone> Wrapper<T> {\n    fn get(&self) {}\n}\n",
        );
        assert_eq!(items[0].owner.as_deref(), Some("Wrapper"));
    }

    #[test]
    fn calls_inside_closures_attribute_to_the_enclosing_fn() {
        let src = "\
fn outer(p: &Pool) {
    p.dispatch(|blk| {
        inner(blk);
    });
}
";
        let items = items_of("x/src/m.rs", src);
        let names: Vec<&str> = items[0].calls.iter().map(|c| c.name.as_str()).collect();
        assert!(names.contains(&"dispatch"));
        assert!(names.contains(&"inner"));
    }

    #[test]
    fn trait_declarations_without_bodies_are_not_items() {
        let src = "\
trait B {
    fn vocab(&self) -> usize;
    fn step(&mut self, buf: &mut [u8; 4]) -> Result<(), E>;
    fn with_default(&self) -> usize {
        self.vocab()
    }
}
";
        let items = items_of("x/src/m.rs", src);
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].name, "with_default");
        assert_eq!(items[0].span, (3, 5));
    }

    #[test]
    fn inline_mod_blocks_extend_the_module_path() {
        let src = "\
pub mod inner {
    pub fn check() {}
}
pub fn outer_level() {}
";
        let items = items_of("x/src/propcheck.rs", src);
        assert_eq!(items[0].module, "propcheck::inner");
        assert_eq!(items[1].module, "propcheck");
    }

    #[test]
    fn macro_invocations_are_not_calls() {
        let src = "fn f() {\n    vec![0.0; 4];\n    format!(\"x\");\n    real(1);\n}\n";
        let items = items_of("x/src/m.rs", src);
        let names: Vec<&str> = items[0].calls.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["real"]);
    }

    #[test]
    fn test_region_fns_are_marked() {
        let src = "\
fn prod() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        prod();
    }
}
";
        let items = items_of("x/src/m.rs", src);
        assert!(!items[0].in_test);
        assert!(items[1].in_test, "fn t is inside cfg(test)");
    }

    #[test]
    fn method_and_qualified_calls_are_classified() {
        let src = "fn f(s: &S) {\n    s.go(1);\n    util::help();\n    plain();\n}\n";
        let items = items_of("x/src/m.rs", src);
        let calls = &items[0].calls;
        assert!(calls.iter().any(|c| c.name == "go" && c.method));
        assert!(calls
            .iter()
            .any(|c| c.name == "help" && c.qualifier.as_deref() == Some("util")));
        assert!(calls
            .iter()
            .any(|c| c.name == "plain" && !c.method && c.qualifier.is_none()));
    }

    #[test]
    fn self_receivers_are_detected() {
        let src = "\
impl S {
    fn method(&mut self, x: u32) {}
    fn assoc(x: u32) {}
}
fn free(out: &mut Vec<u32>) {}
";
        let items = items_of("x/src/m.rs", src);
        assert!(items[0].takes_self);
        assert!(!items[1].takes_self);
        assert!(!items[2].takes_self);
    }

    #[test]
    fn nested_fns_attribute_calls_to_the_innermost() {
        let src = "\
fn outer() {
    fn inner() {
        deep();
    }
    shallow();
}
";
        let items = items_of("x/src/m.rs", src);
        let outer = items.iter().find(|f| f.name == "outer").unwrap();
        let inner = items.iter().find(|f| f.name == "inner").unwrap();
        assert_eq!(outer.span, (0, 5));
        assert_eq!(inner.span, (1, 3));
        assert!(outer.calls.iter().any(|c| c.name == "shallow"));
        assert!(outer.calls.iter().all(|c| c.name != "deep"));
        assert!(inner.calls.iter().any(|c| c.name == "deep"));
    }

    #[test]
    fn multiline_signatures_and_match_patterns() {
        let src = "\
fn f(
    a: usize,
    cb: impl Fn(usize) -> bool,
) -> usize {
    match probe(a) {
        Some(x) => x,
        None => 0,
    }
}
";
        let items = items_of("x/src/m.rs", src);
        assert_eq!(items.len(), 1);
        assert_eq!(items[0].span, (0, 8));
        // `Some(x)` / `None` patterns are not calls; `probe(a)` is
        let names: Vec<&str> = items[0].calls.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["probe"]);
    }
}
