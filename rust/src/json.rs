//! Minimal JSON parser/serializer (serde is unavailable offline).
//!
//! Covers exactly what this repo needs: `artifacts/manifest.json`, config
//! files, and metrics dumps. Numbers are f64; no streaming; strict enough
//! to reject malformed documents, lenient about whitespace.

use std::collections::BTreeMap;


/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `[1, 2, 3]` -> `vec![1, 2, 3]` for shape lists.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- serialization ---------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = &self.b[self.pos..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let is_num_byte =
            |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if is_num_byte(c)) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// builder helpers
// ---------------------------------------------------------------------------

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

/// Build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse(r#""héllo → 世界""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", r#"{"a" 1}"#, "tru", "1 2", "", r#""unterminated"#] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"x"],"nested":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn usize_vec_for_shapes() {
        let v = Json::parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_usize_vec(), Some(vec![2, 3, 4]));
        assert_eq!(Json::parse(r#"[1, "x"]"#).unwrap().as_usize_vec(), None);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).expect("manifest must parse");
            assert!(m.get("artifacts").is_some());
            assert!(m.get("models").is_some());
        }
    }
}
