//! Deterministic PRNG substrate (crates.io `rand` is unavailable offline).
//!
//! [`Rng`] is Xoshiro256++ seeded through SplitMix64 — fast, high quality,
//! and reproducible across runs, which the experiment harnesses rely on
//! (every bench/workload takes an explicit seed).

/// Xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/sequential seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Independent child stream (for per-worker / per-layer seeding).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // take the top 24 bits for an unbiased f32 mantissa
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical needs positive mass");
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut mean = 0.0f64;
        for _ in 0..n {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            mean += x as f64;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        let var: f64 =
            xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let w = [0.0f32, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.4, "ratio={ratio}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::new(8);
        let p = r.permutation(50);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_decorrelate() {
        let mut a = Rng::new(9);
        let mut b = a.split();
        let xa: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }
}
