//! LTW1 tensor-bundle reader/writer — the rust half of `python/compile/ltw.py`.
//!
//! Format (little endian):
//! ```text
//! b"LTW1"
//! u32  n_tensors
//! per tensor:
//!   u32 name_len, name (utf-8)
//!   u8  dtype (0 = f32, 1 = i32, 2 = f16, 3 = bf16, 4 = int8+scales)
//!   u32 ndim, u32 dims[ndim]
//!   raw data
//! ```
//! Payload sizes per dtype: f32/i32 are 4 bytes per element, f16/bf16
//! are 2, int8 is `dims[0]` f32 row scales followed by 1 byte per
//! element (absmax-per-row quantization, `value ~= q * scale[row]`, see
//! [`crate::tensor::quantize_row_i8`]). Every dtype widens to f32 on
//! read — this loader only ever hands out f32 tensors; the serving path
//! re-packs them via [`crate::tensor::WeightMat`] (idempotent, so an
//! offline-cast bundle reproduces the in-memory cast bit-for-bit).
//!
//! Used for initial parameters from `make artifacts`, trainer checkpoints,
//! `lintra cast` output, and moving weights into the native [`crate::nn`]
//! models.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context};

use crate::tensor::{Tensor, WeightDtype};

const MAGIC: &[u8; 4] = b"LTW1";

/// One named tensor (f32 only at this level; i32 entries are converted).
#[derive(Clone, Debug)]
pub struct NamedTensor {
    pub name: String,
    pub tensor: Tensor,
}

/// An ordered weight bundle with name lookup.
#[derive(Clone, Debug, Default)]
pub struct WeightBundle {
    pub tensors: Vec<NamedTensor>,
    index: BTreeMap<String, usize>,
}

impl WeightBundle {
    pub fn new(tensors: Vec<NamedTensor>) -> Self {
        let index = tensors
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect();
        WeightBundle { tensors, index }
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i].tensor)
    }

    /// Panicking accessor for required parameters.
    pub fn req(&self, name: &str) -> &Tensor {
        self.get(name)
            .unwrap_or_else(|| panic!("missing parameter {name:?} in weight bundle"))
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.iter().map(|t| t.name.as_str())
    }

    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.tensor.numel()).sum()
    }

    // ---- I/O --------------------------------------------------------------

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading weight bundle {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_bytes(mut b: &[u8]) -> anyhow::Result<Self> {
        let mut magic = [0u8; 4];
        b.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad magic {:?} (not an LTW1 file)", magic);
        }
        let n = read_u32(&mut b)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u32(&mut b)? as usize;
            let mut name_bytes = vec![0u8; name_len];
            b.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes).context("tensor name not utf-8")?;
            let mut dt = [0u8; 1];
            b.read_exact(&mut dt)?;
            let ndim = read_u32(&mut b)? as usize;
            if ndim > 8 {
                bail!("{name}: implausible ndim {ndim}");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut b)? as usize);
            }
            let count: usize = dims.iter().product::<usize>().max(1);
            let data: Vec<f32> = match dt[0] {
                0 => {
                    let mut raw = vec![0u8; count * 4];
                    b.read_exact(&mut raw)?;
                    raw.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect()
                }
                1 => {
                    let mut raw = vec![0u8; count * 4];
                    b.read_exact(&mut raw)?;
                    raw.chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f32)
                        .collect()
                }
                2 => {
                    let mut raw = vec![0u8; count * 2];
                    b.read_exact(&mut raw)?;
                    raw.chunks_exact(2)
                        .map(|c| crate::tensor::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
                        .collect()
                }
                3 => {
                    let mut raw = vec![0u8; count * 2];
                    b.read_exact(&mut raw)?;
                    raw.chunks_exact(2)
                        .map(|c| crate::tensor::bf16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
                        .collect()
                }
                4 => {
                    let rows = dims.first().copied().unwrap_or(1).max(1);
                    if count % rows != 0 {
                        bail!("{name}: int8 rows {rows} do not divide {count} elements");
                    }
                    let cols = count / rows;
                    let mut sraw = vec![0u8; rows * 4];
                    b.read_exact(&mut sraw)?;
                    let scales: Vec<f32> = sraw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    let mut raw = vec![0u8; count];
                    b.read_exact(&mut raw)?;
                    raw.iter()
                        .enumerate()
                        .map(|(i, &q)| (q as i8) as f32 * scales[i / cols])
                        .collect()
                }
                d => bail!("{name}: unsupported dtype id {d}"),
            };
            let shape = if dims.is_empty() { vec![1] } else { dims };
            tensors.push(NamedTensor {
                name,
                tensor: Tensor::from_vec(&shape, data),
            });
        }
        Ok(WeightBundle::new(tensors))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        self.save_as(path, |_| WeightDtype::F32)
    }

    /// Write the bundle, choosing a storage precision per tensor. Every
    /// non-f32 tensor is quantized on the way out (`lintra cast` uses
    /// this with [`crate::nn::quantized_param`] so exactly the tensors
    /// the serving path would pack go narrow, and everything else —
    /// embeddings, norms, biases — stays f32).
    pub fn save_as(
        &self,
        path: impl AsRef<Path>,
        choose: impl Fn(&NamedTensor) -> WeightDtype,
    ) -> anyhow::Result<()> {
        let mut out: Vec<u8> = Vec::new();
        out.write_all(MAGIC)?;
        out.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for t in &self.tensors {
            out.write_all(&(t.name.len() as u32).to_le_bytes())?;
            out.write_all(t.name.as_bytes())?;
            let dtype = choose(t);
            let id: u8 = match dtype {
                WeightDtype::F32 => 0,
                WeightDtype::F16 => 2,
                WeightDtype::Bf16 => 3,
                WeightDtype::Int8 => 4,
            };
            out.write_all(&[id])?;
            out.write_all(&(t.tensor.shape.len() as u32).to_le_bytes())?;
            for &d in &t.tensor.shape {
                out.write_all(&(d as u32).to_le_bytes())?;
            }
            match dtype {
                WeightDtype::F32 => {
                    for &v in &t.tensor.data {
                        out.write_all(&v.to_le_bytes())?;
                    }
                }
                WeightDtype::F16 => {
                    for &v in &t.tensor.data {
                        out.write_all(&crate::tensor::f32_to_f16_bits(v).to_le_bytes())?;
                    }
                }
                WeightDtype::Bf16 => {
                    for &v in &t.tensor.data {
                        out.write_all(&crate::tensor::f32_to_bf16_bits(v).to_le_bytes())?;
                    }
                }
                WeightDtype::Int8 => {
                    let rows = t.tensor.shape.first().copied().unwrap_or(1).max(1);
                    let cols = t.tensor.numel() / rows;
                    let packed = crate::tensor::WeightMat::quantize(
                        &t.tensor.data,
                        rows,
                        cols,
                        WeightDtype::Int8,
                    );
                    if let crate::tensor::WeightMat::Int8 { packed, scales } = packed {
                        for &s in &scales {
                            out.write_all(&s.to_le_bytes())?;
                        }
                        let bytes: Vec<u8> = packed.iter().map(|&q| q as u8).collect();
                        out.write_all(&bytes)?;
                    }
                }
            }
        }
        std::fs::write(path.as_ref(), out)
            .with_context(|| format!("writing {}", path.as_ref().display()))?;
        Ok(())
    }
}

fn read_u32(b: &mut &[u8]) -> anyhow::Result<u32> {
    let mut buf = [0u8; 4];
    b.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn sample_bundle() -> WeightBundle {
        let mut rng = Rng::new(0);
        WeightBundle::new(vec![
            NamedTensor {
                name: "a.w".into(),
                tensor: Tensor::randn(&[3, 4], 1.0, &mut rng),
            },
            NamedTensor {
                name: "b.bias".into(),
                tensor: Tensor::randn(&[7], 1.0, &mut rng),
            },
        ])
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("ltw_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ltw");
        let bundle = sample_bundle();
        bundle.save(&path).unwrap();
        let back = WeightBundle::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.req("a.w"), &bundle.tensors[0].tensor);
        assert_eq!(back.req("b.bias"), &bundle.tensors[1].tensor);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(WeightBundle::from_bytes(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join(format!("ltw_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ltw");
        sample_bundle().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(WeightBundle::from_bytes(&bytes[..bytes.len() - 5]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn order_preserved_and_lookup_works() {
        let b = sample_bundle();
        let names: Vec<&str> = b.names().collect();
        assert_eq!(names, vec!["a.w", "b.bias"]);
        assert!(b.get("missing").is_none());
        assert_eq!(b.total_params(), 12 + 7);
    }

    #[test]
    fn low_precision_roundtrip_widens_to_quantized_values() {
        use crate::tensor::{
            bf16_bits_to_f32, f16_bits_to_f32, f32_to_bf16_bits, f32_to_f16_bits, WeightMat,
        };
        let dir = std::env::temp_dir().join(format!("ltw_lp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let bundle = sample_bundle();

        for (dtype, tag) in [(WeightDtype::F16, "f16"), (WeightDtype::Bf16, "bf16")] {
            let path = dir.join(format!("t_{tag}.ltw"));
            bundle.save_as(&path, |_| dtype).unwrap();
            let back = WeightBundle::load(&path).unwrap();
            for (orig, got) in bundle.tensors.iter().zip(&back.tensors) {
                assert_eq!(orig.tensor.shape, got.tensor.shape);
                for (&v, &w) in orig.tensor.data.iter().zip(&got.tensor.data) {
                    let want = match dtype {
                        WeightDtype::F16 => f16_bits_to_f32(f32_to_f16_bits(v)),
                        _ => bf16_bits_to_f32(f32_to_bf16_bits(v)),
                    };
                    assert_eq!(w.to_bits(), want.to_bits(), "{tag}: {v} widened wrong");
                }
            }
        }

        // int8: loaded values must equal dequantize(quantize(original))
        let path = dir.join("t_int8.ltw");
        bundle.save_as(&path, |_| WeightDtype::Int8).unwrap();
        let back = WeightBundle::load(&path).unwrap();
        for (orig, got) in bundle.tensors.iter().zip(&back.tensors) {
            let rows = orig.tensor.shape.first().copied().unwrap_or(1).max(1);
            let cols = orig.tensor.numel() / rows;
            let q = WeightMat::quantize(&orig.tensor.data, rows, cols, WeightDtype::Int8);
            let want = q.dequantize(cols);
            assert_eq!(got.tensor.data, want, "int8 widening mismatch for {}", orig.name);
        }

        // a mixed chooser keeps f32 tensors bit-exact alongside cast ones
        let path = dir.join("t_mixed.ltw");
        bundle
            .save_as(&path, |t| if t.name == "a.w" { WeightDtype::F16 } else { WeightDtype::F32 })
            .unwrap();
        let back = WeightBundle::load(&path).unwrap();
        assert_eq!(back.req("b.bias"), &bundle.tensors[1].tensor);
        assert_ne!(back.req("a.w"), &bundle.tensors[0].tensor);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn f16_cast_is_idempotent_across_save_load_cycles() {
        // cast -> load -> cast again must not move any value: the serving
        // path depends on this to make offline casts match in-memory ones
        let dir = std::env::temp_dir().join(format!("ltw_idem_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p1 = dir.join("c1.ltw");
        let p2 = dir.join("c2.ltw");
        let bundle = sample_bundle();
        bundle.save_as(&p1, |_| WeightDtype::F16).unwrap();
        let once = WeightBundle::load(&p1).unwrap();
        once.save_as(&p2, |_| WeightDtype::F16).unwrap();
        let twice = WeightBundle::load(&p2).unwrap();
        for (a, b) in once.tensors.iter().zip(&twice.tensors) {
            assert_eq!(a.tensor, b.tensor, "second f16 cast moved {}", a.name);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_python_written_bundles_if_present() {
        // cross-language check against aot.py's exports
        let path = concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/copy_linear_init.ltw"
        );
        if std::path::Path::new(path).exists() {
            let b = WeightBundle::load(path).unwrap();
            assert!(b.get("embed.tok").is_some());
            assert_eq!(b.req("embed.tok").shape, vec![13, 128]);
            assert!(b.total_params() > 100_000);
        }
    }
}
