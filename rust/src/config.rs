//! Typed configuration system.
//!
//! [`ModelConfig`] mirrors `python/compile/model.py::ModelConfig` and can be
//! parsed straight from the artifact manifest, so the rust engines always
//! agree with the lowered HLO about shapes. [`TrainConfig`] / [`ServeConfig`]
//! configure the trainer and the serving engine; both can be loaded from a
//! JSON file and overridden by CLI flags.
//!
//! # Example
//!
//! Engine configs are plain structs with validated invariants — build
//! them with struct-update syntax off the defaults:
//!
//! ```
//! use linear_transformer::config::ServeConfig;
//!
//! let cfg = ServeConfig {
//!     max_batch: 16,
//!     num_threads: 4,            // GEMM pool width (0 = auto)
//!     prefill_chunks_per_tick: 2, // bound admission work per tick
//!     ..Default::default()
//! };
//! assert!(cfg.validate().is_ok());
//! assert!(ServeConfig { max_batch: 0, ..Default::default() }.validate().is_err());
//! ```

use anyhow::{bail, Context};

use crate::json::Json;

/// Transformer hyper-parameters (must match the python side for a model key).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub max_len: usize,
    pub d_ff: usize,
    pub chunk: usize,
    pub causal: bool,
    pub lsh_rounds: usize,
    pub lsh_buckets: usize,
    pub lsh_chunk: usize,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        debug_assert_eq!(self.d_model % self.n_heads, 0);
        self.d_model / self.n_heads
    }

    /// The copy-task model from the synthetic experiments (§4.1).
    pub fn small_copy() -> Self {
        ModelConfig {
            vocab: 13,
            d_model: 128,
            n_heads: 4,
            n_layers: 4,
            max_len: 128,
            d_ff: 512,
            chunk: 16,
            causal: true,
            lsh_rounds: 1,
            lsh_buckets: 16,
            lsh_chunk: 32,
        }
    }

    /// The MNIST pixel model (§4.2.1, scaled: see DESIGN.md).
    pub fn mnist() -> Self {
        ModelConfig {
            vocab: 256,
            max_len: 784,
            lsh_buckets: 32,
            ..Self::small_copy()
        }
    }

    /// The CIFAR pixel model (§4.2.2, scaled).
    pub fn cifar() -> Self {
        ModelConfig {
            vocab: 256,
            max_len: 3072,
            ..Self::small_copy()
        }
    }

    /// Paper-scale MNIST config (8 layers, 8 heads, d=256) for reference.
    pub fn mnist_paper_scale() -> Self {
        ModelConfig {
            vocab: 256,
            d_model: 256,
            n_heads: 8,
            n_layers: 8,
            max_len: 784,
            d_ff: 1024,
            ..Self::small_copy()
        }
    }

    /// Parse from a manifest `models.<key>.config` object.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let grab = |k: &str| -> anyhow::Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .with_context(|| format!("config missing field {k:?}"))
        };
        Ok(ModelConfig {
            vocab: grab("vocab")?,
            d_model: grab("d_model")?,
            n_heads: grab("n_heads")?,
            n_layers: grab("n_layers")?,
            max_len: grab("max_len")?,
            d_ff: grab("d_ff")?,
            chunk: grab("chunk").unwrap_or(16),
            causal: j.get("causal").and_then(|v| v.as_bool()).unwrap_or(true),
            lsh_rounds: grab("lsh_rounds").unwrap_or(1),
            lsh_buckets: grab("lsh_buckets").unwrap_or(16),
            lsh_chunk: grab("lsh_chunk").unwrap_or(32),
        })
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.d_model % self.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        if self.causal && self.max_len % self.chunk != 0 {
            bail!("max_len {} not a multiple of chunk {}", self.max_len, self.chunk);
        }
        if self.lsh_buckets % 2 != 0 {
            bail!("lsh_buckets must be even (angular LSH)");
        }
        Ok(())
    }
}

/// Trainer configuration (Figure 2 / Figure 5 runs).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub task: String,
    pub variant: String,
    pub steps: usize,
    pub lr: f32,
    /// LR is divided by 10 after this step (paper: 1e-3 -> 1e-4 after 3000).
    pub lr_drop_step: Option<usize>,
    pub log_every: usize,
    pub eval_every: usize,
    pub seed: u64,
    pub out_csv: Option<String>,
    pub checkpoint: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            task: "copy".into(),
            variant: "linear".into(),
            steps: 400,
            lr: 1e-3,
            lr_drop_step: Some(3000),
            log_every: 10,
            eval_every: 0,
            seed: 0,
            out_csv: None,
            checkpoint: None,
        }
    }
}

impl TrainConfig {
    pub fn lr_at(&self, step: usize) -> f32 {
        match self.lr_drop_step {
            Some(drop) if step >= drop => self.lr * 0.1,
            _ => self.lr,
        }
    }
}

/// Serving engine configuration.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Maximum decode batch (requests fused into one RNN step).
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch before dispatching.
    pub max_wait_us: u64,
    /// Upper bound on concurrent sessions.
    pub max_sessions: usize,
    /// TCP bind address for the JSON-lines server ("" = in-process only).
    pub bind: String,
    pub temperature: f32,
    pub seed: u64,
    /// Worker threads for the GEMM pool the decode/prefill kernels run
    /// on. `0` = auto (`LINTRA_NUM_THREADS`, else one per core); `1` =
    /// pure serial. Results are bit-identical at any setting — threads
    /// only partition output rows, never reductions.
    pub num_threads: usize,
    /// How many prompt chunks (of the backend's prefill granularity —
    /// `nn::PREFILL_CHUNK` tokens for the native engine) a slot that is
    /// still ingesting its prompt may absorb per engine tick. This
    /// bounds admission-time work so resident decode lanes keep
    /// producing one token per tick while long prompts stream in; raise
    /// it to trade decode-tick latency for time-to-first-token. Logits
    /// are bit-identical at any setting, so greedy (temperature 0)
    /// outputs never depend on it; with temperature > 0 the worker's
    /// sampling RNG draws in schedule order, so sampled streams can
    /// differ (as they already do with batch composition). Must be
    /// >= 1; a huge value effectively restores
    /// whole-prompt-at-admission behavior.
    pub prefill_chunks_per_tick: usize,
    /// Global cap on prompt chunks ingested per engine tick across *all*
    /// admitting slots (`prefill_chunks_per_tick` stays the per-slot
    /// cap). `0` = unlimited. With K slots admitting simultaneously the
    /// per-slot cap alone still lets one tick absorb K chunks; a global
    /// budget of 1 bounds every tick to one chunk's latency no matter
    /// how many prompts are streaming in (slots past the budget simply
    /// resume on later ticks, earliest-admitted first). Like the
    /// per-slot knob this only reshapes latency: logits are
    /// bit-identical under any budget.
    pub prefill_chunk_budget: usize,
    /// Prefix-reuse state cache budget in MiB; `0` = off (the default —
    /// explicit values win, else the `LINTRA_STATE_CACHE_MB` environment
    /// variable is consulted, mirroring `num_threads` /
    /// `LINTRA_NUM_THREADS` resolution; see [`resolve_state_cache_mb`]).
    /// When on, the engine snapshots each prefilling lane's fixed-size
    /// recurrent state at prefill-chunk boundaries, keyed by the token
    /// prefix, and restores the longest cached prefix at admission —
    /// requests sharing a system prompt / few-shot template / chat
    /// history skip that prefix's prefill entirely, bit-identically.
    pub state_cache_mb: usize,
    /// Storage precision for the projection/FF/lm-head weight matrices.
    /// `None` = auto: the `LINTRA_WEIGHT_DTYPE` environment variable if
    /// set (`f32`/`f16`/`bf16`/`int8`), else f32 — see
    /// [`resolve_weight_dtype`]. f32 is the bitwise reference path;
    /// narrow dtypes halve/quarter the weight bytes each decode tick
    /// streams (the B=1 bottleneck) at a documented numeric tolerance
    /// (ARCHITECTURE.md §Weight storage & numeric contract).
    pub weight_dtype: Option<crate::tensor::WeightDtype>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_wait_us: 500,
            max_sessions: 256,
            bind: String::new(),
            temperature: 1.0,
            seed: 0,
            num_threads: 0,
            prefill_chunks_per_tick: 1,
            prefill_chunk_budget: 0,
            state_cache_mb: 0,
            weight_dtype: None,
        }
    }
}

/// Upper bound on an explicit `num_threads` request. Far above any real
/// core count; a typo like `--num-threads 500000` must fail validation
/// (surfaced synchronously at engine spawn) instead of panicking thread
/// creation inside the already-running worker.
/// `crate::parallel::resolve_threads` clamps every other path to this.
pub const MAX_NUM_THREADS: usize = 1024;

/// Upper bound on `max_wait_us` (one hour). The engine computes
/// `Instant + max_wait` for batch deadlines, which panics on overflow;
/// a bounded wait keeps that arithmetic safe and rejects nonsense like
/// `--max-wait-us 18446744073709551615` up front.
pub const MAX_WAIT_US_LIMIT: u64 = 3_600_000_000;

/// Upper bound on `state_cache_mb` (1 TiB). The engine multiplies by
/// 2^20 to get a byte budget; bounding the MiB count keeps that
/// arithmetic overflow-free and rejects typos up front.
pub const MAX_STATE_CACHE_MB: usize = 1 << 20;

/// Resolve the state-cache size: an explicit `state_cache_mb >= 1` wins;
/// `0` consults `LINTRA_STATE_CACHE_MB` (a positive integer enables the
/// cache at that many MiB — how CI exercises the cached path without
/// touching every config literal), else the cache stays off. Mirrors
/// [`crate::parallel::resolve_threads`]' `LINTRA_NUM_THREADS` handling;
/// every path is clamped to [`MAX_STATE_CACHE_MB`].
pub fn resolve_state_cache_mb(requested: usize) -> usize {
    if requested >= 1 {
        return requested.min(MAX_STATE_CACHE_MB);
    }
    if let Ok(v) = std::env::var("LINTRA_STATE_CACHE_MB") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n.min(MAX_STATE_CACHE_MB);
            }
        }
    }
    0
}

/// Resolve the weight storage precision: an explicit choice wins; `None`
/// consults `LINTRA_WEIGHT_DTYPE` (`f32`/`f16`/`bf16`/`int8`,
/// case-insensitive — how CI runs the whole suite on the widening
/// kernels without touching every config literal), else f32. Mirrors
/// [`resolve_state_cache_mb`] / `LINTRA_NUM_THREADS` resolution. An
/// unparseable environment value falls back to f32 rather than erroring:
/// dtype selection is a performance knob, never a correctness switch.
pub fn resolve_weight_dtype(
    requested: Option<crate::tensor::WeightDtype>,
) -> crate::tensor::WeightDtype {
    if let Some(d) = requested {
        return d;
    }
    if let Ok(v) = std::env::var("LINTRA_WEIGHT_DTYPE") {
        if let Some(d) = crate::tensor::WeightDtype::parse(&v) {
            return d;
        }
    }
    crate::tensor::WeightDtype::F32
}

/// Which attention formulation the serving engine decodes with — the
/// `--attention-backend` / `LINTRA_ATTENTION_BACKEND` knob. Resolution
/// happens at model construction (the backend IS the model's attention
/// kind; weights are shared, the decode recurrence differs), so
/// [`ServeConfig`] carries no field for it: by the time
/// `NativeEngine::spawn` runs, the choice is baked into
/// `TransformerLM::kind`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttentionBackend {
    /// Batched linear-RNN decode (the paper's contribution): fixed-size
    /// per-lane (S, Z) state, O(1) work and bytes per token.
    Linear,
    /// Batched softmax KV-cache decode: exact causal softmax attention
    /// over appended K/V rows, O(t) work per token at position t and
    /// O(N) state — the Tables 4/5 serving baseline.
    Softmax,
}

impl AttentionBackend {
    /// Parse a `--attention-backend` / `LINTRA_ATTENTION_BACKEND` value
    /// (case-insensitive). `None` for anything but `linear`/`softmax`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "linear" => Some(AttentionBackend::Linear),
            "softmax" => Some(AttentionBackend::Softmax),
            _ => None,
        }
    }

    /// The flag-facing name (`linear` / `softmax`).
    pub fn label(self) -> &'static str {
        match self {
            AttentionBackend::Linear => "linear",
            AttentionBackend::Softmax => "softmax",
        }
    }

    /// The [`crate::attention::AttentionKind`] to construct models with.
    pub fn kind(self) -> crate::attention::AttentionKind {
        match self {
            AttentionBackend::Linear => crate::attention::AttentionKind::Linear,
            AttentionBackend::Softmax => crate::attention::AttentionKind::Softmax,
        }
    }
}

/// Whether the kernel layer may use the SIMD microkernels selected by
/// runtime ISA detection — the `--simd` / `LINTRA_SIMD` knob. This is a
/// performance switch only: every SIMD kernel is bitwise-identical to
/// its scalar form by construction (see `ARCHITECTURE.md` §Kernel
/// dispatch & SIMD contract), so the setting can never change an output
/// bit — `Off` exists for benchmarking the scalar tier and for
/// debugging/CI coverage of the fallback path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Detect the ISA at startup and use the widest supported tier
    /// (AVX2+FMA+F16C today, scalar everywhere else). The default.
    Auto,
    /// Force the portable scalar kernels even where SIMD is available.
    Off,
}

impl SimdMode {
    /// Parse a `--simd` / `LINTRA_SIMD` value (case-insensitive).
    /// `auto`/`on`/`1` mean detect-and-use; `off`/`scalar`/`0` force the
    /// scalar tier. `None` for anything else.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "on" | "1" => Some(SimdMode::Auto),
            "off" | "scalar" | "0" => Some(SimdMode::Off),
            _ => None,
        }
    }

    /// The flag-facing name (`auto` / `off`).
    pub fn label(self) -> &'static str {
        match self {
            SimdMode::Auto => "auto",
            SimdMode::Off => "off",
        }
    }
}

/// Resolve the SIMD mode: an explicit choice (the `--simd` flag) wins;
/// `None` consults `LINTRA_SIMD` (`auto`/`on`/`1` vs `off`/`scalar`/`0`,
/// case-insensitive — how CI runs the whole suite on the scalar fallback
/// without touching every test literal), else auto. An unparseable
/// environment value falls back to auto, mirroring
/// [`resolve_weight_dtype`]: both tiers are bitwise-identical, so the
/// knob is never a correctness switch. Same single-file env-resolution
/// contract as the resolvers above (`lintra analyze` rule `env`).
pub fn resolve_simd(requested: Option<SimdMode>) -> SimdMode {
    if let Some(m) = requested {
        return m;
    }
    if let Ok(v) = std::env::var("LINTRA_SIMD") {
        if let Some(m) = SimdMode::parse(&v) {
            return m;
        }
    }
    SimdMode::Auto
}

/// Resolve the serving attention backend: an explicit choice (the
/// `--attention-backend` flag) wins; `None` consults
/// `LINTRA_ATTENTION_BACKEND` (`linear`/`softmax`, case-insensitive —
/// how CI replays the whole engine suite on the KV-cache path without
/// touching every test literal), else linear. An unparseable
/// environment value falls back to linear, mirroring
/// [`resolve_weight_dtype`]: both backends are exact implementations of
/// their formulation, and the tests that compare them pin their kinds
/// explicitly. Same single-file env-resolution contract as the
/// resolvers above (`lintra analyze` rule `env`).
pub fn resolve_attention_backend(requested: Option<AttentionBackend>) -> AttentionBackend {
    if let Some(b) = requested {
        return b;
    }
    if let Ok(v) = std::env::var("LINTRA_ATTENTION_BACKEND") {
        if let Some(b) = AttentionBackend::parse(&v) {
            return b;
        }
    }
    AttentionBackend::Linear
}

/// Resolve the propcheck case count: `PROPCHECK_CASES` overrides (soak
/// runs crank it up), else `default`. An unparseable value falls back to
/// the default — case count is a thoroughness knob, never a correctness
/// switch. Lives here (not in `propcheck.rs`) so every environment knob
/// resolves in one file, the invariant `lintra analyze` (rule `env`)
/// enforces.
pub fn resolve_propcheck_cases(default: usize) -> usize {
    std::env::var("PROPCHECK_CASES")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(default)
}

/// Resolve the benchmark quick mode: `BENCH_QUICK=1` shrinks benchkit
/// workloads to smoke-test size (how CI keeps the bench binaries honest
/// without paying full measurement runs). Any other value — or unset —
/// is the full run. Same single-file env-resolution contract as the
/// resolvers above.
pub fn resolve_bench_quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok_and(|v| v.trim() == "1")
}

impl ServeConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.max_batch == 0 {
            bail!("max_batch must be >= 1");
        }
        if self.max_sessions < self.max_batch {
            bail!("max_sessions must be >= max_batch");
        }
        if self.num_threads > MAX_NUM_THREADS {
            bail!("num_threads {} exceeds the limit {MAX_NUM_THREADS}", self.num_threads);
        }
        if self.max_wait_us > MAX_WAIT_US_LIMIT {
            bail!("max_wait_us {} exceeds the limit {MAX_WAIT_US_LIMIT}", self.max_wait_us);
        }
        if self.prefill_chunks_per_tick == 0 {
            bail!("prefill_chunks_per_tick must be >= 1 (a prefilling slot must make progress)");
        }
        // prefill_chunk_budget: every value is meaningful (0 = unlimited,
        // n >= 1 caps chunks per tick across all admitting slots) — the
        // per-slot cap above already guarantees progress, and a global
        // budget of 1 still ingests one chunk per tick
        if self.state_cache_mb > MAX_STATE_CACHE_MB {
            bail!("state_cache_mb {} exceeds the limit {MAX_STATE_CACHE_MB}", self.state_cache_mb);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for cfg in [
            ModelConfig::small_copy(),
            ModelConfig::mnist(),
            ModelConfig::cifar(),
            ModelConfig::mnist_paper_scale(),
        ] {
            cfg.validate().unwrap();
            assert_eq!(cfg.d_head() * cfg.n_heads, cfg.d_model);
        }
    }

    #[test]
    fn from_json_roundtrip() {
        let j = Json::parse(
            r#"{"vocab": 13, "d_model": 128, "n_heads": 4, "n_layers": 4,
                "max_len": 128, "d_ff": 512, "chunk": 16, "causal": true,
                "lsh_rounds": 1, "lsh_buckets": 16, "lsh_chunk": 32,
                "attention": "linear"}"#,
        )
        .unwrap();
        let cfg = ModelConfig::from_json(&j).unwrap();
        assert_eq!(cfg, ModelConfig::small_copy());
    }

    #[test]
    fn from_json_missing_field_errors() {
        let j = Json::parse(r#"{"vocab": 13}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }

    #[test]
    fn validation_catches_bad_heads() {
        let cfg = ModelConfig {
            n_heads: 5,
            ..ModelConfig::small_copy()
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn lr_schedule() {
        let tc = TrainConfig {
            lr: 1e-3,
            lr_drop_step: Some(100),
            ..Default::default()
        };
        assert_eq!(tc.lr_at(0), 1e-3);
        assert_eq!(tc.lr_at(99), 1e-3);
        assert!((tc.lr_at(100) - 1e-4).abs() < 1e-9);
    }

    #[test]
    fn serve_config_num_threads_settings_are_valid() {
        for n in [0usize, 1, 4, 64, MAX_NUM_THREADS] {
            let cfg = ServeConfig {
                num_threads: n,
                ..Default::default()
            };
            assert!(cfg.validate().is_ok(), "num_threads = {n} must validate");
        }
        let absurd = ServeConfig {
            num_threads: MAX_NUM_THREADS + 1,
            ..Default::default()
        };
        assert!(absurd.validate().is_err(), "an absurd num_threads must be rejected at spawn");
        let overflow_wait = ServeConfig {
            max_wait_us: u64::MAX,
            ..Default::default()
        };
        assert!(
            overflow_wait.validate().is_err(),
            "a max_wait_us that would overflow deadline arithmetic must be rejected"
        );
    }

    #[test]
    fn prefill_chunks_per_tick_must_be_positive() {
        assert_eq!(ServeConfig::default().prefill_chunks_per_tick, 1);
        for n in [1usize, 2, 64, usize::MAX] {
            let cfg = ServeConfig {
                prefill_chunks_per_tick: n,
                ..Default::default()
            };
            assert!(cfg.validate().is_ok(), "prefill_chunks_per_tick = {n} must validate");
        }
        let stuck = ServeConfig {
            prefill_chunks_per_tick: 0,
            ..Default::default()
        };
        assert!(stuck.validate().is_err(), "0 chunks/tick would never finish a prompt");
    }

    #[test]
    fn prefill_chunk_budget_accepts_zero_as_unlimited() {
        assert_eq!(ServeConfig::default().prefill_chunk_budget, 0, "default is unlimited");
        for n in [0usize, 1, 4, usize::MAX] {
            let cfg = ServeConfig {
                prefill_chunk_budget: n,
                ..Default::default()
            };
            assert!(cfg.validate().is_ok(), "prefill_chunk_budget = {n} must validate");
        }
    }

    #[test]
    fn state_cache_mb_validates_and_resolves() {
        assert_eq!(ServeConfig::default().state_cache_mb, 0, "cache defaults to off");
        for n in [0usize, 1, 64, MAX_STATE_CACHE_MB] {
            let cfg = ServeConfig {
                state_cache_mb: n,
                ..Default::default()
            };
            assert!(cfg.validate().is_ok(), "state_cache_mb = {n} must validate");
        }
        let absurd = ServeConfig {
            state_cache_mb: MAX_STATE_CACHE_MB + 1,
            ..Default::default()
        };
        assert!(absurd.validate().is_err(), "an absurd state_cache_mb must be rejected");
        // explicit values win and are clamped
        assert_eq!(resolve_state_cache_mb(64), 64);
        assert_eq!(resolve_state_cache_mb(usize::MAX), MAX_STATE_CACHE_MB);
        // 0 falls back to the environment (mirroring LINTRA_NUM_THREADS);
        // read the ambient value rather than mutating process env from a
        // parallel test — CI exports LINTRA_STATE_CACHE_MB=64 in one run
        // to steer exactly this path
        let ambient = std::env::var("LINTRA_STATE_CACHE_MB")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .map(|n| n.min(MAX_STATE_CACHE_MB))
            .unwrap_or(0);
        assert_eq!(resolve_state_cache_mb(0), ambient);
    }

    #[test]
    fn weight_dtype_resolves_explicit_then_env_then_f32() {
        use crate::tensor::WeightDtype;
        assert_eq!(ServeConfig::default().weight_dtype, None, "default is auto");
        // explicit choices always win
        for d in [WeightDtype::F32, WeightDtype::F16, WeightDtype::Bf16, WeightDtype::Int8] {
            assert_eq!(resolve_weight_dtype(Some(d)), d);
        }
        // None falls back to the environment (mirroring the state-cache
        // knob); read the ambient value rather than mutating process env
        // from a parallel test — CI exports LINTRA_WEIGHT_DTYPE=f16 in
        // one run to steer exactly this path
        let ambient = std::env::var("LINTRA_WEIGHT_DTYPE")
            .ok()
            .and_then(|v| WeightDtype::parse(&v))
            .unwrap_or(WeightDtype::F32);
        assert_eq!(resolve_weight_dtype(None), ambient);
        // a dtype never invalidates a config
        let cfg = ServeConfig {
            weight_dtype: Some(WeightDtype::Int8),
            ..Default::default()
        };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn attention_backend_resolves_explicit_then_env_then_linear() {
        // explicit choices always win
        for b in [AttentionBackend::Linear, AttentionBackend::Softmax] {
            assert_eq!(resolve_attention_backend(Some(b)), b);
            assert_eq!(AttentionBackend::parse(b.label()), Some(b));
        }
        assert_eq!(AttentionBackend::parse("SoftMax"), Some(AttentionBackend::Softmax));
        assert_eq!(AttentionBackend::parse("reformer"), None);
        assert_eq!(
            AttentionBackend::Linear.kind(),
            crate::attention::AttentionKind::Linear
        );
        assert_eq!(
            AttentionBackend::Softmax.kind(),
            crate::attention::AttentionKind::Softmax
        );
        // None falls back to the environment (mirroring the dtype knob);
        // read the ambient value rather than mutating process env from a
        // parallel test — CI exports LINTRA_ATTENTION_BACKEND=softmax in
        // one run to replay the whole suite on the KV-cache path
        let ambient = std::env::var("LINTRA_ATTENTION_BACKEND")
            .ok()
            .and_then(|v| AttentionBackend::parse(&v))
            .unwrap_or(AttentionBackend::Linear);
        assert_eq!(resolve_attention_backend(None), ambient);
    }

    #[test]
    fn simd_mode_resolves_explicit_then_env_then_auto() {
        // explicit choices always win
        for m in [SimdMode::Auto, SimdMode::Off] {
            assert_eq!(resolve_simd(Some(m)), m);
            assert_eq!(SimdMode::parse(m.label()), Some(m));
        }
        assert_eq!(SimdMode::parse("ON"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse("1"), Some(SimdMode::Auto));
        assert_eq!(SimdMode::parse(" scalar "), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("0"), Some(SimdMode::Off));
        assert_eq!(SimdMode::parse("avx512"), None);
        // None falls back to the environment (mirroring the dtype knob);
        // read the ambient value rather than mutating process env from a
        // parallel test — CI exports LINTRA_SIMD=0 in one run to cover
        // exactly this path (and the scalar fallback it forces)
        let ambient = std::env::var("LINTRA_SIMD")
            .ok()
            .and_then(|v| SimdMode::parse(&v))
            .unwrap_or(SimdMode::Auto);
        assert_eq!(resolve_simd(None), ambient);
    }

    #[test]
    fn serve_config_validation() {
        assert!(ServeConfig::default().validate().is_ok());
        assert!(ServeConfig {
            max_batch: 0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(ServeConfig {
            max_batch: 16,
            max_sessions: 4,
            ..Default::default()
        }
        .validate()
        .is_err());
    }
}
