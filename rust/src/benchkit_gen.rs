//! Generation-benchmark support: measure-or-extrapolate.
//!
//! The quadratic decode baselines (softmax recompute, LSH recompute) are
//! so slow at N = 784/3072 on one CPU core that measuring a full image per
//! iteration would take minutes-to-hours — the very point the paper makes.
//! Tables 1/2/5 therefore measure a prefix of decode steps inside a time
//! budget and, when the full sequence wasn't reached, extrapolate the
//! remaining steps with a least-squares quadratic fit of the per-step cost
//! (exact for the cost families here: O(1), O(t) and O(t²) per step).
//! Extrapolated rows are marked `~` in the emitted tables and EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// Outcome of measuring one sequence generation.
#[derive(Clone, Debug)]
pub struct GenMeasurement {
    /// total seconds for the full sequence (measured or extrapolated)
    pub total_secs: f64,
    /// how many steps were actually timed
    pub steps_measured: usize,
    /// full sequence length
    pub steps_total: usize,
    pub extrapolated: bool,
}

impl GenMeasurement {
    pub fn label(&self) -> &'static str {
        if self.extrapolated {
            "~"
        } else {
            ""
        }
    }
}

/// Run `step(t)` for t in 0..n_steps, stopping when `budget` is exhausted;
/// extrapolate the tail from a quadratic fit if stopped early.
pub fn measure_steps(
    n_steps: usize,
    budget: Duration,
    mut step: impl FnMut(usize),
) -> GenMeasurement {
    let mut times: Vec<f64> = Vec::with_capacity(n_steps.min(4096));
    let start = Instant::now();
    let mut done = 0;
    for t in 0..n_steps {
        let t0 = Instant::now();
        step(t);
        times.push(t0.elapsed().as_secs_f64());
        done = t + 1;
        // need at least a few samples for the fit
        if start.elapsed() > budget && done >= 16 {
            break;
        }
    }
    if done == n_steps {
        return GenMeasurement {
            total_secs: times.iter().sum(),
            steps_measured: done,
            steps_total: n_steps,
            extrapolated: false,
        };
    }
    let (c0, c1, c2) = quad_fit(&times);
    let total = poly_sum(c0, c1, c2, n_steps);
    GenMeasurement {
        total_secs: total.max(times.iter().sum()),
        steps_measured: done,
        steps_total: n_steps,
        extrapolated: true,
    }
}

/// Least-squares fit times[t] ~ c0 + c1 t + c2 t² (t = 0-based step index).
pub fn quad_fit(times: &[f64]) -> (f64, f64, f64) {
    let n = times.len() as f64;
    assert!(times.len() >= 3);
    // normal equations over the basis {1, t, t^2}
    let mut s = [0.0f64; 5]; // sum t^k, k = 0..4
    let mut b = [0.0f64; 3]; // sum y t^k, k = 0..2
    for (i, &y) in times.iter().enumerate() {
        let t = i as f64;
        let t2 = t * t;
        s[0] += 1.0;
        s[1] += t;
        s[2] += t2;
        s[3] += t2 * t;
        s[4] += t2 * t2;
        b[0] += y;
        b[1] += y * t;
        b[2] += y * t2;
    }
    let _ = n;
    // solve the 3x3 symmetric system with Cramer's rule
    let m = [
        [s[0], s[1], s[2]],
        [s[1], s[2], s[3]],
        [s[2], s[3], s[4]],
    ];
    let det = det3(&m);
    if det.abs() < 1e-18 {
        let mean = b[0] / s[0];
        return (mean, 0.0, 0.0);
    }
    let repl = |col: usize| {
        let mut mm = m;
        for r in 0..3 {
            mm[r][col] = b[r];
        }
        det3(&mm) / det
    };
    (repl(0), repl(1), repl(2))
}

fn det3(m: &[[f64; 3]; 3]) -> f64 {
    m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
        - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
        + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
}

/// Σ_{t=0}^{n-1} c0 + c1 t + c2 t²  (closed form).
pub fn poly_sum(c0: f64, c1: f64, c2: f64, n: usize) -> f64 {
    let nf = n as f64;
    let s1 = nf * (nf - 1.0) / 2.0;
    let s2 = (nf - 1.0) * nf * (2.0 * nf - 1.0) / 6.0;
    (c0 * nf + c1 * s1 + c2 * s2).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quad_fit_recovers_coefficients() {
        let times: Vec<f64> = (0..50)
            .map(|t| 2.0 + 0.5 * t as f64 + 0.01 * (t * t) as f64)
            .collect();
        let (c0, c1, c2) = quad_fit(&times);
        assert!((c0 - 2.0).abs() < 1e-6, "c0={c0}");
        assert!((c1 - 0.5).abs() < 1e-6, "c1={c1}");
        assert!((c2 - 0.01).abs() < 1e-8, "c2={c2}");
    }

    #[test]
    fn poly_sum_matches_direct_sum() {
        let (c0, c1, c2) = (1.0, 0.2, 0.03);
        let direct: f64 = (0..100)
            .map(|t| c0 + c1 * t as f64 + c2 * (t * t) as f64)
            .sum();
        assert!((poly_sum(c0, c1, c2, 100) - direct).abs() < 1e-9);
    }

    #[test]
    fn extrapolation_close_to_truth_for_quadratic_cost() {
        // Synthetic per-step cost c(t) = 50 + 0.05 t^2 microseconds: the
        // base cost is far above timer noise and the quadratic term is
        // clearly visible inside the measured prefix, so the fit must land
        // near the analytic total. (Numerical precision of the fit itself
        // is covered by quad_fit_recovers_coefficients; this test checks
        // the end-to-end measure->fit->extrapolate path.)
        let cost = |t: usize| 1e-6 * (50.0 + 0.05 * (t * t) as f64);
        let n = 300;
        let truth: f64 = (0..n).map(cost).sum();
        let m = measure_steps(n, Duration::from_millis(6), |t| {
            let dur = Duration::from_secs_f64(cost(t));
            let t0 = Instant::now();
            while t0.elapsed() < dur {
                std::hint::spin_loop();
            }
        });
        assert!(m.extrapolated);
        assert!(m.steps_measured >= 16);
        let rel = (m.total_secs - truth).abs() / truth;
        // generous bound: busy-wait overshoot and 1-core scheduling noise
        // inflate every sample a little, which compounds in the tail
        assert!(rel < 0.75, "extrapolation off by {:.1}%", rel * 100.0);
    }

    #[test]
    fn full_measurement_not_extrapolated() {
        let m = measure_steps(10, Duration::from_secs(5), |_| {});
        assert!(!m.extrapolated);
        assert_eq!(m.steps_measured, 10);
    }
}
