//! Kernel-dispatch tunables: every threshold that decides *which*
//! implementation of a kernel runs lives here, in one documented place.
//!
//! Three dispatch axes exist, and all of them are correctness-neutral by
//! construction — a threshold can only ever change speed, never a bit of
//! output:
//!
//! * **Serial vs pooled** (`PAR_*`): whether a kernel fans its output
//!   rows/columns out across the [`crate::parallel::ThreadPool`]. Pooled
//!   kernels partition *outputs only* (never a reduction), so
//!   pooled == serial bitwise at any thread count.
//! * **Scalar vs SIMD** (`SIMD_*`): whether the inner loops run the
//!   portable scalar form or the [`crate::simd`] microkernels selected by
//!   runtime ISA detection. Every SIMD kernel assigns one vector lane to
//!   one output element and accumulates in the exact scalar order
//!   (ascending k, separate mul-then-add), so SIMD == scalar bitwise.
//! * **Streaming vs packed** (`GEMM_PACK_*`): whether a multi-row GEMM
//!   against a packed [`crate::tensor::WeightMat`] first repacks each
//!   column panel into a contiguous widened scratch buffer. Packing is
//!   pure data movement (the per-element accumulation order is
//!   unchanged), so packed == unpacked bitwise.
//!
//! The values were chosen against the mnist serving geometry
//! (d_model 128, d_ff 512, vocab 256) — see EXPERIMENTS.md §Perf for the
//! methodology; they are compile-time constants on purpose (no env knob:
//! dispatch must stay deterministic for a given build and shape).

/// Mul-add count below which a pooled GEMM-shaped kernel stays serial:
/// one pool dispatch costs a few microseconds, so only real work fans
/// out.
pub const PAR_MIN_WORK: usize = 16 * 1024;

/// Element count below which pooled row-wise kernels (layer norm) stay
/// serial — cheaper per element than a GEMM row, so the bar is lower.
pub const PAR_MIN_ROW_ELEMS: usize = 2048;

/// Output width below which a B=1 GEMV is not worth a pool dispatch:
/// fewer columns than this can't amortize waking the workers.
pub const PAR_MIN_GEMV_COLS: usize = 64;

/// Column-tile width of the widening GEMV/GEMM kernels: 8 independent
/// accumulators keep the FMA pipeline busy while each individual
/// accumulator still sums in strict k order. Equal to the AVX2 f32 lane
/// count, so one tile is exactly one `ymm` accumulator register on the
/// SIMD path.
pub const NR: usize = 8;

/// Slice length below which the SIMD `axpy` dispatch stays scalar: a
/// vector body needs at least one full [`NR`]-lane step to do anything,
/// so shorter slices skip the tier check entirely and run the scalar
/// tail they would have run anyway.
pub const SIMD_MIN_LEN: usize = NR;

/// Row count at or above which a multi-row GEMM against a packed
/// [`crate::tensor::WeightMat`] switches to the cache-blocked packed
/// path: each k×[`NR`] column panel is widened once into contiguous
/// scratch and then reused by every row, amortizing the dtype conversion
/// m ways and turning the strided column-tile walk into sequential
/// loads. Below this, per-row streaming wins (packing would convert the
/// whole matrix for too few consumers).
pub const GEMM_PACK_MIN_ROWS: usize = 4;
