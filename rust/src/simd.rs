//! Runtime-dispatched SIMD microkernels under [`crate::tensor`].
//!
//! The decode hot path spends essentially all of its time in a handful
//! of GEMV/GEMM-shaped inner loops. This module gives each of them an
//! AVX2 form selected by *runtime* ISA detection (one binary serves
//! every x86-64, and every other architecture falls back to the portable
//! scalar form at compile time), without giving up the repo's bitwise
//! contract.
//!
//! ## The column-lane rule (why SIMD == scalar bitwise)
//!
//! Every vector kernel here assigns **one SIMD lane to one output
//! element**: a `ymm` register holds 8 *independent* accumulators for 8
//! adjacent output columns, `k` advances in ascending order, and each
//! step is a separate multiply then add (`_mm256_mul_ps` +
//! `_mm256_add_ps` — never FMA, which contracts the intermediate
//! rounding). Per output element the float-op sequence is therefore
//! *identical* to the serial scalar kernel — the same trick the pooled
//! kernels use with threads (partition outputs, never split a
//! reduction), applied to vector lanes. Consequently SIMD == scalar
//! BITWISE for f32, at any thread count.
//!
//! The widening loads are exact conversions (every f16/bf16/int8 value
//! is exactly representable in f32, and `_mm256_cvtph_ps` / the bf16
//! shift / `_mm256_cvtepi32_ps` produce exactly those values), so the
//! narrow-dtype kernels are *also* bitwise-identical to their scalar
//! widening counterparts — the `dtype_parity` tolerance envelopes bound
//! quantization error against f32 references, not tier-to-tier drift,
//! which is zero.
//!
//! Horizontal reductions (`dot`, the layer-norm statistics) stay scalar
//! on purpose: vectorizing a reduction would split its accumulator and
//! change the rounding order.
//!
//! ## Tiers and resolution
//!
//! Two tiers exist: `Scalar` (portable, always available) and `Avx2`
//! (requires AVX2 + FMA + F16C; FMA is *detected* as part of the tier so
//! the tier names one fixed feature set, but it is deliberately never
//! used in accumulation — see above). The active tier resolves once per
//! process from [`crate::config::resolve_simd`] (`--simd` flag >
//! `LINTRA_SIMD` env > auto-detect) on first kernel use, is cached in an
//! atomic, and can be overridden at any time with [`force_tier`] (tests
//! and `bench_gemm` use this to compare tiers inside one process — safe
//! precisely because tiers never disagree on results).
//!
//! ## SAFETY policy
//!
//! `unsafe` appears in exactly two shapes here, each with a `// SAFETY:`
//! justification (enforced repo-wide by `lintra analyze` rule `safety`):
//! `#[target_feature]` kernel definitions, whose contract is "caller
//! proved the features are available", and their single dispatch call
//! sites, which only run after [`avx2_supported`] returned true (the
//! `Avx2` tier cannot be stored otherwise — [`force_tier`] clamps).

use std::sync::atomic::{AtomicU8, Ordering};

use crate::config::SimdMode;
use crate::tunables::{NR, SIMD_MIN_LEN};

/// An instruction-set tier the kernels can dispatch to. Tiers are
/// performance levels, never behavior levels: every tier produces
/// bit-identical output (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IsaTier {
    /// Portable scalar loops — always available, the reference order.
    Scalar,
    /// AVX2 + FMA + F16C 8-wide kernels (x86-64 only, runtime-detected).
    Avx2,
}

impl IsaTier {
    /// Human-facing name, logged at serve startup and in bench JSON.
    pub fn label(self) -> &'static str {
        match self {
            IsaTier::Scalar => "scalar",
            IsaTier::Avx2 => "avx2",
        }
    }
}

const TIER_UNRESOLVED: u8 = 0;
const TIER_SCALAR: u8 = 1;
const TIER_AVX2: u8 = 2;

/// The resolved tier, cached process-wide. `0` = not yet resolved;
/// kernels resolve lazily on first use so library users (tests, the
/// engine) get the env-configured tier without an init call. Relaxed
/// ordering is sufficient: the value is a pure performance hint and
/// every tier computes identical results, so readers racing a
/// [`force_tier`] store merely pick one of two equivalent code paths.
static TIER: AtomicU8 = AtomicU8::new(TIER_UNRESOLVED);

/// Does this CPU support the `Avx2` tier (AVX2 + FMA + F16C)?
pub fn avx2_supported() -> bool {
    avx2::detect()
}

/// Resolve and cache the active tier from an explicit mode (the `--simd`
/// flag), falling back to `LINTRA_SIMD` then auto-detection — the
/// explicit > env > default chain lives in
/// [`crate::config::resolve_simd`]. Returns the tier actually selected.
pub fn configure(requested: Option<SimdMode>) -> IsaTier {
    let tier = match crate::config::resolve_simd(requested) {
        SimdMode::Off => IsaTier::Scalar,
        SimdMode::Auto => {
            if avx2_supported() {
                IsaTier::Avx2
            } else {
                IsaTier::Scalar
            }
        }
    };
    force_tier(tier)
}

/// Set the active tier directly, clamped to what the CPU supports
/// (requesting `Avx2` without hardware support selects `Scalar` — this
/// can never enable undetected instructions). Returns the tier actually
/// stored. Safe to call at any time from any thread: tiers are
/// bit-identical, so in-flight kernels finishing on the old tier are
/// indistinguishable from ones that flipped earlier.
pub fn force_tier(tier: IsaTier) -> IsaTier {
    let actual = match tier {
        IsaTier::Avx2 if avx2_supported() => IsaTier::Avx2,
        _ => IsaTier::Scalar,
    };
    let code = match actual {
        IsaTier::Scalar => TIER_SCALAR,
        IsaTier::Avx2 => TIER_AVX2,
    };
    TIER.store(code, Ordering::Relaxed);
    actual
}

/// The tier kernels dispatch on, resolving it on first use.
#[inline]
pub fn active_tier() -> IsaTier {
    match TIER.load(Ordering::Relaxed) {
        TIER_SCALAR => IsaTier::Scalar,
        TIER_AVX2 => IsaTier::Avx2,
        _ => configure(None),
    }
}

// ---------------------------------------------------------------------------
// axpy — the shared inner loop of every f32 kernel
// ---------------------------------------------------------------------------

/// `y += alpha * x`, dispatched to the active tier. This is the inner
/// loop of `vecmat_into` / `matmul_into` / `gemv_cols_f32` and the
/// batched attention kernels (`batched_outer_acc`, `batched_contract`),
/// so one dispatch point vectorizes the whole f32 family. Each element
/// is one accumulator updated with a separate mul-then-add in ascending
/// index order on every tier.
// lintra: bitwise-critical
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    if y.len() >= SIMD_MIN_LEN && avx2::try_axpy(y, alpha, x) {
        return;
    }
    axpy_scalar(y, alpha, x);
}

/// The portable reference form of [`axpy`].
// lintra: bitwise-critical
#[inline]
fn axpy_scalar(y: &mut [f32], alpha: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

// ---------------------------------------------------------------------------
// widening GEMV column-range kernels (f16 / bf16 / int8 weights)
// ---------------------------------------------------------------------------
//
// Each `try_gemv_cols_*` runs the AVX2 form when the active tier allows
// it and returns `true`; a `false` return means "not taken" and the
// caller (`tensor::gemv_cols_w`) falls back to the scalar widening
// kernel. This keeps exactly one scalar source of truth
// (`tensor::gemv_cols_widen`) and exactly one tier check per GEMV call.

/// AVX2 widening GEMV over an f16 column range:
/// `y[j] = sum_k x[k] * widen(bits[k, col0 + j])`. Returns `false` when
/// the active tier is scalar (caller falls back).
// lintra: bitwise-critical
#[inline]
pub fn try_gemv_cols_f16(
    y: &mut [f32],
    bits: &[u16],
    x: &[f32],
    k: usize,
    n: usize,
    col0: usize,
) -> bool {
    assert_eq!(x.len(), k);
    assert!(col0 + y.len() <= n);
    assert!(bits.len() >= k * n);
    avx2::try_gemv_cols_f16(y, bits, x, k, n, col0)
}

/// AVX2 widening GEMV over a bf16 column range — see
/// [`try_gemv_cols_f16`] for the contract.
// lintra: bitwise-critical
#[inline]
pub fn try_gemv_cols_bf16(
    y: &mut [f32],
    bits: &[u16],
    x: &[f32],
    k: usize,
    n: usize,
    col0: usize,
) -> bool {
    assert_eq!(x.len(), k);
    assert!(col0 + y.len() <= n);
    assert!(bits.len() >= k * n);
    avx2::try_gemv_cols_bf16(y, bits, x, k, n, col0)
}

/// AVX2 fused dequant-multiply GEMV over an int8 column range:
/// `y[j] = sum_k (x[k] * scales[k]) * (packed[k, col0 + j] as f32)`.
/// The per-row scale folds into the broadcast coefficient (one scalar
/// multiply per k, the exact expression the scalar kernel uses) and the
/// int8 payload widens in-register, so the dequantized matrix never
/// materializes. See [`try_gemv_cols_f16`] for the dispatch contract.
// lintra: bitwise-critical
#[inline]
pub fn try_gemv_cols_i8(
    y: &mut [f32],
    packed: &[i8],
    scales: &[f32],
    x: &[f32],
    k: usize,
    n: usize,
    col0: usize,
) -> bool {
    assert_eq!(x.len(), k);
    assert!(scales.len() >= k);
    assert!(col0 + y.len() <= n);
    assert!(packed.len() >= k * n);
    avx2::try_gemv_cols_i8(y, packed, scales, x, k, n, col0)
}

// ---------------------------------------------------------------------------
// packed-panel row kernels (cache-blocked GEMM, see tensor::matmul_into_w)
// ---------------------------------------------------------------------------

/// One output-row step of the packed GEMM: `out[0..NR] = sum_k
/// coeffs[k] * panel[k * NR ..][0..NR]` with the f32 path's `== 0.0`
/// coefficient skip. `panel` is a k×[`NR`] column panel already widened
/// to f32 (pure data movement), so every tier sees identical operand
/// values and accumulates them in identical (ascending-k, one
/// accumulator per column) order.
// lintra: bitwise-critical
#[inline]
pub fn panel_row_f32_skip(out: &mut [f32], coeffs: &[f32], panel: &[f32]) {
    assert_eq!(out.len(), NR);
    assert!(panel.len() >= coeffs.len() * NR);
    if avx2::try_panel_row_f32_skip(out, coeffs, panel) {
        return;
    }
    let mut acc = [0.0f32; NR];
    for (kk, &c) in coeffs.iter().enumerate() {
        if c == 0.0 {
            continue;
        }
        let p = &panel[kk * NR..kk * NR + NR];
        for t in 0..NR {
            acc[t] += c * p[t];
        }
    }
    out.copy_from_slice(&acc);
}

/// [`panel_row_f32_skip`] without the zero-skip — the widened-dtype form
/// (the scalar widening kernels are dense on purpose: the decode stream
/// almost never carries exact zeros, and a skip would cost a branch per
/// coefficient).
// lintra: bitwise-critical
#[inline]
pub fn panel_row_dense(out: &mut [f32], coeffs: &[f32], panel: &[f32]) {
    assert_eq!(out.len(), NR);
    assert!(panel.len() >= coeffs.len() * NR);
    if avx2::try_panel_row_dense(out, coeffs, panel) {
        return;
    }
    let mut acc = [0.0f32; NR];
    for (kk, &c) in coeffs.iter().enumerate() {
        let p = &panel[kk * NR..kk * NR + NR];
        for t in 0..NR {
            acc[t] += c * p[t];
        }
    }
    out.copy_from_slice(&acc);
}

/// The AVX2 kernel bodies. Everything ISA-specific lives behind this
/// item-level `cfg`, so non-x86-64 targets compile the stub twin below
/// and the public dispatchers above never mention an intrinsic.
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::{active_tier, IsaTier};
    use crate::tunables::NR;

    /// Runtime feature probe for the `Avx2` tier.
    pub(super) fn detect() -> bool {
        is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
            && is_x86_feature_detected!("f16c")
    }

    /// Dispatch gate shared by every `try_*` below: true only when the
    /// cached tier says the AVX2 kernels may run.
    #[inline]
    fn tier_is_avx2() -> bool {
        active_tier() == IsaTier::Avx2
    }

    // lintra: bitwise-critical
    #[inline]
    pub(super) fn try_axpy(y: &mut [f32], alpha: f32, x: &[f32]) -> bool {
        if !tier_is_avx2() {
            return false;
        }
        // SAFETY: the Avx2 tier is only ever stored after `detect()`
        // confirmed AVX2 on this CPU (`force_tier` clamps every path).
        unsafe { axpy_avx2(y, alpha, x) };
        true
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must guarantee AVX2 is available; every raw
    // load/store below is bounds-derived from the slice lengths.
    // lintra: bitwise-critical
    unsafe fn axpy_avx2(y: &mut [f32], alpha: f32, x: &[f32]) {
        let len = y.len().min(x.len());
        let a = _mm256_set1_ps(alpha);
        let mut i = 0;
        while i + NR <= len {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            // separate mul then add — never _mm256_fmadd_ps (bitwise rule)
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_mul_ps(a, xv)));
            i += NR;
        }
        while i < len {
            y[i] += alpha * x[i];
            i += 1;
        }
    }

    // lintra: bitwise-critical
    #[inline]
    pub(super) fn try_gemv_cols_f16(
        y: &mut [f32],
        bits: &[u16],
        x: &[f32],
        k: usize,
        n: usize,
        col0: usize,
    ) -> bool {
        if !tier_is_avx2() {
            return false;
        }
        // SAFETY: Avx2 tier implies detected AVX2+F16C (`force_tier`
        // clamps); the public wrapper asserted the slice bounds the raw
        // loads rely on.
        unsafe { gemv_cols_f16_avx2(y, bits, x, k, n, col0) };
        true
    }

    #[target_feature(enable = "avx2,f16c")]
    // SAFETY: caller must guarantee AVX2+F16C are available and that
    // `x.len() == k`, `col0 + y.len() <= n`, `bits.len() >= k * n` —
    // every raw load below stays inside `bits` by that arithmetic.
    // lintra: bitwise-critical
    unsafe fn gemv_cols_f16_avx2(
        y: &mut [f32],
        bits: &[u16],
        x: &[f32],
        k: usize,
        n: usize,
        col0: usize,
    ) {
        debug_assert_eq!(x.len(), k);
        let nc = y.len();
        let mut j = 0;
        while j + NR <= nc {
            let base = col0 + j;
            let mut acc = _mm256_setzero_ps();
            for (kk, &xv) in x.iter().enumerate() {
                let h = _mm_loadu_si128(bits.as_ptr().add(kk * n + base) as *const __m128i);
                // exact f16 -> f32 widening; one lane per output column
                let w = _mm256_cvtph_ps(h);
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(xv), w));
            }
            _mm256_storeu_ps(y.as_mut_ptr().add(j), acc);
            j += NR;
        }
        while j < nc {
            let col = col0 + j;
            let mut acc = 0.0f32;
            for (kk, &xv) in x.iter().enumerate() {
                acc += xv * crate::tensor::f16_bits_to_f32(bits[kk * n + col]);
            }
            y[j] = acc;
            j += 1;
        }
    }

    // lintra: bitwise-critical
    #[inline]
    pub(super) fn try_gemv_cols_bf16(
        y: &mut [f32],
        bits: &[u16],
        x: &[f32],
        k: usize,
        n: usize,
        col0: usize,
    ) -> bool {
        if !tier_is_avx2() {
            return false;
        }
        // SAFETY: Avx2 tier implies detected AVX2 (`force_tier` clamps);
        // the public wrapper asserted the slice bounds the raw loads
        // rely on.
        unsafe { gemv_cols_bf16_avx2(y, bits, x, k, n, col0) };
        true
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must guarantee AVX2 is available and that
    // `x.len() == k`, `col0 + y.len() <= n`, `bits.len() >= k * n` —
    // every raw load below stays inside `bits` by that arithmetic.
    // lintra: bitwise-critical
    unsafe fn gemv_cols_bf16_avx2(
        y: &mut [f32],
        bits: &[u16],
        x: &[f32],
        k: usize,
        n: usize,
        col0: usize,
    ) {
        debug_assert_eq!(x.len(), k);
        let nc = y.len();
        let mut j = 0;
        while j + NR <= nc {
            let base = col0 + j;
            let mut acc = _mm256_setzero_ps();
            for (kk, &xv) in x.iter().enumerate() {
                let h = _mm_loadu_si128(bits.as_ptr().add(kk * n + base) as *const __m128i);
                // exact bf16 -> f32 widening: zero-extend each u16 to u32
                // and shift into the high half (bf16 is the top 16 bits
                // of an f32)
                let w = _mm256_castsi256_ps(_mm256_slli_epi32(_mm256_cvtepu16_epi32(h), 16));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(xv), w));
            }
            _mm256_storeu_ps(y.as_mut_ptr().add(j), acc);
            j += NR;
        }
        while j < nc {
            let col = col0 + j;
            let mut acc = 0.0f32;
            for (kk, &xv) in x.iter().enumerate() {
                acc += xv * crate::tensor::bf16_bits_to_f32(bits[kk * n + col]);
            }
            y[j] = acc;
            j += 1;
        }
    }

    // lintra: bitwise-critical
    #[inline]
    pub(super) fn try_gemv_cols_i8(
        y: &mut [f32],
        packed: &[i8],
        scales: &[f32],
        x: &[f32],
        k: usize,
        n: usize,
        col0: usize,
    ) -> bool {
        if !tier_is_avx2() {
            return false;
        }
        // SAFETY: Avx2 tier implies detected AVX2 (`force_tier` clamps);
        // the public wrapper asserted the slice bounds the raw loads
        // rely on.
        unsafe { gemv_cols_i8_avx2(y, packed, scales, x, k, n, col0) };
        true
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must guarantee AVX2 is available and that
    // `x.len() == k`, `scales.len() >= k`, `col0 + y.len() <= n`,
    // `packed.len() >= k * n` — every raw load below stays inside
    // `packed` by that arithmetic.
    // lintra: bitwise-critical
    unsafe fn gemv_cols_i8_avx2(
        y: &mut [f32],
        packed: &[i8],
        scales: &[f32],
        x: &[f32],
        k: usize,
        n: usize,
        col0: usize,
    ) {
        debug_assert_eq!(x.len(), k);
        let nc = y.len();
        let mut j = 0;
        while j + NR <= nc {
            let base = col0 + j;
            let mut acc = _mm256_setzero_ps();
            for (kk, &xv) in x.iter().enumerate() {
                // same coefficient expression as the scalar kernel, so
                // the rounded f32 coefficient is identical
                let c = xv * scales[kk];
                let q = _mm_loadl_epi64(packed.as_ptr().add(kk * n + base) as *const __m128i);
                // exact int8 -> f32 widening: sign-extend to i32, convert
                let w = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(q));
                acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(c), w));
            }
            _mm256_storeu_ps(y.as_mut_ptr().add(j), acc);
            j += NR;
        }
        while j < nc {
            let col = col0 + j;
            let mut acc = 0.0f32;
            for (kk, &xv) in x.iter().enumerate() {
                acc += (xv * scales[kk]) * (packed[kk * n + col] as f32);
            }
            y[j] = acc;
            j += 1;
        }
    }

    // lintra: bitwise-critical
    #[inline]
    pub(super) fn try_panel_row_f32_skip(out: &mut [f32], coeffs: &[f32], panel: &[f32]) -> bool {
        if !tier_is_avx2() {
            return false;
        }
        // SAFETY: Avx2 tier implies detected AVX2 (`force_tier` clamps);
        // the public wrapper asserted `out.len() == NR` and
        // `panel.len() >= coeffs.len() * NR`.
        unsafe { panel_row_f32_skip_avx2(out, coeffs, panel) };
        true
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must guarantee AVX2 is available, `out.len() == NR`,
    // and `panel.len() >= coeffs.len() * NR` — the raw loads below stay
    // inside `panel` by that arithmetic.
    // lintra: bitwise-critical
    unsafe fn panel_row_f32_skip_avx2(out: &mut [f32], coeffs: &[f32], panel: &[f32]) {
        let mut acc = _mm256_setzero_ps();
        for (kk, &c) in coeffs.iter().enumerate() {
            if c == 0.0 {
                continue;
            }
            let p = _mm256_loadu_ps(panel.as_ptr().add(kk * NR));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(c), p));
        }
        _mm256_storeu_ps(out.as_mut_ptr(), acc);
    }

    // lintra: bitwise-critical
    #[inline]
    pub(super) fn try_panel_row_dense(out: &mut [f32], coeffs: &[f32], panel: &[f32]) -> bool {
        if !tier_is_avx2() {
            return false;
        }
        // SAFETY: Avx2 tier implies detected AVX2 (`force_tier` clamps);
        // the public wrapper asserted `out.len() == NR` and
        // `panel.len() >= coeffs.len() * NR`.
        unsafe { panel_row_dense_avx2(out, coeffs, panel) };
        true
    }

    #[target_feature(enable = "avx2")]
    // SAFETY: caller must guarantee AVX2 is available, `out.len() == NR`,
    // and `panel.len() >= coeffs.len() * NR` — the raw loads below stay
    // inside `panel` by that arithmetic.
    // lintra: bitwise-critical
    unsafe fn panel_row_dense_avx2(out: &mut [f32], coeffs: &[f32], panel: &[f32]) {
        let mut acc = _mm256_setzero_ps();
        for (kk, &c) in coeffs.iter().enumerate() {
            let p = _mm256_loadu_ps(panel.as_ptr().add(kk * NR));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(c), p));
        }
        _mm256_storeu_ps(out.as_mut_ptr(), acc);
    }
}

/// Stub twin of the AVX2 module for non-x86-64 targets: detection is
/// `false`, every `try_*` declines, so the dispatchers above always take
/// the portable scalar path and the crate builds with zero intrinsics.
#[cfg(not(target_arch = "x86_64"))]
mod avx2 {
    pub(super) fn detect() -> bool {
        false
    }

    pub(super) fn try_axpy(_y: &mut [f32], _alpha: f32, _x: &[f32]) -> bool {
        false
    }

    pub(super) fn try_gemv_cols_f16(
        _y: &mut [f32],
        _bits: &[u16],
        _x: &[f32],
        _k: usize,
        _n: usize,
        _col0: usize,
    ) -> bool {
        false
    }

    pub(super) fn try_gemv_cols_bf16(
        _y: &mut [f32],
        _bits: &[u16],
        _x: &[f32],
        _k: usize,
        _n: usize,
        _col0: usize,
    ) -> bool {
        false
    }

    pub(super) fn try_gemv_cols_i8(
        _y: &mut [f32],
        _packed: &[i8],
        _scales: &[f32],
        _x: &[f32],
        _k: usize,
        _n: usize,
        _col0: usize,
    ) -> bool {
        false
    }

    pub(super) fn try_panel_row_f32_skip(
        _out: &mut [f32],
        _coeffs: &[f32],
        _panel: &[f32],
    ) -> bool {
        false
    }

    pub(super) fn try_panel_row_dense(_out: &mut [f32], _coeffs: &[f32], _panel: &[f32]) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Tier-forcing parity sweeps live in rust/tests/simd_parity.rs (their
    // own process, serialized by a local mutex); the unit tests here only
    // assert properties that hold on whatever tier happens to be active.

    #[test]
    fn labels_and_detection_are_consistent() {
        assert_eq!(IsaTier::Scalar.label(), "scalar");
        assert_eq!(IsaTier::Avx2.label(), "avx2");
        let t = active_tier();
        if t == IsaTier::Avx2 {
            assert!(avx2_supported(), "Avx2 tier must imply hardware support");
        }
        // forcing Avx2 clamps to hardware support and reports the truth
        let forced = force_tier(IsaTier::Avx2);
        assert_eq!(forced == IsaTier::Avx2, avx2_supported());
        force_tier(t);
    }

    #[test]
    fn axpy_matches_scalar_on_active_tier() {
        for len in [0usize, 1, 3, 7, 8, 9, 16, 31, 64, 100] {
            let x: Vec<f32> = (0..len).map(|i| (i as f32) * 0.37 - 3.0).collect();
            let mut y: Vec<f32> = (0..len).map(|i| (i as f32) * -0.11 + 1.0).collect();
            let mut want = y.clone();
            axpy_scalar(&mut want, 1.7, &x);
            axpy(&mut y, 1.7, &x);
            assert_eq!(y, want, "len {len}");
        }
    }

    #[test]
    fn panel_kernels_match_reference_on_active_tier() {
        for k in [0usize, 1, 3, 4, 17] {
            let coeffs: Vec<f32> = (0..k)
                .map(|i| if i % 3 == 0 { 0.0 } else { i as f32 * 0.5 - 1.0 })
                .collect();
            let panel: Vec<f32> = (0..k * NR).map(|i| (i as f32) * 0.01 - 0.5).collect();
            let mut want_skip = [0.0f32; NR];
            for (kk, &c) in coeffs.iter().enumerate() {
                if c == 0.0 {
                    continue;
                }
                for t in 0..NR {
                    want_skip[t] += c * panel[kk * NR + t];
                }
            }
            let mut got = [0.0f32; NR];
            panel_row_f32_skip(&mut got, &coeffs, &panel);
            assert_eq!(got, want_skip, "skip k {k}");
            let mut want_dense = [0.0f32; NR];
            for (kk, &c) in coeffs.iter().enumerate() {
                for t in 0..NR {
                    want_dense[t] += c * panel[kk * NR + t];
                }
            }
            panel_row_dense(&mut got, &coeffs, &panel);
            assert_eq!(got, want_dense, "dense k {k}");
        }
    }
}
