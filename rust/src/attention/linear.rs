//! Linearized attention (the paper's contribution), per head.
//!
//! * [`forward_causal`] — the chunk-free O(N·D·M) training/eval pass
//!   (Algorithm 1 forward).
//! * [`forward_backward_causal`] — constant-memory gradients (eqs 13-15
//!   plus the denominator terms), mirroring the Pallas backward kernel.
//! * [`forward_noncausal`] — eq. 6 for encoder stacks.
//! * [`LinearAttnState`] — eqs 16-20: the RNN cell. `step()` is one
//!   autoregressive update in O(D·M), independent of sequence length.
//! * [`BatchedLinearAttnState`] — the same recurrence over B decode lanes
//!   in structure-of-arrays layout: all lanes' S matrices live in one
//!   contiguous `[B, d, m]` block and all Z vectors in one `[B, d]` block,
//!   so `step_batch()` advances the whole batch with three streaming
//!   kernels (row-wise phi, batched outer-product accumulate, batched
//!   contraction) instead of B scalar loops. This is THE hot path of the
//!   serving engine (see `coordinator::engine`); because every lane is a
//!   fixed-size row pair, slot churn is plain row insert (`push_row`) and
//!   swap-remove compaction (`swap_remove_row`) — no cache planning.
//!   Prompt ingestion goes through `prefill_row`: one call absorbs a whole
//!   chunk of tokens into a lane's cumulative (S, Z) — bit-identical to
//!   ticking the chunk token-by-token, but lets the layers above batch
//!   their projections over the chunk and skip the lm-head until the
//!   final prompt position. Because the state is a fixed-size row pair,
//!   a lane is also *portable*: `export_row`/`import_row` copy one
//!   lane's exact (S, Z) bits out into / back from a flat buffer, which
//!   is what the serving engine's prefix-reuse state cache snapshots.
//!
//! Inputs q, k are *raw* (un-mapped); phi(x) = elu(x)+1 is applied
//! internally, matching the python wrappers.
//!
//! Numeric contract under weight quantization: these kernels never see
//! quantized values. Weight storage precision (`tensor::WeightDtype`) only
//! changes the *projection* matrices feeding q/k/v; activations, the (S, Z)
//! recurrent state, and every accumulation in this module stay f32, so a
//! cached state snapshot taken under one weight dtype is meaningless under
//! another (the cache is per-process and the dtype is fixed at engine spawn,
//! so this cannot arise in practice). See ARCHITECTURE.md, "Weight storage
//! & numeric contract".

use crate::parallel::ThreadPool;
use crate::tensor::{
    axpy, batched_contract_pooled, batched_outer_acc_pooled, dot, elu_plus_one, elu_plus_one_map,
};

pub const EPS: f32 = 1e-6;

/// Causal linear attention forward. q,k: [n,d], v: [n,m] -> out [n,m].
// lintra: bitwise-critical
pub fn forward_causal(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    m: usize,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * m);
    assert_eq!(out.len(), n * m);
    let mut s = vec![0.0f32; d * m]; // S_i = sum phi(k_j) v_j^T
    let mut z = vec![0.0f32; d]; // Z_i = sum phi(k_j)
    let mut qi = vec![0.0f32; d];
    let mut ki = vec![0.0f32; d];
    for i in 0..n {
        for t in 0..d {
            qi[t] = elu_plus_one(q[i * d + t]);
            ki[t] = elu_plus_one(k[i * d + t]);
        }
        let vi = &v[i * m..(i + 1) * m];
        // S += phi(k_i) v_i^T ; Z += phi(k_i)
        for t in 0..d {
            let kt = ki[t];
            if kt != 0.0 {
                axpy(&mut s[t * m..(t + 1) * m], kt, vi);
            }
            z[t] += kt;
        }
        // out_i = (phi(q_i)^T S) / (phi(q_i) . Z + eps)
        let den = dot(&qi, &z) + EPS;
        let orow = &mut out[i * m..(i + 1) * m];
        orow.fill(0.0);
        for t in 0..d {
            let qt = qi[t];
            if qt != 0.0 {
                axpy(orow, qt, &s[t * m..(t + 1) * m]);
            }
        }
        let inv = 1.0 / den;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
}

/// Non-causal linear attention (eq. 6): one global KV aggregation.
pub fn forward_noncausal(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    m: usize,
    out: &mut [f32],
) {
    let mut kv = vec![0.0f32; d * m];
    let mut z = vec![0.0f32; d];
    let mut ki = vec![0.0f32; d];
    for j in 0..n {
        for t in 0..d {
            ki[t] = elu_plus_one(k[j * d + t]);
        }
        let vj = &v[j * m..(j + 1) * m];
        for t in 0..d {
            if ki[t] != 0.0 {
                axpy(&mut kv[t * m..(t + 1) * m], ki[t], vj);
            }
            z[t] += ki[t];
        }
    }
    let mut qi = vec![0.0f32; d];
    for i in 0..n {
        for t in 0..d {
            qi[t] = elu_plus_one(q[i * d + t]);
        }
        let den = dot(&qi, &z) + EPS;
        let orow = &mut out[i * m..(i + 1) * m];
        orow.fill(0.0);
        for t in 0..d {
            if qi[t] != 0.0 {
                axpy(orow, qi[t], &kv[t * m..(t + 1) * m]);
            }
        }
        let inv = 1.0 / den;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
}

/// Constant-memory forward+backward for causal linear attention
/// (paper §3.3.1). Returns (out, dq, dk, dv) for raw (un-mapped) q, k.
#[allow(clippy::too_many_arguments)]
pub fn forward_backward_causal(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: &[f32],
    n: usize,
    d: usize,
    m: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    // map q, k once; chain rule through phi at the end
    let qm: Vec<f32> = q.iter().map(|&x| elu_plus_one(x)).collect();
    let km: Vec<f32> = k.iter().map(|&x| elu_plus_one(x)).collect();

    // ---- forward, saving only out + den (O(N) residuals) ----
    let mut out = vec![0.0f32; n * m];
    let mut den = vec![0.0f32; n];
    {
        let mut s = vec![0.0f32; d * m];
        let mut z = vec![0.0f32; d];
        for i in 0..n {
            let ki = &km[i * d..(i + 1) * d];
            let qi = &qm[i * d..(i + 1) * d];
            let vi = &v[i * m..(i + 1) * m];
            for t in 0..d {
                if ki[t] != 0.0 {
                    axpy(&mut s[t * m..(t + 1) * m], ki[t], vi);
                }
                z[t] += ki[t];
            }
            den[i] = dot(qi, &z) + EPS;
            let orow = &mut out[i * m..(i + 1) * m];
            for t in 0..d {
                if qi[t] != 0.0 {
                    axpy(orow, qi[t], &s[t * m..(t + 1) * m]);
                }
            }
            let inv = 1.0 / den[i];
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    }

    // upstream grads split into numerator/denominator parts
    // gn_i = g_i / den_i ; h_i = -(g_i . out_i) / den_i
    let mut gn = vec![0.0f32; n * m];
    let mut h = vec![0.0f32; n];
    for i in 0..n {
        let inv = 1.0 / den[i];
        let gi = &g[i * m..(i + 1) * m];
        let oi = &out[i * m..(i + 1) * m];
        for e in 0..m {
            gn[i * m + e] = gi[e] * inv;
        }
        h[i] = -dot(gi, oi) * inv;
    }

    let mut dqm = vec![0.0f32; n * d];
    let mut dkm = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * m];

    // ---- forward sweep: dq (eq. 13 + denominator term) ----
    {
        let mut s = vec![0.0f32; d * m];
        let mut z = vec![0.0f32; d];
        for i in 0..n {
            let ki = &km[i * d..(i + 1) * d];
            let vi = &v[i * m..(i + 1) * m];
            for t in 0..d {
                if ki[t] != 0.0 {
                    axpy(&mut s[t * m..(t + 1) * m], ki[t], vi);
                }
                z[t] += ki[t];
            }
            let gi = &gn[i * m..(i + 1) * m];
            let dqrow = &mut dqm[i * d..(i + 1) * d];
            for t in 0..d {
                dqrow[t] = dot(gi, &s[t * m..(t + 1) * m]) + h[i] * z[t];
            }
        }
    }

    // ---- backward sweep: dk (eq. 14 + den), dv (eq. 15) ----
    {
        let mut tmat = vec![0.0f32; d * m]; // T_i = sum_{j>=i} q_j gn_j^T
        let mut u = vec![0.0f32; d]; // sum_{j>=i} h_j q_j
        for i in (0..n).rev() {
            let qi = &qm[i * d..(i + 1) * d];
            let gi = &gn[i * m..(i + 1) * m];
            // include j = i
            for t in 0..d {
                if qi[t] != 0.0 {
                    axpy(&mut tmat[t * m..(t + 1) * m], qi[t], gi);
                }
                u[t] += h[i] * qi[t];
            }
            let ki = &km[i * d..(i + 1) * d];
            let vi = &v[i * m..(i + 1) * m];
            let dkrow = &mut dkm[i * d..(i + 1) * d];
            for t in 0..d {
                dkrow[t] = dot(vi, &tmat[t * m..(t + 1) * m]) + u[t];
            }
            let dvrow = &mut dv[i * m..(i + 1) * m];
            dvrow.fill(0.0);
            for t in 0..d {
                if ki[t] != 0.0 {
                    axpy(dvrow, ki[t], &tmat[t * m..(t + 1) * m]);
                }
            }
        }
    }

    // chain through phi: d phi/dx = 1 for x >= 0, exp(x) for x < 0
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    for idx in 0..n * d {
        dq[idx] = dqm[idx] * if q[idx] >= 0.0 { 1.0 } else { q[idx].exp() };
        dk[idx] = dkm[idx] * if k[idx] >= 0.0 { 1.0 } else { k[idx].exp() };
    }
    (out, dq, dk, dv)
}

/// The RNN view (eqs 16-20): per-head recurrent state.
///
/// `step()` performs one autoregressive update in O(D·M) — independent of
/// how many tokens came before. This is the paper's headline property.
#[derive(Clone, Debug)]
pub struct LinearAttnState {
    pub d: usize,
    pub m: usize,
    /// s: [d, m] row-major — the attention memory (eq. 18)
    pub s: Vec<f32>,
    /// z: [d] — the normalizer memory (eq. 19)
    pub z: Vec<f32>,
    // preallocated scratch (phi(q), phi(k)) to keep step() allocation-free
    qbuf: Vec<f32>,
    kbuf: Vec<f32>,
}

impl LinearAttnState {
    pub fn new(d: usize, m: usize) -> Self {
        LinearAttnState {
            d,
            m,
            s: vec![0.0; d * m],
            z: vec![0.0; d],
            qbuf: vec![0.0; d],
            kbuf: vec![0.0; d],
        }
    }

    pub fn reset(&mut self) {
        self.s.fill(0.0);
        self.z.fill(0.0);
    }

    /// Memory footprint (constant in sequence length).
    pub fn state_bytes(&self) -> usize {
        (self.s.len() + self.z.len()) * 4
    }

    /// Absorb a chunk of `n` tokens into the state through the causal
    /// cumulative recurrence (the prefill path). `q, k: [n, d]`,
    /// `v, out: [n, m]`; `out` receives every position's attention output.
    ///
    /// Equivalent to `n` calls of [`Self::step`] — bit-for-bit, because it
    /// replays the same per-token update order — but callable once per
    /// prompt chunk so the layers above can batch their projections.
    // lintra: bitwise-critical
    pub fn prefill(&mut self, q: &[f32], k: &[f32], v: &[f32], n: usize, out: &mut [f32]) {
        let (d, m) = (self.d, self.m);
        assert_eq!(q.len(), n * d);
        assert_eq!(k.len(), n * d);
        assert_eq!(v.len(), n * m);
        assert_eq!(out.len(), n * m);
        for i in 0..n {
            self.step(
                &q[i * d..(i + 1) * d],
                &k[i * d..(i + 1) * d],
                &v[i * m..(i + 1) * m],
                &mut out[i * m..(i + 1) * m],
            );
        }
    }

    /// One decode step with raw (un-mapped) q, k, v; writes `out` [m].
    // lintra: bitwise-critical
    pub fn step(&mut self, q: &[f32], k: &[f32], v: &[f32], out: &mut [f32]) {
        debug_assert_eq!(q.len(), self.d);
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.m);
        debug_assert_eq!(out.len(), self.m);
        let d = self.d;
        let m = self.m;
        for t in 0..d {
            self.qbuf[t] = elu_plus_one(q[t]);
            self.kbuf[t] = elu_plus_one(k[t]);
        }
        // s += phi(k) v^T ; z += phi(k)   (eqs 18, 19)
        for t in 0..d {
            let kt = self.kbuf[t];
            if kt != 0.0 {
                axpy(&mut self.s[t * m..(t + 1) * m], kt, v);
            }
            self.z[t] += kt;
        }
        // out = (phi(q)^T s) / (phi(q) . z + eps)   (eq. 20 numerator part)
        let den = dot(&self.qbuf, &self.z) + EPS;
        out.fill(0.0);
        for t in 0..d {
            let qt = self.qbuf[t];
            if qt != 0.0 {
                axpy(out, qt, &self.s[t * m..(t + 1) * m]);
            }
        }
        let inv = 1.0 / den;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

/// The RNN view over B decode lanes, structure-of-arrays.
///
/// Lane r's state is row r of `s` (`[d, m]`) and row r of `z` (`[d]`);
/// rows `0..rows` are live and contiguous. The serving engine maps decode
/// slots onto lanes and keeps them dense with [`Self::push_row`] /
/// [`Self::swap_remove_row`].
#[derive(Clone, Debug)]
pub struct BatchedLinearAttnState {
    pub d: usize,
    pub m: usize,
    cap: usize,
    rows: usize,
    /// `[cap, d, m]` — per-lane attention memory (eq. 18)
    s: Vec<f32>,
    /// `[cap, d]` — per-lane normalizer memory (eq. 19)
    z: Vec<f32>,
    // preallocated phi(q) / phi(k) scratch, [cap, d]
    qbuf: Vec<f32>,
    kbuf: Vec<f32>,
}

impl BatchedLinearAttnState {
    pub fn new(cap: usize, d: usize, m: usize) -> Self {
        assert!(cap >= 1);
        BatchedLinearAttnState {
            d,
            m,
            cap,
            rows: 0,
            s: vec![0.0; cap * d * m],
            z: vec![0.0; cap * d],
            qbuf: vec![0.0; cap * d],
            kbuf: vec![0.0; cap * d],
        }
    }

    /// Live lanes.
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Lane r's (S, Z) pair.
    pub fn lane(&self, r: usize) -> (&[f32], &[f32]) {
        assert!(r < self.rows);
        let (d, m) = (self.d, self.m);
        (&self.s[r * d * m..(r + 1) * d * m], &self.z[r * d..(r + 1) * d])
    }

    /// Append a zeroed lane; returns its row index, or `None` at capacity.
    pub fn push_row(&mut self) -> Option<usize> {
        if self.rows == self.cap {
            return None;
        }
        let r = self.rows;
        let (d, m) = (self.d, self.m);
        self.s[r * d * m..(r + 1) * d * m].fill(0.0);
        self.z[r * d..(r + 1) * d].fill(0.0);
        self.rows += 1;
        Some(r)
    }

    /// Swap lanes `a` and `b` (state and normalizer rows). O(d·m), the
    /// same cost as a [`Self::swap_remove_row`] compaction move. The
    /// serving engine uses this to keep decoding lanes as a contiguous
    /// prefix while later lanes are still mid-prefill.
    pub fn swap_rows(&mut self, a: usize, b: usize) {
        assert!(a < self.rows && b < self.rows, "swap_rows out of {} live lanes", self.rows);
        if a == b {
            return;
        }
        let (d, m) = (self.d, self.m);
        for t in 0..d * m {
            self.s.swap(a * d * m + t, b * d * m + t);
        }
        for t in 0..d {
            self.z.swap(a * d + t, b * d + t);
        }
    }

    /// Free lane `r`, compacting by moving the last lane into its place.
    /// Returns the index the moved lane previously had (`None` if `r` was
    /// already last) so callers can fix their lane maps.
    pub fn swap_remove_row(&mut self, r: usize) -> Option<usize> {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        let last = self.rows - 1;
        self.rows = last;
        if r == last {
            return None;
        }
        let (d, m) = (self.d, self.m);
        self.s.copy_within(last * d * m..(last + 1) * d * m, r * d * m);
        self.z.copy_within(last * d..(last + 1) * d, r * d);
        Some(last)
    }

    /// Memory footprint of the live lanes (constant per lane, per token).
    pub fn state_bytes(&self) -> usize {
        self.rows * (self.d * self.m + self.d) * 4
    }

    /// Floats in one lane's snapshot: the `[d, m]` S block followed by
    /// the `[d]` Z block (the layout [`Self::export_row`] writes and
    /// [`Self::import_row`] expects).
    pub fn lane_len(&self) -> usize {
        self.d * self.m + self.d
    }

    /// Copy lane `r`'s (S, Z) pair into `out` (`[lane_len()]`: s
    /// row-major, then z). The lane itself is untouched; the copy is the
    /// exact f32 bits of the state, so importing it later resumes the
    /// recurrence bit-identically (snapshot/restore is plain memcpy —
    /// the paper's fixed-size state makes the whole attention memory of
    /// a prefix a small flat buffer).
    pub fn export_row(&self, r: usize, out: &mut [f32]) {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        let (d, m) = (self.d, self.m);
        assert_eq!(out.len(), d * m + d, "snapshot buffer has the wrong length");
        out[..d * m].copy_from_slice(&self.s[r * d * m..(r + 1) * d * m]);
        out[d * m..].copy_from_slice(&self.z[r * d..(r + 1) * d]);
    }

    /// Overwrite lane `r`'s (S, Z) pair from a buffer written by
    /// [`Self::export_row`]. Bitwise: after the import the lane is
    /// indistinguishable from the lane the snapshot was taken from, so
    /// any continuation ([`Self::step_batch`] / [`Self::prefill_row`])
    /// produces the exact floats the source lane would have produced.
    pub fn import_row(&mut self, r: usize, snap: &[f32]) {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        let (d, m) = (self.d, self.m);
        assert_eq!(snap.len(), d * m + d, "snapshot buffer has the wrong length");
        self.s[r * d * m..(r + 1) * d * m].copy_from_slice(&snap[..d * m]);
        self.z[r * d..(r + 1) * d].copy_from_slice(&snap[d * m..]);
    }

    /// Absorb a chunk of `n` tokens into lane `r`'s state through the
    /// causal cumulative recurrence — the prefill path. `q, k: [n, d]`,
    /// `v, out: [n, m]`; `out` receives the chunk's attention outputs.
    ///
    /// One call ingests one chunk; the carried (S, Z) makes successive
    /// calls (and a following [`Self::step_batch`] decode) continue the
    /// same sequence. The per-token update replays exactly the float-op
    /// order of `step_batch`'s per-lane slice, so prefilling a prompt is
    /// bit-identical to feeding it one tick at a time.
    // lintra: bitwise-critical
    pub fn prefill_row(
        &mut self,
        r: usize,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        let (d, m) = (self.d, self.m);
        assert_eq!(q.len(), n * d);
        assert_eq!(k.len(), n * d);
        assert_eq!(v.len(), n * m);
        assert_eq!(out.len(), n * m);
        let s = &mut self.s[r * d * m..(r + 1) * d * m];
        let z = &mut self.z[r * d..(r + 1) * d];
        let qb = &mut self.qbuf[..d];
        let kb = &mut self.kbuf[..d];
        for i in 0..n {
            elu_plus_one_map(qb, &q[i * d..(i + 1) * d]);
            elu_plus_one_map(kb, &k[i * d..(i + 1) * d]);
            let vi = &v[i * m..(i + 1) * m];
            // S += phi(k_i) v_i^T ; Z += phi(k_i)   (eqs 18, 19)
            for (t, &kt) in kb.iter().enumerate() {
                if kt != 0.0 {
                    axpy(&mut s[t * m..(t + 1) * m], kt, vi);
                }
            }
            for (zv, &kt) in z.iter_mut().zip(kb.iter()) {
                *zv += kt;
            }
            // out_i = (phi(q_i)^T S) / (phi(q_i) . Z + eps)   (eq. 20)
            let orow = &mut out[i * m..(i + 1) * m];
            orow.fill(0.0);
            for (t, &qt) in qb.iter().enumerate() {
                if qt != 0.0 {
                    axpy(orow, qt, &s[t * m..(t + 1) * m]);
                }
            }
            let den = dot(qb, z) + EPS;
            let inv = 1.0 / den;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    }

    /// One decode step for the first `q.len() / d` live lanes with raw
    /// (un-mapped) inputs. `q, k: [b, d]`, `v, out: [b, m]` for any
    /// `b <= rows`; lanes `b..rows` are left untouched (the serving
    /// engine keeps lanes that are still mid-prefill in that suffix).
    // lintra: bitwise-critical
    pub fn step_batch(&mut self, q: &[f32], k: &[f32], v: &[f32], out: &mut [f32]) {
        self.step_batch_pooled(None, q, k, v, out)
    }

    /// [`Self::step_batch`] with the two streaming batched kernels
    /// (outer-product accumulate, contraction) partitioned over lanes on
    /// `pool`. Lanes are independent and each lane's float-op order never
    /// depends on `b` or the thread count, so stepping a prefix on a pool
    /// is bit-identical to stepping the same lanes serially, full-width.
    // lintra: bitwise-critical
    pub fn step_batch_pooled(
        &mut self,
        pool: Option<&ThreadPool>,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) {
        let (d, m) = (self.d, self.m);
        assert_eq!(q.len() % d, 0, "q is not [b, d]");
        let b = q.len() / d;
        assert!(b <= self.rows, "stepping {b} lanes of {} live", self.rows);
        assert_eq!(k.len(), b * d);
        assert_eq!(v.len(), b * m);
        assert_eq!(out.len(), b * m);
        if b == 0 {
            return;
        }
        let qb = &mut self.qbuf[..b * d];
        let kb = &mut self.kbuf[..b * d];
        elu_plus_one_map(qb, q);
        elu_plus_one_map(kb, k);
        // S_r += phi(k_r) v_r^T ; Z_r += phi(k_r)   (eqs 18, 19, all lanes)
        batched_outer_acc_pooled(pool, &mut self.s[..b * d * m], kb, v, b, d, m);
        for (zv, &kv) in self.z[..b * d].iter_mut().zip(kb.iter()) {
            *zv += kv;
        }
        // out_r = (phi(q_r)^T S_r) / (phi(q_r) . Z_r + eps)   (eq. 20)
        batched_contract_pooled(pool, out, qb, &self.s[..b * d * m], b, d, m);
        for r in 0..b {
            let den = dot(&qb[r * d..(r + 1) * d], &self.z[r * d..(r + 1) * d]) + EPS;
            let inv = 1.0 / den;
            for o in out[r * m..(r + 1) * m].iter_mut() {
                *o *= inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand(n: usize, rng: &mut Rng) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn rnn_view_equals_parallel_view() {
        // the crux of section 3.4, at the engine level
        let (n, d, m) = (24, 8, 8);
        let mut rng = Rng::new(0);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut parallel = vec![0.0; n * m];
        forward_causal(&q, &k, &v, n, d, m, &mut parallel);

        let mut state = LinearAttnState::new(d, m);
        let mut step_out = vec![0.0; m];
        for i in 0..n {
            state.step(
                &q[i * d..(i + 1) * d],
                &k[i * d..(i + 1) * d],
                &v[i * m..(i + 1) * m],
                &mut step_out,
            );
            for e in 0..m {
                let p = parallel[i * m + e];
                assert!(
                    (p - step_out[e]).abs() < 1e-4,
                    "RNN/parallel divergence at i={i} e={e}: {p} vs {}",
                    step_out[e]
                );
            }
        }
    }

    #[test]
    fn first_output_is_v0() {
        let (n, d, m) = (4, 4, 4);
        let mut rng = Rng::new(1);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut out = vec![0.0; n * m];
        forward_causal(&q, &k, &v, n, d, m, &mut out);
        for e in 0..m {
            assert!((out[e] - v[e]).abs() < 1e-4);
        }
    }

    #[test]
    fn causality_perturbation() {
        let (n, d, m) = (16, 4, 4);
        let mut rng = Rng::new(2);
        let (q, mut k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut base = vec![0.0; n * m];
        forward_causal(&q, &k, &v, n, d, m, &mut base);
        for x in &mut k[(n - 2) * d..] {
            *x += 2.0;
        }
        let mut pert = vec![0.0; n * m];
        forward_causal(&q, &k, &v, n, d, m, &mut pert);
        for i in 0..(n - 2) * m {
            assert!((base[i] - pert[i]).abs() < 1e-6);
        }
        let tail: f32 = ((n - 2) * m..n * m).map(|i| (base[i] - pert[i]).abs()).sum();
        assert!(tail > 1e-4);
    }

    #[test]
    fn noncausal_is_constant_over_positions_when_q_constant() {
        // with identical queries, every output row must be identical
        let (n, d, m) = (10, 4, 4);
        let mut rng = Rng::new(3);
        let q1 = rand(d, &mut rng);
        let q: Vec<f32> = (0..n).flat_map(|_| q1.clone()).collect();
        let (k, v) = (rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut out = vec![0.0; n * m];
        forward_noncausal(&q, &k, &v, n, d, m, &mut out);
        for i in 1..n {
            for e in 0..m {
                assert!((out[e] - out[i * m + e]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (n, d, m) = (6, 3, 3);
        let mut rng = Rng::new(4);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let g = rand(n * m, &mut rng);
        let (_, dq, dk, dv) = forward_backward_causal(&q, &k, &v, &g, n, d, m);

        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f32 {
            let mut out = vec![0.0; n * m];
            forward_causal(q, k, v, n, d, m, &mut out);
            out.iter().zip(&g).map(|(o, gg)| o * gg).sum()
        };
        let eps = 1e-3;
        for (analytic, which) in [(&dq, 0usize), (&dk, 1), (&dv, 2)] {
            for idx in [0usize, 4, analytic.len() - 1] {
                let (mut qp, mut kp, mut vp) = (q.clone(), k.clone(), v.clone());
                match which {
                    0 => qp[idx] += eps,
                    1 => kp[idx] += eps,
                    _ => vp[idx] += eps,
                }
                let up = loss(&qp, &kp, &vp);
                match which {
                    0 => qp[idx] -= 2.0 * eps,
                    1 => kp[idx] -= 2.0 * eps,
                    _ => vp[idx] -= 2.0 * eps,
                }
                let down = loss(&qp, &kp, &vp);
                let fd = (up - down) / (2.0 * eps);
                assert!(
                    (fd - analytic[idx]).abs() < 2e-2,
                    "which={which} idx={idx}: fd={fd} analytic={}",
                    analytic[idx]
                );
            }
        }
    }

    #[test]
    fn state_size_constant_and_resettable() {
        let mut st = LinearAttnState::new(32, 32);
        let bytes0 = st.state_bytes();
        let mut rng = Rng::new(5);
        let mut out = vec![0.0; 32];
        for _ in 0..100 {
            let q = rand(32, &mut rng);
            let k = rand(32, &mut rng);
            let v = rand(32, &mut rng);
            st.step(&q, &k, &v, &mut out);
        }
        assert_eq!(st.state_bytes(), bytes0, "state must not grow with tokens");
        st.reset();
        assert!(st.s.iter().all(|&x| x == 0.0));
        assert!(st.z.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn batched_lanes_match_independent_scalar_states() {
        let (d, m, b, steps) = (8, 8, 5, 12);
        let mut rng = Rng::new(6);
        let mut batched = BatchedLinearAttnState::new(b, d, m);
        let mut scalars: Vec<LinearAttnState> =
            (0..b).map(|_| LinearAttnState::new(d, m)).collect();
        for r in 0..b {
            assert_eq!(batched.push_row(), Some(r));
        }
        let mut out_b = vec![0.0; b * m];
        let mut out_s = vec![0.0; m];
        for _ in 0..steps {
            let q = rand(b * d, &mut rng);
            let k = rand(b * d, &mut rng);
            let v = rand(b * m, &mut rng);
            batched.step_batch(&q, &k, &v, &mut out_b);
            for (r, st) in scalars.iter_mut().enumerate() {
                st.step(
                    &q[r * d..(r + 1) * d],
                    &k[r * d..(r + 1) * d],
                    &v[r * m..(r + 1) * m],
                    &mut out_s,
                );
                for e in 0..m {
                    assert!(
                        (out_b[r * m + e] - out_s[e]).abs() < 1e-4,
                        "lane {r} diverged at element {e}"
                    );
                }
            }
        }
    }

    #[test]
    fn swap_remove_compaction_preserves_survivors() {
        let (d, m) = (4, 4);
        let mut rng = Rng::new(7);
        let mut batched = BatchedLinearAttnState::new(3, d, m);
        for _ in 0..3 {
            batched.push_row();
        }
        // independent references for lanes 0 and 2 (lane 1 will be evicted)
        let mut ref0 = LinearAttnState::new(d, m);
        let mut ref2 = LinearAttnState::new(d, m);
        let mut out_b = vec![0.0; 3 * m];
        let mut out_s = vec![0.0; m];
        let (q, k, v) = (rand(3 * d, &mut rng), rand(3 * d, &mut rng), rand(3 * m, &mut rng));
        batched.step_batch(&q, &k, &v, &mut out_b);
        ref0.step(&q[..d], &k[..d], &v[..m], &mut out_s);
        ref2.step(&q[2 * d..], &k[2 * d..], &v[2 * m..], &mut out_s);

        // evict lane 1: lane 2 moves into row 1
        assert_eq!(batched.swap_remove_row(1), Some(2));
        assert_eq!(batched.rows(), 2);

        // survivors keep their trajectories (row 0 = old lane 0, row 1 = old lane 2)
        let (q2, k2, v2) = (rand(2 * d, &mut rng), rand(2 * d, &mut rng), rand(2 * m, &mut rng));
        let mut out2 = vec![0.0; 2 * m];
        batched.step_batch(&q2, &k2, &v2, &mut out2);
        ref0.step(&q2[..d], &k2[..d], &v2[..m], &mut out_s);
        for e in 0..m {
            assert!((out2[e] - out_s[e]).abs() < 1e-4, "lane 0 broke after compaction");
        }
        ref2.step(&q2[d..], &k2[d..], &v2[m..], &mut out_s);
        for e in 0..m {
            assert!((out2[m + e] - out_s[e]).abs() < 1e-4, "moved lane broke after compaction");
        }

        // freed capacity is reusable and comes back zeroed
        let r = batched.push_row().unwrap();
        assert_eq!(r, 2);
        let (s, z) = batched.lane(r);
        assert!(s.iter().all(|&x| x == 0.0) && z.iter().all(|&x| x == 0.0));
        assert!(batched.push_row().is_none(), "capacity enforced");
    }

    #[test]
    fn scalar_prefill_is_bitwise_stepwise() {
        let (n, d, m) = (13, 8, 8);
        let mut rng = Rng::new(20);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut stepped = LinearAttnState::new(d, m);
        let mut expect = vec![0.0; n * m];
        for i in 0..n {
            stepped.step(
                &q[i * d..(i + 1) * d],
                &k[i * d..(i + 1) * d],
                &v[i * m..(i + 1) * m],
                &mut expect[i * m..(i + 1) * m],
            );
        }
        let mut prefilled = LinearAttnState::new(d, m);
        let mut out = vec![0.0; n * m];
        prefilled.prefill(&q, &k, &v, n, &mut out);
        assert_eq!(out, expect, "prefill outputs must be bit-identical to stepping");
        assert_eq!(prefilled.s, stepped.s, "prefill S must be bit-identical");
        assert_eq!(prefilled.z, stepped.z, "prefill Z must be bit-identical");
    }

    #[test]
    fn batched_prefill_row_is_bitwise_stepwise_and_carries_state() {
        // prefill two chunks into lane 1 of a 3-lane state, then keep
        // decoding with step_batch; a scalar reference fed token-by-token
        // must agree bit-for-bit at every point
        let (d, m, b) = (8, 8, 3);
        let mut rng = Rng::new(21);
        let mut batched = BatchedLinearAttnState::new(b, d, m);
        for _ in 0..b {
            batched.push_row();
        }
        let mut reference = LinearAttnState::new(d, m);
        let mut ref_out = vec![0.0; m];
        for chunk_len in [5usize, 3] {
            let q = rand(chunk_len * d, &mut rng);
            let k = rand(chunk_len * d, &mut rng);
            let v = rand(chunk_len * m, &mut rng);
            let mut out = vec![0.0; chunk_len * m];
            batched.prefill_row(1, &q, &k, &v, chunk_len, &mut out);
            for i in 0..chunk_len {
                reference.step(
                    &q[i * d..(i + 1) * d],
                    &k[i * d..(i + 1) * d],
                    &v[i * m..(i + 1) * m],
                    &mut ref_out,
                );
                assert_eq!(
                    &out[i * m..(i + 1) * m],
                    &ref_out[..],
                    "chunk position {i} diverged from stepwise ingestion"
                );
            }
        }
        let (s1, z1) = batched.lane(1);
        assert_eq!(s1, &reference.s[..], "lane S must match stepwise state");
        assert_eq!(z1, &reference.z[..], "lane Z must match stepwise state");
        // the prefilled lane keeps decoding in lockstep with the reference
        let mut out_b = vec![0.0; b * m];
        for _ in 0..4 {
            let q = rand(b * d, &mut rng);
            let k = rand(b * d, &mut rng);
            let v = rand(b * m, &mut rng);
            batched.step_batch(&q, &k, &v, &mut out_b);
            reference.step(&q[d..2 * d], &k[d..2 * d], &v[m..2 * m], &mut ref_out);
            assert_eq!(&out_b[m..2 * m], &ref_out[..], "decode after prefill diverged");
        }
    }

    #[test]
    fn swap_rows_exchanges_lane_trajectories_exactly() {
        // after swapping lanes 0 and 2, feeding swapped inputs must
        // reproduce the unswapped run bit-for-bit
        let (d, m, b) = (4, 4, 3);
        let mut rng = Rng::new(23);
        let mut plain = BatchedLinearAttnState::new(b, d, m);
        let mut swapped = BatchedLinearAttnState::new(b, d, m);
        for _ in 0..b {
            plain.push_row();
            swapped.push_row();
        }
        let (q, k, v) = (rand(b * d, &mut rng), rand(b * d, &mut rng), rand(b * m, &mut rng));
        let mut out_a = vec![0.0; b * m];
        let mut out_b = vec![0.0; b * m];
        plain.step_batch(&q, &k, &v, &mut out_a);
        swapped.step_batch(&q, &k, &v, &mut out_b);
        swapped.swap_rows(0, 2);
        swapped.swap_rows(0, 0); // self-swap is a no-op
        // route lane 0's stream to row 2 and vice versa
        let perm = |x: &[f32], w: usize| {
            let mut y = x.to_vec();
            for t in 0..w {
                y.swap(t, 2 * w + t);
            }
            y
        };
        let (q2, k2, v2) = (rand(b * d, &mut rng), rand(b * d, &mut rng), rand(b * m, &mut rng));
        plain.step_batch(&q2, &k2, &v2, &mut out_a);
        swapped.step_batch(&perm(&q2, d), &perm(&k2, d), &perm(&v2, m), &mut out_b);
        let unswapped = perm(&out_b, m);
        assert_eq!(&out_a[..m], &unswapped[..m], "lane 0 trajectory broke under swap");
        assert_eq!(&out_a[2 * m..], &unswapped[2 * m..], "lane 2 trajectory broke under swap");
        assert_eq!(&out_a[m..2 * m], &out_b[m..2 * m], "bystander lane disturbed by swap");
    }

    #[test]
    fn prefix_step_leaves_suffix_lanes_untouched() {
        // stepping only the first 2 of 3 lanes must not move lane 2's
        // state, and must be bit-identical to a 2-lane session
        let (d, m) = (4, 4);
        let mut rng = Rng::new(24);
        let mut full = BatchedLinearAttnState::new(3, d, m);
        let mut two = BatchedLinearAttnState::new(2, d, m);
        for _ in 0..3 {
            full.push_row();
        }
        for _ in 0..2 {
            two.push_row();
        }
        let snapshot = {
            let (s, z) = full.lane(2);
            (s.to_vec(), z.to_vec())
        };
        let mut out_a = vec![0.0; 2 * m];
        let mut out_b = vec![0.0; 2 * m];
        for _ in 0..5 {
            let (q, k, v) = (rand(2 * d, &mut rng), rand(2 * d, &mut rng), rand(2 * m, &mut rng));
            full.step_batch(&q, &k, &v, &mut out_a);
            two.step_batch(&q, &k, &v, &mut out_b);
            assert_eq!(out_a, out_b, "prefix step must match the narrow session bitwise");
        }
        let (s, z) = full.lane(2);
        assert_eq!((s.to_vec(), z.to_vec()), snapshot, "suffix lane state moved");
    }

    #[test]
    fn export_import_row_resumes_bitwise() {
        // snapshot a lane mid-stream, perturb the world, restore into a
        // different lane of a different state: the restored lane must
        // continue the source trajectory bit-for-bit
        let (d, m, b) = (8, 8, 3);
        let mut rng = Rng::new(25);
        let mut src = BatchedLinearAttnState::new(b, d, m);
        for _ in 0..b {
            src.push_row();
        }
        let mut out = vec![0.0; b * m];
        for _ in 0..6 {
            let (q, k, v) = (rand(b * d, &mut rng), rand(b * d, &mut rng), rand(b * m, &mut rng));
            src.step_batch(&q, &k, &v, &mut out);
        }
        let mut snap = vec![0.0f32; src.lane_len()];
        src.export_row(1, &mut snap);
        // export must not disturb the source lane
        let (s1, z1) = src.lane(1);
        assert_eq!(&snap[..d * m], s1);
        assert_eq!(&snap[d * m..], z1);

        let mut dst = BatchedLinearAttnState::new(2, d, m);
        dst.push_row();
        dst.push_row();
        // dirty the destination lane first: import must fully overwrite
        let (q, k, v) = (rand(2 * d, &mut rng), rand(2 * d, &mut rng), rand(2 * m, &mut rng));
        let mut out2 = vec![0.0; 2 * m];
        dst.step_batch(&q, &k, &v, &mut out2);
        dst.import_row(0, &snap);
        let (s0, z0) = dst.lane(0);
        assert_eq!(s0, &snap[..d * m], "import must land the exact S bits");
        assert_eq!(z0, &snap[d * m..], "import must land the exact Z bits");

        // both lanes now decode in bitwise lockstep
        let mut out_src = vec![0.0; b * m];
        let mut out_dst = vec![0.0; 2 * m];
        for _ in 0..4 {
            let (q, k, v) = (rand(b * d, &mut rng), rand(b * d, &mut rng), rand(b * m, &mut rng));
            src.step_batch(&q, &k, &v, &mut out_src);
            // route the same stream lane 1 sees into dst lane 0
            let mut q2 = q[..2 * d].to_vec();
            let mut k2 = k[..2 * d].to_vec();
            let mut v2 = v[..2 * m].to_vec();
            q2[..d].copy_from_slice(&q[d..2 * d]);
            k2[..d].copy_from_slice(&k[d..2 * d]);
            v2[..m].copy_from_slice(&v[m..2 * m]);
            dst.step_batch(&q2, &k2, &v2, &mut out_dst);
            assert_eq!(
                &out_src[m..2 * m],
                &out_dst[..m],
                "restored lane diverged from the source trajectory"
            );
        }
    }

    #[test]
    #[should_panic(expected = "wrong length")]
    fn import_row_rejects_mismatched_snapshot() {
        let mut st = BatchedLinearAttnState::new(1, 4, 4);
        st.push_row();
        st.import_row(0, &[0.0; 7]);
    }

    #[test]
    fn batched_state_bytes_track_live_lanes() {
        let mut st = BatchedLinearAttnState::new(4, 8, 8);
        assert_eq!(st.state_bytes(), 0);
        st.push_row();
        st.push_row();
        assert_eq!(st.state_bytes(), 2 * (8 * 8 + 8) * 4);
        st.swap_remove_row(0);
        assert_eq!(st.state_bytes(), (8 * 8 + 8) * 4);
    }

    #[test]
    fn outputs_are_weighted_averages_of_values() {
        crate::propcheck::check("linear-attn-convex-hull", 30, |gen| {
            let n = gen.usize_in(2, 16);
            let d = 4usize;
            let m = 4usize;
            let q = gen.vec_f32(n * d, 1.0);
            let k = gen.vec_f32(n * d, 1.0);
            let v = gen.vec_f32(n * m, 1.0);
            let mut out = vec![0.0; n * m];
            forward_causal(&q, &k, &v, n, d, m, &mut out);
            let vmax = v.iter().cloned().fold(f32::MIN, f32::max);
            let vmin = v.iter().cloned().fold(f32::MAX, f32::min);
            for &o in &out {
                if o > vmax + 1e-3 || o < vmin - 1e-3 {
                    return Err(format!("output {o} escapes value hull [{vmin}, {vmax}]"));
                }
            }
            Ok(())
        });
    }
}
