//! Full softmax attention (eq. 2) — the vanilla-transformer baseline.
//!
//! Materializes the N x N weight matrix; O(N²·max(D,M)) time and O(N²)
//! memory, which is exactly the wall Figure 1 measures. The backward pass
//! implements the standard softmax-attention vjp, recomputing W.

use crate::tensor::{axpy, dot, matmul_into, softmax_inplace};

/// out[n,m] = softmax(q k^T / sqrt(d)) v, optionally causal.
pub fn forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    m: usize,
    causal: bool,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * m);
    assert_eq!(out.len(), n * m);
    let mut w = vec![0.0f32; n * n];
    weights_into(&mut w, q, k, n, d, causal);
    matmul_into(out, &w, v, n, n, m);
}

/// Compute the softmax weight matrix into `w`.
fn weights_into(w: &mut [f32], q: &[f32], k: &[f32], n: usize, d: usize, causal: bool) {
    let scale = 1.0 / (d as f32).sqrt();
    // w = q k^T (k is [n, d], we need k^T [d, n]: loop directly)
    for i in 0..n {
        let qi = &q[i * d..(i + 1) * d];
        let row = &mut w[i * n..(i + 1) * n];
        let limit = if causal { i + 1 } else { n };
        for (j, rj) in row.iter_mut().enumerate().take(limit) {
            let kj = &k[j * d..(j + 1) * d];
            *rj = crate::tensor::dot(qi, kj) * scale;
        }
        for rj in row.iter_mut().take(n).skip(limit) {
            *rj = f32::NEG_INFINITY;
        }
        softmax_inplace(&mut row[..n]);
    }
}

/// Forward + backward in one call (for the Figure 1 fwd/bwd benchmark).
/// Returns (out, dq, dk, dv) given upstream gradient g[n,m].
#[allow(clippy::too_many_arguments)]
pub fn forward_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: &[f32],
    n: usize,
    d: usize,
    m: usize,
    causal: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f32).sqrt();
    let mut w = vec![0.0f32; n * n];
    weights_into(&mut w, q, k, n, d, causal);
    let mut out = vec![0.0f32; n * m];
    matmul_into(&mut out, &w, v, n, n, m);

    // dv = W^T g
    let mut dv = vec![0.0f32; n * m];
    for i in 0..n {
        let wi = &w[i * n..(i + 1) * n];
        let gi = &g[i * m..(i + 1) * m];
        for (j, &wij) in wi.iter().enumerate() {
            if wij != 0.0 {
                crate::tensor::axpy(&mut dv[j * m..(j + 1) * m], wij, gi);
            }
        }
    }

    // dW = g v^T ; dlogits = W ∘ (dW - rowsum(dW ∘ W))
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dwrow = vec![0.0f32; n];
    for i in 0..n {
        let gi = &g[i * m..(i + 1) * m];
        let wi = &w[i * n..(i + 1) * n];
        let limit = if causal { i + 1 } else { n };
        // dW_ij = g_i . v_j
        for j in 0..limit {
            dwrow[j] = crate::tensor::dot(gi, &v[j * m..(j + 1) * m]);
        }
        let dot_ww: f32 = (0..limit).map(|j| dwrow[j] * wi[j]).sum();
        // dlogits_ij
        for j in 0..limit {
            let dl = wi[j] * (dwrow[j] - dot_ww) * scale;
            if dl != 0.0 {
                crate::tensor::axpy(&mut dq[i * d..(i + 1) * d], dl, &k[j * d..(j + 1) * d]);
                crate::tensor::axpy(&mut dk[j * d..(j + 1) * d], dl, &q[i * d..(i + 1) * d]);
            }
        }
    }
    (out, dq, dk, dv)
}

/// The KV-cache view over B decode lanes, structure-of-arrays.
///
/// The softmax counterpart of
/// [`super::linear::BatchedLinearAttnState`]: lane r's state is its
/// appended K/V rows (`[len_r, d]` / `[len_r, m]` inside a stripe
/// reserved at `max_tokens` rows) plus the cursor `len_r`. Rows
/// `0..rows` are live and contiguous; the serving engine maps decode
/// slots onto lanes and keeps them dense with [`Self::push_row`] /
/// [`Self::swap_remove_row`], exactly as it does for the linear state.
///
/// The contrast the paper's Tables 4/5 measure lives here: where the
/// linear lane is a fixed `[d, m] + [d]` block updated in O(d·m) per
/// token, a softmax lane grows by one `(k, v)` row per token and each
/// step attends over the whole cache — O(t·d) at position t, O(N) bytes
/// after N tokens. All per-lane capacity is reserved at construction
/// (`cap · max_tokens` rows), so appending during a serving tick never
/// allocates.
#[derive(Clone, Debug)]
pub struct BatchedKvCache {
    pub d: usize,
    pub m: usize,
    cap: usize,
    max_tokens: usize,
    rows: usize,
    /// `[cap]` — tokens cached per lane
    len: Vec<usize>,
    /// `[cap, max_tokens, d]` — appended key rows
    k: Vec<f32>,
    /// `[cap, max_tokens, m]` — appended value rows
    v: Vec<f32>,
    // preallocated attention-weight scratch, [max_tokens]
    logits: Vec<f32>,
}

impl BatchedKvCache {
    pub fn new(cap: usize, d: usize, m: usize, max_tokens: usize) -> Self {
        assert!(cap >= 1);
        assert!(max_tokens >= 1);
        BatchedKvCache {
            d,
            m,
            cap,
            max_tokens,
            rows: 0,
            len: vec![0; cap],
            k: vec![0.0; cap * max_tokens * d],
            v: vec![0.0; cap * max_tokens * m],
            logits: vec![0.0; max_tokens],
        }
    }

    /// Live lanes.
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Token capacity of each lane (reserved up front).
    pub fn max_tokens(&self) -> usize {
        self.max_tokens
    }

    /// Tokens currently cached in lane `r`.
    pub fn lane_len(&self, r: usize) -> usize {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        self.len[r]
    }

    /// Append an empty lane; returns its row index, or `None` at capacity.
    pub fn push_row(&mut self) -> Option<usize> {
        if self.rows == self.cap {
            return None;
        }
        let r = self.rows;
        self.len[r] = 0;
        self.rows += 1;
        Some(r)
    }

    /// Swap lanes `a` and `b` (cached rows and cursors). Costs
    /// O(max(len_a, len_b)·(d+m)) — only the live prefixes move; rows
    /// past a lane's cursor are never read. The serving engine uses this
    /// to keep decoding lanes as a contiguous prefix while later lanes
    /// are still mid-prefill.
    pub fn swap_rows(&mut self, a: usize, b: usize) {
        assert!(a < self.rows && b < self.rows, "swap_rows out of {} live lanes", self.rows);
        if a == b {
            return;
        }
        let (d, m) = (self.d, self.m);
        let stride_k = self.max_tokens * d;
        let stride_v = self.max_tokens * m;
        let live = self.len[a].max(self.len[b]);
        for t in 0..live * d {
            self.k.swap(a * stride_k + t, b * stride_k + t);
        }
        for t in 0..live * m {
            self.v.swap(a * stride_v + t, b * stride_v + t);
        }
        self.len.swap(a, b);
    }

    /// Free lane `r`, compacting by moving the last lane into its place.
    /// Returns the index the moved lane previously had (`None` if `r` was
    /// already last) so callers can fix their lane maps.
    pub fn swap_remove_row(&mut self, r: usize) -> Option<usize> {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        let last = self.rows - 1;
        self.rows = last;
        if r == last {
            return None;
        }
        let (d, m) = (self.d, self.m);
        let stride_k = self.max_tokens * d;
        let stride_v = self.max_tokens * m;
        let live = self.len[last];
        self.k
            .copy_within(last * stride_k..last * stride_k + live * d, r * stride_k);
        self.v
            .copy_within(last * stride_v..last * stride_v + live * m, r * stride_v);
        self.len[r] = live;
        Some(last)
    }

    /// Bytes held by the live lanes *at their current lengths* — grows
    /// with every cached token, unlike the constant-size linear state
    /// (this is what Table 4 contrasts).
    pub fn state_bytes(&self) -> usize {
        (0..self.rows)
            .map(|r| self.len[r] * (self.d + self.m) * 4)
            .sum()
    }

    /// Floats in lane `r`'s snapshot: its `[len_r, d]` key rows followed
    /// by its `[len_r, m]` value rows (the layout [`Self::export_row`]
    /// writes and [`Self::import_row`] expects). Unlike the linear
    /// state's fixed-size snapshot, this grows with the lane's cursor.
    pub fn snapshot_len(&self, r: usize) -> usize {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        self.len[r] * (self.d + self.m)
    }

    /// Copy lane `r`'s cached rows into `out` (`[snapshot_len(r)]`: k
    /// rows row-major, then v rows). The lane itself is untouched; the
    /// copy is the exact f32 bits of the cache, so importing it later
    /// resumes decoding bit-identically.
    pub fn export_row(&self, r: usize, out: &mut [f32]) {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        let (d, m) = (self.d, self.m);
        let t = self.len[r];
        assert_eq!(out.len(), t * (d + m), "snapshot buffer has the wrong length");
        let kbase = r * self.max_tokens * d;
        let vbase = r * self.max_tokens * m;
        out[..t * d].copy_from_slice(&self.k[kbase..kbase + t * d]);
        out[t * d..].copy_from_slice(&self.v[vbase..vbase + t * m]);
    }

    /// Overwrite lane `r`'s cache from a buffer written by
    /// [`Self::export_row`] holding `tokens` cached positions. Bitwise:
    /// after the import the lane is indistinguishable from the lane the
    /// snapshot was taken from.
    pub fn import_row(&mut self, r: usize, tokens: usize, snap: &[f32]) {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        assert!(
            tokens <= self.max_tokens,
            "snapshot of {tokens} tokens exceeds lane capacity {}",
            self.max_tokens
        );
        let (d, m) = (self.d, self.m);
        assert_eq!(snap.len(), tokens * (d + m), "snapshot buffer has the wrong length");
        let kbase = r * self.max_tokens * d;
        let vbase = r * self.max_tokens * m;
        self.k[kbase..kbase + tokens * d].copy_from_slice(&snap[..tokens * d]);
        self.v[vbase..vbase + tokens * m].copy_from_slice(&snap[tokens * d..]);
        self.len[r] = tokens;
    }

    /// Append `(k, v)` to lane `r` and attend `q` over the whole cache.
    /// Replays exactly the float-op order of the quadratic
    /// [`forward`] recompute's last row: logits in append order, one
    /// stable softmax, value accumulation in append order skipping exact
    /// zeros (matching `matmul_into`'s zero-coefficient skip), so the
    /// incremental path is bit-identical to recomputing the prefix.
    // lintra: bitwise-critical
    fn step_lane(&mut self, r: usize, q: &[f32], k: &[f32], v: &[f32], out: &mut [f32]) {
        let (d, m) = (self.d, self.m);
        debug_assert_eq!(q.len(), d);
        debug_assert!(self.len[r] < self.max_tokens, "KV cache capacity exceeded");
        let kbase = r * self.max_tokens * d;
        let vbase = r * self.max_tokens * m;
        let cur = self.len[r];
        self.k[kbase + cur * d..kbase + (cur + 1) * d].copy_from_slice(k);
        self.v[vbase + cur * m..vbase + (cur + 1) * m].copy_from_slice(v);
        self.len[r] = cur + 1;

        let scale = 1.0 / (d as f32).sqrt();
        let t = cur + 1;
        for j in 0..t {
            self.logits[j] = dot(q, &self.k[kbase + j * d..kbase + (j + 1) * d]) * scale;
        }
        softmax_inplace(&mut self.logits[..t]);
        out.fill(0.0);
        for j in 0..t {
            let w = self.logits[j];
            if w != 0.0 {
                axpy(out, w, &self.v[vbase + j * m..vbase + (j + 1) * m]);
            }
        }
    }

    /// Absorb a chunk of `n` tokens into lane `r`'s cache — the prefill
    /// path. `q, k: [n, d]`, `v, out: [n, m]`; `out` receives the chunk's
    /// attention outputs. One call ingests one chunk; the carried rows
    /// and cursor make successive calls (and a following
    /// [`Self::step_batch`] decode) continue the same sequence. The
    /// per-token update IS the step path, so prefilling a prompt is
    /// bit-identical to feeding it one tick at a time.
    // lintra: bitwise-critical
    pub fn prefill_row(
        &mut self,
        r: usize,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        n: usize,
        out: &mut [f32],
    ) {
        assert!(r < self.rows, "lane {r} out of {} live lanes", self.rows);
        let (d, m) = (self.d, self.m);
        assert_eq!(q.len(), n * d);
        assert_eq!(k.len(), n * d);
        assert_eq!(v.len(), n * m);
        assert_eq!(out.len(), n * m);
        for i in 0..n {
            let (qi, ki) = (&q[i * d..(i + 1) * d], &k[i * d..(i + 1) * d]);
            let vi = &v[i * m..(i + 1) * m];
            self.step_lane(r, qi, ki, vi, &mut out[i * m..(i + 1) * m]);
        }
    }

    /// One decode step for the first `q.len() / d` live lanes. `q, k:
    /// [b, d]`, `v, out: [b, m]` for any `b <= rows`; lanes `b..rows`
    /// are left untouched (the serving engine keeps lanes that are still
    /// mid-prefill in that suffix). Lanes are independent and each
    /// lane's float-op order never depends on `b`, so stepping a prefix
    /// is bit-identical to stepping the same lanes full-width. The
    /// attention core stays serial over lanes: per-lane work is
    /// O(t·(d+m)) next to the session's pooled `[b, ·]` GEMMs, and a
    /// serial core is trivially thread-count-invariant.
    // lintra: bitwise-critical
    pub fn step_batch(&mut self, q: &[f32], k: &[f32], v: &[f32], out: &mut [f32]) {
        let (d, m) = (self.d, self.m);
        assert_eq!(q.len() % d, 0, "q is not [b, d]");
        let b = q.len() / d;
        assert!(b <= self.rows, "stepping {b} lanes of {} live", self.rows);
        assert_eq!(k.len(), b * d);
        assert_eq!(v.len(), b * m);
        assert_eq!(out.len(), b * m);
        for r in 0..b {
            let (qi, ki) = (&q[r * d..(r + 1) * d], &k[r * d..(r + 1) * d]);
            let vi = &v[r * m..(r + 1) * m];
            self.step_lane(r, qi, ki, vi, &mut out[r * m..(r + 1) * m]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand(n: usize, rng: &mut Rng) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn rows_are_convex_combinations() {
        let (n, d, m) = (16, 8, 8);
        let mut rng = Rng::new(0);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut out = vec![0.0; n * m];
        forward(&q, &k, &v, n, d, m, false, &mut out);
        let vmax = v.iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.iter().cloned().fold(f32::MAX, f32::min);
        assert!(out.iter().all(|&o| o <= vmax + 1e-4 && o >= vmin - 1e-4));
    }

    #[test]
    fn causal_first_row_is_v0() {
        let (n, d, m) = (8, 4, 4);
        let mut rng = Rng::new(1);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut out = vec![0.0; n * m];
        forward(&q, &k, &v, n, d, m, true, &mut out);
        for j in 0..m {
            assert!((out[j] - v[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn causality_perturbation() {
        let (n, d, m) = (12, 4, 4);
        let mut rng = Rng::new(2);
        let (q, mut k, mut v) = (
            rand(n * d, &mut rng),
            rand(n * d, &mut rng),
            rand(n * m, &mut rng),
        );
        let mut base = vec![0.0; n * m];
        forward(&q, &k, &v, n, d, m, true, &mut base);
        // perturb the last position
        for x in &mut k[(n - 1) * d..] {
            *x += 3.0;
        }
        for x in &mut v[(n - 1) * m..] {
            *x -= 2.0;
        }
        let mut pert = vec![0.0; n * m];
        forward(&q, &k, &v, n, d, m, true, &mut pert);
        for i in 0..(n - 1) * m {
            assert!((base[i] - pert[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (n, d, m) = (6, 3, 3);
        let mut rng = Rng::new(3);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let g = rand(n * m, &mut rng);
        let (_, dq, dk, dv) = forward_backward(&q, &k, &v, &g, n, d, m, true);

        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f32 {
            let mut out = vec![0.0; n * m];
            forward(q, k, v, n, d, m, true, &mut out);
            out.iter().zip(&g).map(|(o, gg)| o * gg).sum()
        };
        let eps = 1e-3;
        let check = |analytic: &[f32], which: usize| {
            for idx in [0usize, 5, analytic.len() - 1] {
                let (mut qp, mut kp, mut vp) = (q.clone(), k.clone(), v.clone());
                let target = match which {
                    0 => &mut qp,
                    1 => &mut kp,
                    _ => &mut vp,
                };
                target[idx] += eps;
                let up = loss(&qp, &kp, &vp);
                let target = match which {
                    0 => &mut qp,
                    1 => &mut kp,
                    _ => &mut vp,
                };
                target[idx] -= 2.0 * eps;
                let down = loss(&qp, &kp, &vp);
                let fd = (up - down) / (2.0 * eps);
                assert!(
                    (fd - analytic[idx]).abs() < 2e-2,
                    "which={which} idx={idx}: fd={fd} analytic={}",
                    analytic[idx]
                );
            }
        };
        check(&dq, 0);
        check(&dk, 1);
        check(&dv, 2);
    }

    // --- BatchedKvCache: the serving-engine lane discipline ---

    /// Step one lane of a batched cache alongside the quadratic oracle.
    fn oracle_rows(q: &[f32], k: &[f32], v: &[f32], n: usize, d: usize, m: usize) -> Vec<f32> {
        let mut full = vec![0.0; n * m];
        forward(q, k, v, n, d, m, true, &mut full);
        full
    }

    #[test]
    fn batched_step_is_bitwise_quadratic_recompute() {
        // the differential contract: the incremental KV step must
        // reproduce the exact bits of recomputing the whole prefix
        let (n, d, m) = (24, 8, 8);
        let mut rng = Rng::new(10);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let full = oracle_rows(&q, &k, &v, n, d, m);

        let mut cache = BatchedKvCache::new(1, d, m, n);
        cache.push_row().unwrap();
        let mut out = vec![0.0; m];
        for i in 0..n {
            cache.step_batch(
                &q[i * d..(i + 1) * d],
                &k[i * d..(i + 1) * d],
                &v[i * m..(i + 1) * m],
                &mut out,
            );
            for e in 0..m {
                assert_eq!(
                    full[i * m + e].to_bits(),
                    out[e].to_bits(),
                    "bitwise divergence at position {i}, dim {e}"
                );
            }
        }
    }

    #[test]
    fn batched_lanes_match_independent_scalar_caches() {
        let (b, steps, d, m) = (5, 12, 8, 8);
        let mut rng = Rng::new(11);
        let mut batched = BatchedKvCache::new(b, d, m, steps);
        let mut scalars: Vec<_> = (0..b)
            .map(|_| super::super::stateful_softmax::KvCache::new(d, m, steps))
            .collect();
        for _ in 0..b {
            batched.push_row().unwrap();
        }
        let mut out = vec![0.0; b * m];
        let mut sout = vec![0.0; m];
        for _ in 0..steps {
            let q = rand(b * d, &mut rng);
            let k = rand(b * d, &mut rng);
            let v = rand(b * m, &mut rng);
            batched.step_batch(&q, &k, &v, &mut out);
            for (r, scalar) in scalars.iter_mut().enumerate() {
                scalar.step(
                    &q[r * d..(r + 1) * d],
                    &k[r * d..(r + 1) * d],
                    &v[r * m..(r + 1) * m],
                    &mut sout,
                );
                assert_eq!(
                    out[r * m..(r + 1) * m]
                        .iter()
                        .map(|x| x.to_bits())
                        .collect::<Vec<_>>(),
                    sout.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "lane {r} diverged from its scalar cache"
                );
            }
        }
    }

    #[test]
    fn prefill_row_is_bitwise_stepwise() {
        let (n, d, m) = (20, 8, 8);
        let mut rng = Rng::new(12);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));

        let mut stepped = BatchedKvCache::new(1, d, m, n);
        stepped.push_row().unwrap();
        let mut step_out = vec![0.0; n * m];
        for i in 0..n {
            let (s, e) = (i * m, (i + 1) * m);
            let mut row = vec![0.0; m];
            stepped.step_batch(
                &q[i * d..(i + 1) * d],
                &k[i * d..(i + 1) * d],
                &v[s..e],
                &mut row,
            );
            step_out[s..e].copy_from_slice(&row);
        }

        let mut prefilled = BatchedKvCache::new(1, d, m, n);
        prefilled.push_row().unwrap();
        let mut pre_out = vec![0.0; n * m];
        prefilled.prefill_row(0, &q, &k, &v, n, &mut pre_out);

        assert_eq!(
            step_out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            pre_out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
        assert_eq!(stepped.lane_len(0), prefilled.lane_len(0));
    }

    #[test]
    fn swap_remove_compaction_preserves_survivors() {
        let (b, d, m, steps) = (4, 4, 4, 6);
        let mut rng = Rng::new(13);
        let mut cache = BatchedKvCache::new(b, d, m, steps + 4);
        for _ in 0..b {
            cache.push_row().unwrap();
        }
        // give each lane a distinct trajectory
        let q = rand(b * d, &mut rng);
        let k = rand(b * d, &mut rng);
        let v = rand(b * m, &mut rng);
        let mut out = vec![0.0; b * m];
        for _ in 0..steps {
            cache.step_batch(&q, &k, &v, &mut out);
        }
        // snapshot survivors, remove lane 1 (lane 3 moves into its slot)
        let mut want2 = vec![0.0; cache.snapshot_len(2)];
        cache.export_row(2, &mut want2);
        let mut want3 = vec![0.0; cache.snapshot_len(3)];
        cache.export_row(3, &mut want3);
        assert_eq!(cache.swap_remove_row(1), Some(3));
        assert_eq!(cache.rows(), 3);
        let mut got1 = vec![0.0; cache.snapshot_len(1)];
        cache.export_row(1, &mut got1);
        let mut got2 = vec![0.0; cache.snapshot_len(2)];
        cache.export_row(2, &mut got2);
        assert_eq!(got1, want3, "moved lane must carry its rows exactly");
        assert_eq!(got2, want2, "untouched lane must not move");
    }

    #[test]
    fn swap_rows_exchanges_lane_trajectories_exactly() {
        let (d, m, n) = (4, 4, 8);
        let mut rng = Rng::new(14);
        let mut cache = BatchedKvCache::new(2, d, m, n + 2);
        cache.push_row().unwrap();
        cache.push_row().unwrap();
        let q = rand(2 * d, &mut rng);
        let k = rand(2 * d, &mut rng);
        let v = rand(2 * m, &mut rng);
        let mut out = vec![0.0; 2 * m];
        // ragged lengths: lane 0 sees n tokens, lane 1 only n/2
        for i in 0..n {
            if i < n / 2 {
                cache.step_batch(&q, &k, &v, &mut out);
            } else {
                cache.step_batch(&q[..d], &k[..d], &v[..m], &mut out[..m]);
            }
        }
        let mut snap0 = vec![0.0; cache.snapshot_len(0)];
        cache.export_row(0, &mut snap0);
        let mut snap1 = vec![0.0; cache.snapshot_len(1)];
        cache.export_row(1, &mut snap1);
        cache.swap_rows(0, 1);
        assert_eq!(cache.lane_len(0), n / 2);
        assert_eq!(cache.lane_len(1), n);
        let mut got0 = vec![0.0; cache.snapshot_len(0)];
        cache.export_row(0, &mut got0);
        let mut got1 = vec![0.0; cache.snapshot_len(1)];
        cache.export_row(1, &mut got1);
        assert_eq!(got0, snap1);
        assert_eq!(got1, snap0);
    }

    #[test]
    fn prefix_step_leaves_suffix_lanes_untouched() {
        let (b, d, m) = (3, 4, 4);
        let mut rng = Rng::new(15);
        let mut cache = BatchedKvCache::new(b, d, m, 8);
        for _ in 0..b {
            cache.push_row().unwrap();
        }
        let q = rand(b * d, &mut rng);
        let k = rand(b * d, &mut rng);
        let v = rand(b * m, &mut rng);
        let mut out = vec![0.0; b * m];
        cache.step_batch(&q, &k, &v, &mut out);
        let mut before = vec![0.0; cache.snapshot_len(2)];
        cache.export_row(2, &mut before);
        // step only the first two lanes
        cache.step_batch(&q[..2 * d], &k[..2 * d], &v[..2 * m], &mut out[..2 * m]);
        assert_eq!(cache.lane_len(2), 1, "suffix lane must not advance");
        let mut after = vec![0.0; cache.snapshot_len(2)];
        cache.export_row(2, &mut after);
        assert_eq!(before, after);
    }

    #[test]
    fn export_import_row_resumes_bitwise() {
        let (d, m, n) = (8, 8, 16);
        let mut rng = Rng::new(16);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let cut = n / 2;

        // uninterrupted reference
        let mut full = BatchedKvCache::new(1, d, m, n);
        full.push_row().unwrap();
        let mut want = vec![0.0; n * m];
        full.prefill_row(0, &q, &k, &v, n, &mut want);

        // run to the cut, snapshot, restore into a fresh cache, continue
        let mut donor = BatchedKvCache::new(1, d, m, n);
        donor.push_row().unwrap();
        let mut tmp = vec![0.0; cut * m];
        donor.prefill_row(0, &q[..cut * d], &k[..cut * d], &v[..cut * m], cut, &mut tmp);
        let mut snap = vec![0.0; donor.snapshot_len(0)];
        donor.export_row(0, &mut snap);

        let mut resumed = BatchedKvCache::new(1, d, m, n);
        resumed.push_row().unwrap();
        resumed.import_row(0, cut, &snap);
        let rest = n - cut;
        let mut got = vec![0.0; rest * m];
        resumed.prefill_row(
            0,
            &q[cut * d..],
            &k[cut * d..],
            &v[cut * m..],
            rest,
            &mut got,
        );
        assert_eq!(
            want[cut * m..].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    #[should_panic(expected = "wrong length")]
    fn import_row_rejects_mismatched_snapshot() {
        let mut cache = BatchedKvCache::new(1, 4, 4, 8);
        cache.push_row().unwrap();
        let snap = vec![0.0; 3];
        cache.import_row(0, 2, &snap);
    }

    #[test]
    fn state_bytes_track_cached_tokens() {
        let (d, m) = (4, 4);
        let mut cache = BatchedKvCache::new(2, d, m, 8);
        cache.push_row().unwrap();
        cache.push_row().unwrap();
        assert_eq!(cache.state_bytes(), 0);
        let q = vec![0.1; d];
        let mut out = vec![0.0; m];
        cache.step_batch(&q, &q, &q, &mut out);
        assert_eq!(cache.state_bytes(), (d + m) * 4, "one token in one lane");
        let q2 = vec![0.1; 2 * d];
        let mut out2 = vec![0.0; 2 * m];
        cache.step_batch(&q2, &q2, &q2, &mut out2);
        assert_eq!(cache.state_bytes(), 3 * (d + m) * 4);
        cache.swap_remove_row(0);
        assert_eq!(cache.state_bytes(), (d + m) * 4, "survivor has one token");
    }

    #[test]
    fn push_row_at_capacity_returns_none() {
        let mut cache = BatchedKvCache::new(2, 4, 4, 4);
        assert_eq!(cache.push_row(), Some(0));
        assert_eq!(cache.push_row(), Some(1));
        assert_eq!(cache.push_row(), None);
    }
}
