//! Full softmax attention (eq. 2) — the vanilla-transformer baseline.
//!
//! Materializes the N x N weight matrix; O(N²·max(D,M)) time and O(N²)
//! memory, which is exactly the wall Figure 1 measures. The backward pass
//! implements the standard softmax-attention vjp, recomputing W.

use crate::tensor::{matmul_into, softmax_inplace};

/// out[n,m] = softmax(q k^T / sqrt(d)) v, optionally causal.
pub fn forward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    m: usize,
    causal: bool,
    out: &mut [f32],
) {
    assert_eq!(q.len(), n * d);
    assert_eq!(k.len(), n * d);
    assert_eq!(v.len(), n * m);
    assert_eq!(out.len(), n * m);
    let mut w = vec![0.0f32; n * n];
    weights_into(&mut w, q, k, n, d, causal);
    matmul_into(out, &w, v, n, n, m);
}

/// Compute the softmax weight matrix into `w`.
fn weights_into(w: &mut [f32], q: &[f32], k: &[f32], n: usize, d: usize, causal: bool) {
    let scale = 1.0 / (d as f32).sqrt();
    // w = q k^T (k is [n, d], we need k^T [d, n]: loop directly)
    for i in 0..n {
        let qi = &q[i * d..(i + 1) * d];
        let row = &mut w[i * n..(i + 1) * n];
        let limit = if causal { i + 1 } else { n };
        for (j, rj) in row.iter_mut().enumerate().take(limit) {
            let kj = &k[j * d..(j + 1) * d];
            *rj = crate::tensor::dot(qi, kj) * scale;
        }
        for rj in row.iter_mut().take(n).skip(limit) {
            *rj = f32::NEG_INFINITY;
        }
        softmax_inplace(&mut row[..n]);
    }
}

/// Forward + backward in one call (for the Figure 1 fwd/bwd benchmark).
/// Returns (out, dq, dk, dv) given upstream gradient g[n,m].
#[allow(clippy::too_many_arguments)]
pub fn forward_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: &[f32],
    n: usize,
    d: usize,
    m: usize,
    causal: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let scale = 1.0 / (d as f32).sqrt();
    let mut w = vec![0.0f32; n * n];
    weights_into(&mut w, q, k, n, d, causal);
    let mut out = vec![0.0f32; n * m];
    matmul_into(&mut out, &w, v, n, n, m);

    // dv = W^T g
    let mut dv = vec![0.0f32; n * m];
    for i in 0..n {
        let wi = &w[i * n..(i + 1) * n];
        let gi = &g[i * m..(i + 1) * m];
        for (j, &wij) in wi.iter().enumerate() {
            if wij != 0.0 {
                crate::tensor::axpy(&mut dv[j * m..(j + 1) * m], wij, gi);
            }
        }
    }

    // dW = g v^T ; dlogits = W ∘ (dW - rowsum(dW ∘ W))
    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dwrow = vec![0.0f32; n];
    for i in 0..n {
        let gi = &g[i * m..(i + 1) * m];
        let wi = &w[i * n..(i + 1) * n];
        let limit = if causal { i + 1 } else { n };
        // dW_ij = g_i . v_j
        for j in 0..limit {
            dwrow[j] = crate::tensor::dot(gi, &v[j * m..(j + 1) * m]);
        }
        let dot_ww: f32 = (0..limit).map(|j| dwrow[j] * wi[j]).sum();
        // dlogits_ij
        for j in 0..limit {
            let dl = wi[j] * (dwrow[j] - dot_ww) * scale;
            if dl != 0.0 {
                crate::tensor::axpy(&mut dq[i * d..(i + 1) * d], dl, &k[j * d..(j + 1) * d]);
                crate::tensor::axpy(&mut dk[j * d..(j + 1) * d], dl, &q[i * d..(i + 1) * d]);
            }
        }
    }
    (out, dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand(n: usize, rng: &mut Rng) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn rows_are_convex_combinations() {
        let (n, d, m) = (16, 8, 8);
        let mut rng = Rng::new(0);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut out = vec![0.0; n * m];
        forward(&q, &k, &v, n, d, m, false, &mut out);
        let vmax = v.iter().cloned().fold(f32::MIN, f32::max);
        let vmin = v.iter().cloned().fold(f32::MAX, f32::min);
        assert!(out.iter().all(|&o| o <= vmax + 1e-4 && o >= vmin - 1e-4));
    }

    #[test]
    fn causal_first_row_is_v0() {
        let (n, d, m) = (8, 4, 4);
        let mut rng = Rng::new(1);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let mut out = vec![0.0; n * m];
        forward(&q, &k, &v, n, d, m, true, &mut out);
        for j in 0..m {
            assert!((out[j] - v[j]).abs() < 1e-5);
        }
    }

    #[test]
    fn causality_perturbation() {
        let (n, d, m) = (12, 4, 4);
        let mut rng = Rng::new(2);
        let (q, mut k, mut v) = (
            rand(n * d, &mut rng),
            rand(n * d, &mut rng),
            rand(n * m, &mut rng),
        );
        let mut base = vec![0.0; n * m];
        forward(&q, &k, &v, n, d, m, true, &mut base);
        // perturb the last position
        for x in &mut k[(n - 1) * d..] {
            *x += 3.0;
        }
        for x in &mut v[(n - 1) * m..] {
            *x -= 2.0;
        }
        let mut pert = vec![0.0; n * m];
        forward(&q, &k, &v, n, d, m, true, &mut pert);
        for i in 0..(n - 1) * m {
            assert!((base[i] - pert[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let (n, d, m) = (6, 3, 3);
        let mut rng = Rng::new(3);
        let (q, k, v) = (rand(n * d, &mut rng), rand(n * d, &mut rng), rand(n * m, &mut rng));
        let g = rand(n * m, &mut rng);
        let (_, dq, dk, dv) = forward_backward(&q, &k, &v, &g, n, d, m, true);

        let loss = |q: &[f32], k: &[f32], v: &[f32]| -> f32 {
            let mut out = vec![0.0; n * m];
            forward(q, k, v, n, d, m, true, &mut out);
            out.iter().zip(&g).map(|(o, gg)| o * gg).sum()
        };
        let eps = 1e-3;
        let check = |analytic: &[f32], which: usize| {
            for idx in [0usize, 5, analytic.len() - 1] {
                let (mut qp, mut kp, mut vp) = (q.clone(), k.clone(), v.clone());
                let target = match which {
                    0 => &mut qp,
                    1 => &mut kp,
                    _ => &mut vp,
                };
                target[idx] += eps;
                let up = loss(&qp, &kp, &vp);
                let target = match which {
                    0 => &mut qp,
                    1 => &mut kp,
                    _ => &mut vp,
                };
                target[idx] -= 2.0 * eps;
                let down = loss(&qp, &kp, &vp);
                let fd = (up - down) / (2.0 * eps);
                assert!(
                    (fd - analytic[idx]).abs() < 2e-2,
                    "which={which} idx={idx}: fd={fd} analytic={}",
                    analytic[idx]
                );
            }
        };
        check(&dq, 0);
        check(&dk, 1);
        check(&dv, 2);
    }
}
