//! LSH attention (Reformer; Kitaev et al. 2020) — the paper's baseline,
//! implemented as the real sort→chunk→attend pipeline.
//!
//! Per hashing round:
//!   1. angular LSH: bucket(x) = argmax([xR; -xR]) with a random rotation R,
//!   2. stable sort positions by (bucket, position),
//!   3. cut the sorted order into chunks of `chunk` positions,
//!   4. each position attends within its chunk and the previous chunk,
//!      causally masked by *original* position,
//! then rounds are combined weighted by their softmax mass (the round that
//! found the query's true neighbours gets the weight).
//!
//! Unlike the jax `lsh_attention.py` (dense-mask variant used only for the
//! convergence figure), this implementation has the true ~O(N · chunk)
//! compute profile and is what the speed/memory benches (Figure 1, Tables
//! 1-2 lsh rows) run.
//!
//! `forward_backward` recomputes per-chunk weights and backpropagates the
//! local attention exactly; the round-combination weights are treated as
//! constants (straight-through), which preserves the cost profile Figure 1
//! measures. The models *trained* with lsh use the jax path.

use crate::rng::Rng;
use crate::tensor::dot;

/// LSH attention configuration.
#[derive(Clone, Copy, Debug)]
pub struct LshConfig {
    pub rounds: usize,
    pub buckets: usize,
    pub chunk: usize,
    pub seed: u64,
}

impl Default for LshConfig {
    fn default() -> Self {
        LshConfig {
            rounds: 1,
            buckets: 32,
            chunk: 32,
            seed: 0,
        }
    }
}

/// Rotation bank: rounds x [d, buckets/2], deterministic in (seed, d).
pub fn make_rotations(cfg: &LshConfig, d: usize) -> Vec<Vec<f32>> {
    assert!(cfg.buckets % 2 == 0, "angular LSH needs even bucket count");
    let mut rng = Rng::new(cfg.seed ^ 0x15ba_77f0);
    (0..cfg.rounds)
        .map(|_| rng.normal_vec(d * cfg.buckets / 2, 1.0))
        .collect()
}

/// Bucket ids for all n positions under one rotation.
fn bucket_ids(k: &[f32], n: usize, d: usize, rot: &[f32], buckets: usize) -> Vec<u32> {
    let half = buckets / 2;
    let mut ids = Vec::with_capacity(n);
    for i in 0..n {
        let ki = &k[i * d..(i + 1) * d];
        let mut best = 0usize;
        let mut best_v = f32::NEG_INFINITY;
        for b in 0..half {
            // proj = k_i . rot[:, b]
            let mut p = 0.0;
            for t in 0..d {
                p += ki[t] * rot[t * half + b];
            }
            if p > best_v {
                best_v = p;
                best = b;
            }
            if -p > best_v {
                best_v = -p;
                best = b + half;
            }
        }
        ids.push(best as u32);
    }
    ids
}

/// Sorted order (stable by bucket then position) and per-position chunk id.
fn sort_and_chunk(buckets_of: &[u32], chunk: usize) -> (Vec<usize>, Vec<usize>) {
    let n = buckets_of.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| (buckets_of[i], i)); // stable by construction
    let mut chunk_of = vec![0usize; n];
    for (rank, &pos) in order.iter().enumerate() {
        chunk_of[pos] = rank / chunk;
    }
    (order, chunk_of)
}

/// Multi-round LSH attention forward.
/// q, k: [n, d] (k doubles as the hashed vector — Reformer shares QK),
/// v: [n, m], out: [n, m]. Returns per-round outputs merged by mass.
#[allow(clippy::too_many_arguments)]
pub fn forward(
    cfg: &LshConfig,
    rotations: &[Vec<f32>],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    m: usize,
    causal: bool,
    out: &mut [f32],
) {
    assert_eq!(rotations.len(), cfg.rounds);
    let mut round_outs = vec![0.0f32; cfg.rounds * n * m];
    let mut round_mass = vec![f32::NEG_INFINITY; cfg.rounds * n];
    for (r, rot) in rotations.iter().enumerate() {
        round_forward(
            cfg,
            rot,
            q,
            k,
            v,
            n,
            d,
            m,
            causal,
            &mut round_outs[r * n * m..(r + 1) * n * m],
            &mut round_mass[r * n..(r + 1) * n],
        );
    }
    // combine rounds: softmax over per-round log mass, per position
    out.fill(0.0);
    for i in 0..n {
        let mut mx = f32::NEG_INFINITY;
        for r in 0..cfg.rounds {
            mx = mx.max(round_mass[r * n + i]);
        }
        let mut total = 0.0f32;
        let mut ws = vec![0.0f32; cfg.rounds];
        for r in 0..cfg.rounds {
            let w = (round_mass[r * n + i] - mx).exp();
            ws[r] = w;
            total += w;
        }
        for r in 0..cfg.rounds {
            let w = ws[r] / total;
            if w != 0.0 {
                crate::tensor::axpy(
                    &mut out[i * m..(i + 1) * m],
                    w,
                    &round_outs[r * n * m + i * m..r * n * m + (i + 1) * m],
                );
            }
        }
    }
}

/// One hashing round. Writes the round's output and per-position log-mass.
#[allow(clippy::too_many_arguments)]
fn round_forward(
    cfg: &LshConfig,
    rot: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    m: usize,
    causal: bool,
    out: &mut [f32],
    mass: &mut [f32],
) {
    let buckets = bucket_ids(k, n, d, rot, cfg.buckets);
    let (order, chunk_of) = sort_and_chunk(&buckets, cfg.chunk);
    let n_chunks = n.div_ceil(cfg.chunk);
    let scale = 1.0 / (d as f32).sqrt();

    // candidate list per chunk: positions in chunk c-1 and c (sorted order)
    let chunk_span = |c: usize| -> &[usize] {
        let lo = c.saturating_sub(1) * cfg.chunk;
        let hi = ((c + 1) * cfg.chunk).min(n);
        &order[lo..hi]
    };

    let mut logits: Vec<f32> = Vec::with_capacity(2 * cfg.chunk);
    for c in 0..n_chunks {
        let span = chunk_span(c);
        let own_lo = c * cfg.chunk;
        let own_hi = ((c + 1) * cfg.chunk).min(n);
        for &i in &order[own_lo..own_hi] {
            debug_assert_eq!(chunk_of[i], c);
            let qi = &q[i * d..(i + 1) * d];
            logits.clear();
            let mut mx = f32::NEG_INFINITY;
            for &j in span {
                let l = if causal && j > i {
                    f32::NEG_INFINITY
                } else if j == i && span.len() > 1 {
                    // Reformer: self-attention only as a last resort
                    -1e5
                } else {
                    dot(qi, &k[j * d..(j + 1) * d]) * scale
                };
                mx = mx.max(l);
                logits.push(l);
            }
            let mut denom = 0.0f32;
            for l in logits.iter_mut() {
                *l = (*l - mx).exp();
                denom += *l;
            }
            let orow = &mut out[i * m..(i + 1) * m];
            orow.fill(0.0);
            if denom > 0.0 {
                for (idx, &j) in span.iter().enumerate() {
                    let w = logits[idx] / denom;
                    if w != 0.0 {
                        crate::tensor::axpy(orow, w, &v[j * m..(j + 1) * m]);
                    }
                }
            }
            // log total mass (for round combination): mx + log denom
            mass[i] = if denom > 0.0 { mx + denom.ln() } else { f32::NEG_INFINITY };
        }
    }
}

/// Forward + backward for the Figure-1 cost sweep: exact within-round local
/// attention gradients; round-combination weights straight-through.
#[allow(clippy::too_many_arguments)]
pub fn forward_backward(
    cfg: &LshConfig,
    rotations: &[Vec<f32>],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    g: &[f32],
    n: usize,
    d: usize,
    m: usize,
    causal: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut out = vec![0.0f32; n * m];
    forward(cfg, rotations, q, k, v, n, d, m, causal, &mut out);

    let mut dq = vec![0.0f32; n * d];
    let mut dk = vec![0.0f32; n * d];
    let mut dv = vec![0.0f32; n * m];
    let scale = 1.0 / (d as f32).sqrt();
    let gscale = 1.0 / cfg.rounds as f32; // straight-through round average

    for rot in rotations {
        let buckets = bucket_ids(k, n, d, rot, cfg.buckets);
        let (order, _) = sort_and_chunk(&buckets, cfg.chunk);
        let n_chunks = n.div_ceil(cfg.chunk);
        let mut logits: Vec<f32> = Vec::with_capacity(2 * cfg.chunk);
        let mut dlog: Vec<f32> = Vec::with_capacity(2 * cfg.chunk);
        for c in 0..n_chunks {
            let lo = c.saturating_sub(1) * cfg.chunk;
            let hi = ((c + 1) * cfg.chunk).min(n);
            let span = &order[lo..hi];
            let own_lo = c * cfg.chunk;
            let own_hi = ((c + 1) * cfg.chunk).min(n);
            for &i in &order[own_lo..own_hi] {
                let qi = &q[i * d..(i + 1) * d];
                let gi = &g[i * m..(i + 1) * m];
                logits.clear();
                let mut mx = f32::NEG_INFINITY;
                for &j in span {
                    let l = if causal && j > i {
                        f32::NEG_INFINITY
                    } else if j == i && span.len() > 1 {
                        -1e5
                    } else {
                        dot(qi, &k[j * d..(j + 1) * d]) * scale
                    };
                    mx = mx.max(l);
                    logits.push(l);
                }
                let mut denom = 0.0f32;
                for l in logits.iter_mut() {
                    *l = (*l - mx).exp();
                    denom += *l;
                }
                if denom <= 0.0 {
                    continue;
                }
                for l in logits.iter_mut() {
                    *l /= denom;
                }
                // dW_j = g_i . v_j ; dlogits = w (dW - sum w dW)
                dlog.clear();
                let mut wd = 0.0f32;
                for (idx, &j) in span.iter().enumerate() {
                    let dwj = dot(gi, &v[j * m..(j + 1) * m]);
                    wd += logits[idx] * dwj;
                    dlog.push(dwj);
                }
                for (idx, &j) in span.iter().enumerate() {
                    let w = logits[idx];
                    if w == 0.0 {
                        continue;
                    }
                    crate::tensor::axpy(&mut dv[j * m..(j + 1) * m], w * gscale, gi);
                    let dl = w * (dlog[idx] - wd) * scale * gscale;
                    if dl != 0.0 {
                        crate::tensor::axpy(
                            &mut dq[i * d..(i + 1) * d],
                            dl,
                            &k[j * d..(j + 1) * d],
                        );
                        crate::tensor::axpy(&mut dk[j * d..(j + 1) * d], dl, qi);
                    }
                }
            }
        }
    }
    (out, dq, dk, dv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::softmax;
    use crate::rng::Rng;

    fn rand(n: usize, rng: &mut Rng) -> Vec<f32> {
        rng.normal_vec(n, 1.0)
    }

    #[test]
    fn bucket_ids_in_range_and_antipodal() {
        let cfg = LshConfig {
            buckets: 8,
            ..Default::default()
        };
        let rots = make_rotations(&cfg, 6);
        let mut rng = Rng::new(0);
        let k = rand(10 * 6, &mut rng);
        let ids = bucket_ids(&k, 10, 6, &rots[0], 8);
        assert!(ids.iter().all(|&b| b < 8));
        // x and -x land in complementary buckets
        let mut k2 = k.clone();
        for x in &mut k2[..6] {
            *x = -*x;
        }
        let ids2 = bucket_ids(&k2, 10, 6, &rots[0], 8);
        assert_ne!(ids[0], ids2[0]);
        assert_eq!((ids[0] + 4) % 8, ids2[0] % 8);
    }

    #[test]
    fn sort_is_stable_partition() {
        let buckets = vec![2u32, 0, 1, 0, 2, 1];
        let (order, chunk_of) = sort_and_chunk(&buckets, 2);
        assert_eq!(order, vec![1, 3, 2, 5, 0, 4]);
        assert_eq!(chunk_of[1], 0);
        assert_eq!(chunk_of[0], 2);
    }

    #[test]
    fn single_chunk_single_round_equals_full_softmax() {
        // chunk >= n and 1 round: candidate set = everything, so (up to the
        // self-exclusion handled below) LSH == full causal softmax.
        let (n, d, m) = (12, 8, 8);
        let mut rng = Rng::new(1);
        let q = rand(n * d, &mut rng);
        let k = rand(n * d, &mut rng);
        let v = rand(n * m, &mut rng);
        let cfg = LshConfig {
            rounds: 1,
            buckets: 4,
            chunk: n, // one chunk covers all
            seed: 0,
        };
        let rots = make_rotations(&cfg, d);
        let mut lsh_out = vec![0.0; n * m];
        forward(&cfg, &rots, &q, &k, &v, n, d, m, true, &mut lsh_out);
        let mut full = vec![0.0; n * m];
        softmax::forward(&q, &k, &v, n, d, m, true, &mut full);
        // positions i >= 1 (self is down-weighted in lsh, so compare where
        // self weight in full attention is small — use a generous tolerance
        // on later positions where 1/t self-mass is diluted)
        for i in 4..n {
            for e in 0..m {
                let a = lsh_out[i * m + e];
                let b = full[i * m + e];
                assert!((a - b).abs() < 0.6, "i={i} e={e}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn value_causality() {
        // future VALUES never leak backward (future keys may reshuffle
        // chunks — inherent to Reformer — so perturb v only)
        let (n, d, m) = (32, 8, 4);
        let mut rng = Rng::new(2);
        let q = rand(n * d, &mut rng);
        let k = rand(n * d, &mut rng);
        let mut v = rand(n * m, &mut rng);
        let cfg = LshConfig {
            rounds: 2,
            buckets: 8,
            chunk: 8,
            seed: 3,
        };
        let rots = make_rotations(&cfg, d);
        let mut base = vec![0.0; n * m];
        forward(&cfg, &rots, &q, &k, &v, n, d, m, true, &mut base);
        for x in &mut v[(n - 1) * m..] {
            *x += 10.0;
        }
        let mut pert = vec![0.0; n * m];
        forward(&cfg, &rots, &q, &k, &v, n, d, m, true, &mut pert);
        for i in 0..(n - 1) * m {
            assert!((base[i] - pert[i]).abs() < 1e-5, "leak at {i}");
        }
    }

    #[test]
    fn every_position_gets_output_mass() {
        let (n, d, m) = (64, 8, 8);
        let mut rng = Rng::new(4);
        let q = rand(n * d, &mut rng);
        let k = rand(n * d, &mut rng);
        let v: Vec<f32> = (0..n * m).map(|_| 1.0).collect(); // constant values
        let cfg = LshConfig {
            rounds: 1,
            buckets: 8,
            chunk: 16,
            seed: 5,
        };
        let rots = make_rotations(&cfg, d);
        let mut out = vec![0.0; n * m];
        forward(&cfg, &rots, &q, &k, &v, n, d, m, true, &mut out);
        // with constant v = 1, any valid attention average must be 1
        for i in 0..n {
            assert!(
                (out[i * m] - 1.0).abs() < 1e-4,
                "position {i} got mass {}",
                out[i * m]
            );
        }
    }

    #[test]
    fn backward_finite_differences_single_round() {
        let (n, d, m) = (10, 4, 4);
        let mut rng = Rng::new(6);
        let q = rand(n * d, &mut rng);
        let k = rand(n * d, &mut rng);
        let v = rand(n * m, &mut rng);
        let g = rand(n * m, &mut rng);
        let cfg = LshConfig {
            rounds: 1,
            buckets: 4,
            chunk: 4,
            seed: 7,
        };
        let rots = make_rotations(&cfg, d);
        let (_, _dq, _dk, dv) = forward_backward(&cfg, &rots, &q, &k, &v, &g, n, d, m, true);
        // check dv by finite differences (v does not affect hashing, so
        // the gradient is exact for v)
        let loss = |v: &[f32]| -> f32 {
            let mut out = vec![0.0; n * m];
            forward(&cfg, &rots, &q, &k, v, n, d, m, true, &mut out);
            out.iter().zip(&g).map(|(o, gg)| o * gg).sum()
        };
        let eps = 1e-3;
        for idx in [0usize, 7, n * m - 1] {
            let mut vp = v.clone();
            vp[idx] += eps;
            let up = loss(&vp);
            vp[idx] -= 2.0 * eps;
            let down = loss(&vp);
            let fd = (up - down) / (2.0 * eps);
            assert!(
                (fd - dv[idx]).abs() < 2e-2,
                "idx={idx}: fd={fd} analytic={}",
                dv[idx]
            );
        }
    }
}
