//! Stateful softmax decode — the "recurrent view of softmax" baseline of
//! the paper's supplementary §C.1 (Table 4).
//!
//! Keys and values are cached; each decode step attends over the whole
//! cache. Per-token cost is O(t·D) at position t (linear-in-position,
//! quadratic over a whole sequence), and the cache grows with the
//! sequence — the two contrasts against [`super::linear::LinearAttnState`].

use crate::tensor::{dot, softmax_inplace};

/// Per-head KV cache with preallocated capacity.
#[derive(Clone, Debug)]
pub struct KvCache {
    pub d: usize,
    pub m: usize,
    pub len: usize,
    k: Vec<f32>, // [cap, d]
    v: Vec<f32>, // [cap, m]
    logits: Vec<f32>,
}

impl KvCache {
    pub fn new(d: usize, m: usize, capacity: usize) -> Self {
        KvCache {
            d,
            m,
            len: 0,
            k: vec![0.0; capacity * d],
            v: vec![0.0; capacity * m],
            logits: vec![0.0; capacity],
        }
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Bytes held by the cache *at the current length* — grows with tokens
    /// (this is what Table 4 contrasts against the constant linear state).
    pub fn state_bytes(&self) -> usize {
        self.len * (self.d + self.m) * 4
    }

    /// One decode step: append (k, v), attend q over the cache.
    pub fn step(&mut self, q: &[f32], k: &[f32], v: &[f32], out: &mut [f32]) {
        debug_assert_eq!(q.len(), self.d);
        debug_assert!(self.len * self.d < self.k.len(), "KV cache capacity exceeded");
        let d = self.d;
        let m = self.m;
        self.k[self.len * d..(self.len + 1) * d].copy_from_slice(k);
        self.v[self.len * m..(self.len + 1) * m].copy_from_slice(v);
        self.len += 1;

        let scale = 1.0 / (d as f32).sqrt();
        let t = self.len;
        for j in 0..t {
            self.logits[j] = dot(q, &self.k[j * d..(j + 1) * d]) * scale;
        }
        softmax_inplace(&mut self.logits[..t]);
        out.fill(0.0);
        for j in 0..t {
            let w = self.logits[j];
            if w != 0.0 {
                crate::tensor::axpy(out, w, &self.v[j * m..(j + 1) * m]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::softmax;
    use crate::rng::Rng;

    #[test]
    fn stepwise_equals_full_causal_softmax() {
        let (n, d, m) = (20, 8, 8);
        let mut rng = Rng::new(0);
        let q = rng.normal_vec(n * d, 1.0);
        let k = rng.normal_vec(n * d, 1.0);
        let v = rng.normal_vec(n * m, 1.0);
        let mut full = vec![0.0; n * m];
        softmax::forward(&q, &k, &v, n, d, m, true, &mut full);

        let mut cache = KvCache::new(d, m, n);
        let mut out = vec![0.0; m];
        for i in 0..n {
            cache.step(
                &q[i * d..(i + 1) * d],
                &k[i * d..(i + 1) * d],
                &v[i * m..(i + 1) * m],
                &mut out,
            );
            for e in 0..m {
                assert!(
                    (full[i * m + e] - out[e]).abs() < 1e-4,
                    "divergence at {i},{e}"
                );
            }
        }
    }

    #[test]
    fn cache_grows_linearly() {
        let mut cache = KvCache::new(16, 16, 64);
        let q = vec![0.1; 16];
        let mut out = vec![0.0; 16];
        let mut prev = 0;
        for i in 0..64 {
            cache.step(&q, &q, &q, &mut out);
            let b = cache.state_bytes();
            assert!(b > prev, "cache must grow at step {i}");
            prev = b;
        }
        assert_eq!(prev, 64 * (16 + 16) * 4);
    }

    #[test]
    fn reset_reuses_capacity() {
        let mut cache = KvCache::new(4, 4, 8);
        let x = vec![0.5; 4];
        let mut out = vec![0.0; 4];
        for _ in 0..8 {
            cache.step(&x, &x, &x, &mut out);
        }
        cache.reset();
        assert_eq!(cache.len, 0);
        cache.step(&x, &x, &x, &mut out);
        // single entry: output must equal v exactly
        for e in 0..4 {
            assert!((out[e] - 0.5).abs() < 1e-6);
        }
    }
}
