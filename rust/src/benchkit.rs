//! Benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + timed iterations with robust statistics, per-run peak
//! memory accounting hooks, and table output in both human-readable
//! markdown and machine-readable CSV — every `rust/benches/*.rs` target
//! (one per paper table/figure) is built on this.

use std::time::{Duration, Instant};

/// Result statistics of one measured case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Items/sec at `items` per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }
}

/// Bench configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    /// Hard wall-clock cap for the measurement loop — long configurations
    /// (e.g. softmax at N=16384) stop early with however many iters ran.
    pub max_total: Duration,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup_iters: 2,
            measure_iters: 10,
            max_total: Duration::from_secs(20),
        }
    }
}

impl BenchOpts {
    pub fn quick() -> Self {
        BenchOpts {
            warmup_iters: 1,
            measure_iters: 3,
            max_total: Duration::from_secs(8),
        }
    }
}

/// Time a closure under the given options.
pub fn bench(name: &str, opts: BenchOpts, mut f: impl FnMut()) -> Measurement {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(opts.measure_iters);
    let start = Instant::now();
    for _ in 0..opts.measure_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if start.elapsed() > opts.max_total && !samples.is_empty() {
            break;
        }
    }
    summarize(name, samples)
}

/// Time a closure once (for very slow configurations).
pub fn bench_once(name: &str, mut f: impl FnMut()) -> Measurement {
    let t0 = Instant::now();
    f();
    summarize(name, vec![t0.elapsed()])
}

fn summarize(name: &str, mut samples: Vec<Duration>) -> Measurement {
    assert!(!samples.is_empty());
    samples.sort();
    let total: Duration = samples.iter().sum();
    let idx = |q: f64| ((samples.len() - 1) as f64 * q).round() as usize;
    Measurement {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[idx(0.5)],
        p95: samples[idx(0.95)],
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// A results table with aligned markdown rendering and CSV output.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n## {}\n\n", self.title);
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:w$} |", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    /// Print markdown to stdout and write CSV under results/.
    pub fn emit(&self, csv_name: &str) {
        print!("{}", self.to_markdown());
        let dir = std::path::Path::new("results");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(csv_name);
            if let Err(e) = std::fs::write(&path, self.to_csv()) {
                eprintln!("warning: could not write {}: {e}", path.display());
            } else {
                eprintln!("[csv] {}", path.display());
            }
        }
    }
}

/// Human-readable duration.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2} s")
    } else if s >= 1e-3 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 4] = ["B", "KiB", "MiB", "GiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1} {}", UNITS[u])
}

/// `BENCH_QUICK=1` shrinks iteration counts in CI-ish runs; the env read
/// itself lives in [`crate::config::resolve_bench_quick`] (single-file
/// env resolution, enforced by `lintra analyze` rule `env`).
pub fn opts_from_env() -> BenchOpts {
    if crate::config::resolve_bench_quick() {
        BenchOpts::quick()
    } else {
        BenchOpts::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let m = bench(
            "sleepless",
            BenchOpts {
                warmup_iters: 1,
                measure_iters: 5,
                max_total: Duration::from_secs(5),
            },
            || {
                std::hint::black_box((0..1000).sum::<u64>());
            },
        );
        assert_eq!(m.iters, 5);
        assert!(m.min <= m.p50 && m.p50 <= m.max);
        assert!(m.mean >= m.min && m.mean <= m.max);
    }

    #[test]
    fn max_total_stops_early() {
        let m = bench(
            "slow",
            BenchOpts {
                warmup_iters: 0,
                measure_iters: 1000,
                max_total: Duration::from_millis(30),
            },
            || std::thread::sleep(Duration::from_millis(10)),
        );
        assert!(m.iters < 1000);
    }

    #[test]
    fn throughput_math() {
        let m = summarize("t", vec![Duration::from_millis(100)]);
        let thr = m.throughput(50.0);
        assert!((thr - 500.0).abs() < 1.0);
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("Demo", &["method", "value"]);
        t.row(vec!["linear".into(), "1.0".into()]);
        t.row(vec!["softmax".into(), "2.0".into()]);
        let md = t.to_markdown();
        assert!(md.contains("## Demo"));
        assert!(md.contains("| linear "));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("method,value"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_bytes(512), "512.0 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert!(fmt_duration(Duration::from_micros(5)).contains("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).contains("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).contains("s"));
    }
}
