//! Token sampling for autoregressive generation.
//!
//! Temperature 0 = greedy argmax; otherwise softmax-with-temperature
//! categorical sampling (optionally top-k truncated). Used by the image
//! generation examples and the serving engine.

use crate::rng::Rng;
use crate::tensor::softmax_inplace;

/// Sample one token id from unnormalized logits.
pub fn sample_logits(logits: &[f32], temperature: f32, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let mut probs: Vec<f32> = logits.iter().map(|&x| x / temperature).collect();
    softmax_inplace(&mut probs);
    rng.categorical(&probs) as u32
}

/// Top-k restricted sampling (k = 0 means unrestricted).
pub fn sample_logits_topk(logits: &[f32], temperature: f32, k: usize, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 || k == 1 {
        return argmax(logits);
    }
    if k == 0 || k >= logits.len() {
        return sample_logits(logits, temperature, rng);
    }
    // indices of the k largest logits
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    idx.truncate(k);
    let mut probs: Vec<f32> = idx.iter().map(|&i| logits[i] / temperature).collect();
    softmax_inplace(&mut probs);
    idx[rng.categorical(&probs)] as u32
}

/// Argmax over logits.
pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as u32)
        .expect("argmax of empty logits")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = [0.1, 5.0, -2.0, 4.9];
        let mut rng = Rng::new(0);
        for _ in 0..10 {
            assert_eq!(sample_logits(&logits, 0.0, &mut rng), 1);
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = [0.0, 3.0, 0.0];
        let mut rng = Rng::new(1);
        let hits = (0..200)
            .filter(|_| sample_logits(&logits, 0.1, &mut rng) == 1)
            .count();
        assert!(hits > 195, "hits={hits}");
    }

    #[test]
    fn high_temperature_spreads() {
        let logits = [0.0, 3.0, 0.0];
        let mut rng = Rng::new(2);
        let hits = (0..2000)
            .filter(|_| sample_logits(&logits, 100.0, &mut rng) == 1)
            .count();
        // nearly uniform: expect ~1/3
        assert!(hits < 900, "hits={hits}");
    }

    #[test]
    fn topk_never_leaves_topk() {
        let logits = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let t = sample_logits_topk(&logits, 1.0, 2, &mut rng);
            assert!(t == 4 || t == 3, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn distribution_roughly_softmax() {
        let logits = [0.0f32, (2.0f32).ln()]; // probs [1/3, 2/3]
        let mut rng = Rng::new(4);
        let n = 30_000;
        let ones = (0..n)
            .filter(|_| sample_logits(&logits, 1.0, &mut rng) == 1)
            .count();
        let p = ones as f64 / n as f64;
        assert!((p - 2.0 / 3.0).abs() < 0.02, "p={p}");
    }
}
