//! Token sampling for autoregressive generation.
//!
//! Temperature 0 = greedy argmax; otherwise softmax-with-temperature
//! categorical sampling (optionally top-k truncated). Used by the image
//! generation examples and the serving engine.
//!
//! NaN logits must never panic here: this code runs inside the engine
//! worker, where a panic kills every in-flight request. Comparisons use
//! the total order (`f32::total_cmp`) with NaN demoted below every real
//! logit, and degenerate distributions fall back to greedy.
//!
//! # Example
//!
//! ```
//! use linear_transformer::rng::Rng;
//! use linear_transformer::sampling::{argmax, sample_logits};
//!
//! let logits = [0.1, 5.0, -2.0];
//! assert_eq!(argmax(&logits), 1);
//! // temperature 0 is deterministic greedy; > 0 samples the softmax
//! let mut rng = Rng::new(0);
//! assert_eq!(sample_logits(&logits, 0.0, &mut rng), 1);
//! let t = sample_logits(&logits, 1.0, &mut rng);
//! assert!((t as usize) < logits.len());
//! ```

use crate::rng::Rng;
use crate::tensor::softmax_inplace;

/// NaN-proof sampling key: a NaN logit ranks below (and contributes no
/// probability mass against) every real logit.
#[inline]
fn nan_as_neg_inf(x: f32) -> f32 {
    if x.is_nan() {
        f32::NEG_INFINITY
    } else {
        x
    }
}

/// Sample one token id from unnormalized logits.
pub fn sample_logits(logits: &[f32], temperature: f32, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 {
        return argmax(logits);
    }
    let mut probs: Vec<f32> = logits.iter().map(|&x| nan_as_neg_inf(x) / temperature).collect();
    softmax_inplace(&mut probs);
    if probs.iter().any(|p| !p.is_finite()) {
        // every logit NaN/-inf (or one +inf): no usable distribution
        return argmax(logits);
    }
    rng.categorical(&probs) as u32
}

/// Top-k restricted sampling (k = 0 means unrestricted).
pub fn sample_logits_topk(logits: &[f32], temperature: f32, k: usize, rng: &mut Rng) -> u32 {
    if temperature <= 0.0 || k == 1 {
        return argmax(logits);
    }
    if k == 0 || k >= logits.len() {
        return sample_logits(logits, temperature, rng);
    }
    // indices of the k largest logits (total order; NaN sorts last)
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| nan_as_neg_inf(logits[b]).total_cmp(&nan_as_neg_inf(logits[a])));
    idx.truncate(k);
    let mut probs: Vec<f32> = idx
        .iter()
        .map(|&i| nan_as_neg_inf(logits[i]) / temperature)
        .collect();
    softmax_inplace(&mut probs);
    if probs.iter().any(|p| !p.is_finite()) {
        return argmax(logits);
    }
    idx[rng.categorical(&probs)] as u32
}

/// Argmax over logits (total order; NaN ranks below every real logit, so
/// a NaN-bearing row still yields a deterministic in-vocab token).
pub fn argmax(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| nan_as_neg_inf(*a.1).total_cmp(&nan_as_neg_inf(*b.1)))
        .map(|(i, _)| i as u32)
        .expect("argmax of empty logits") // lintra: allow(panic) -- logits rows are vocab-sized, never empty
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let logits = [0.1, 5.0, -2.0, 4.9];
        let mut rng = Rng::new(0);
        for _ in 0..10 {
            assert_eq!(sample_logits(&logits, 0.0, &mut rng), 1);
        }
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = [0.0, 3.0, 0.0];
        let mut rng = Rng::new(1);
        let hits = (0..200)
            .filter(|_| sample_logits(&logits, 0.1, &mut rng) == 1)
            .count();
        assert!(hits > 195, "hits={hits}");
    }

    #[test]
    fn high_temperature_spreads() {
        let logits = [0.0, 3.0, 0.0];
        let mut rng = Rng::new(2);
        let hits = (0..2000)
            .filter(|_| sample_logits(&logits, 100.0, &mut rng) == 1)
            .count();
        // nearly uniform: expect ~1/3
        assert!(hits < 900, "hits={hits}");
    }

    #[test]
    fn topk_never_leaves_topk() {
        let logits = [1.0, 2.0, 3.0, 4.0, 5.0];
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            let t = sample_logits_topk(&logits, 1.0, 2, &mut rng);
            assert!(t == 4 || t == 3, "sampled {t} outside top-2");
        }
    }

    #[test]
    fn nan_logits_never_panic_and_are_never_selected() {
        // regression: partial_cmp().unwrap() used to panic the engine
        // worker on any NaN logit
        let logits = [0.5, f32::NAN, 3.0, 1.0];
        assert_eq!(argmax(&logits), 2, "NaN must rank below real logits");
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            let t = sample_logits(&logits, 1.0, &mut rng);
            assert_ne!(t, 1, "NaN logit must carry no probability mass");
            assert!((t as usize) < logits.len());
            let t = sample_logits_topk(&logits, 1.0, 2, &mut rng);
            assert!(t == 2 || t == 3, "top-2 of [0.5, NaN, 3.0, 1.0] is {{2, 3}}, got {t}");
        }
    }

    #[test]
    fn all_nan_logits_fall_back_to_a_deterministic_token() {
        let logits = [f32::NAN, f32::NAN, f32::NAN];
        let mut rng = Rng::new(8);
        let a = argmax(&logits);
        assert!((a as usize) < logits.len());
        assert_eq!(sample_logits(&logits, 1.0, &mut rng), a);
        assert_eq!(sample_logits_topk(&logits, 1.0, 2, &mut rng), a);
    }

    #[test]
    fn distribution_roughly_softmax() {
        let logits = [0.0f32, (2.0f32).ln()]; // probs [1/3, 2/3]
        let mut rng = Rng::new(4);
        let n = 30_000;
        let ones = (0..n)
            .filter(|_| sample_logits(&logits, 1.0, &mut rng) == 1)
            .count();
        let p = ones as f64 / n as f64;
        assert!((p - 2.0 / 3.0).abs() < 0.02, "p={p}");
    }
}
