//! Evaluation metrics + serving telemetry.
//!
//! * [`bits_per_dim`] — image-modeling metric of Tables 1–2.
//! * [`edit_distance`] / [`phoneme_error_rate`] — Table 3's PER.
//! * [`LatencyRecorder`] — p50/p95/p99 request latency for the engine.
//! * [`TickLatencySplit`] — engine tick durations, split by whether the
//!   tick ingested prompt chunks (the flat-decode-latency evidence).
//! * [`StateCacheCounters`] — prefix-reuse state-cache hit/miss/evict
//!   telemetry for the engine.
//! * [`Throughput`] — wall-clock throughput accounting for the coordinator.

use std::time::Duration;

/// Cross entropy (nats) -> bits per dimension.
pub fn bits_per_dim(nats: f64) -> f64 {
    nats / std::f64::consts::LN_2
}

/// Mean negative log likelihood (nats) of `targets` under `logits` rows.
/// `logits` is [n, vocab] row-major, already unnormalized.
pub fn mean_nll(logits: &[f32], vocab: usize, targets: &[u32]) -> f64 {
    assert_eq!(logits.len(), vocab * targets.len());
    let mut total = 0.0f64;
    for (i, &t) in targets.iter().enumerate() {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
        total += (lse - row[t as usize]) as f64;
    }
    total / targets.len() as f64
}

/// Levenshtein edit distance between two symbol sequences.
pub fn edit_distance(a: &[u32], b: &[u32]) -> usize {
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Phoneme error rate: total edit distance / total reference length.
pub fn phoneme_error_rate(pairs: &[(Vec<u32>, Vec<u32>)]) -> f64 {
    let mut errs = 0usize;
    let mut total = 0usize;
    for (hyp, reference) in pairs {
        errs += edit_distance(hyp, reference);
        total += reference.len();
    }
    if total == 0 {
        0.0
    } else {
        100.0 * errs as f64 / total as f64
    }
}

/// CTC greedy decode: argmax per frame, collapse repeats, drop blanks.
///
/// Per-frame argmax uses the shared NaN-below-all total order
/// ([`crate::sampling::argmax`]): a NaN log-prob must never panic this
/// path — it runs inside worker threads, where a panic takes every
/// in-flight request down — and must never be selected over a real one.
pub fn ctc_greedy_decode(logp: &[f32], frames: usize, vocab: usize, blank: u32) -> Vec<u32> {
    assert_eq!(logp.len(), frames * vocab);
    let mut out = Vec::new();
    let mut prev = u32::MAX;
    for f in 0..frames {
        let arg = crate::sampling::argmax(&logp[f * vocab..(f + 1) * vocab]);
        if arg != prev && arg != blank {
            out.push(arg);
        }
        prev = arg;
    }
    out
}

/// Online latency statistics, bounded for long-lived serving.
///
/// Keeps a fixed-size reservoir (Algorithm R) of at most
/// [`LATENCY_RESERVOIR`] samples plus an exact running count/sum, so a
/// server that has answered millions of requests holds the same few KiB
/// it held after the first thousand (the previous version stored every
/// sample forever). The first `LATENCY_RESERVOIR` samples are kept
/// exactly; past that, percentiles are an unbiased uniform-sample
/// estimate while `count`/`mean` stay exact.
#[derive(Debug, Clone)]
pub struct LatencyRecorder {
    samples: Vec<Duration>,
    seen: u64,
    sum: Duration,
    /// deterministic xorshift state for reservoir replacement
    rng: u64,
}

/// Upper bound on samples a [`LatencyRecorder`] retains.
pub const LATENCY_RESERVOIR: usize = 4096;

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        LatencyRecorder {
            samples: Vec::new(),
            seen: 0,
            sum: Duration::ZERO,
            rng: 0x243F_6A88_85A3_08D3, // pi digits; any nonzero seed works
        }
    }

    fn next_u64(&mut self) -> u64 {
        // xorshift64*: cheap, deterministic, good enough for reservoir slots
        let mut x = self.rng;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.rng = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    pub fn record(&mut self, d: Duration) {
        self.seen += 1;
        self.sum += d;
        if self.samples.len() < LATENCY_RESERVOIR {
            self.samples.push(d);
        } else {
            // Algorithm R: keep each of the `seen` samples with equal
            // probability by replacing a random slot
            let j = self.next_u64() % self.seen;
            if (j as usize) < LATENCY_RESERVOIR {
                self.samples[j as usize] = d;
            }
        }
    }

    /// Total samples observed (exact, not capped by the reservoir).
    pub fn count(&self) -> usize {
        self.seen as usize
    }

    /// Samples currently held (≤ [`LATENCY_RESERVOIR`]).
    pub fn stored(&self) -> usize {
        self.samples.len()
    }

    /// Exact mean over every recorded sample.
    pub fn mean(&self) -> Duration {
        if self.seen == 0 {
            return Duration::ZERO;
        }
        Duration::from_secs_f64(self.sum.as_secs_f64() / self.seen as f64)
    }

    /// Several percentiles with one clone + sort of the reservoir — the
    /// path for callers that read p50/p95/p99 together.
    pub fn percentiles(&self, qs: &[f64]) -> Vec<Duration> {
        let mut s = self.samples.clone();
        s.sort();
        qs.iter().map(|&q| percentile_of(&s, q)).collect()
    }

    pub fn percentile(&self, q: f64) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        percentile_of(&s, q)
    }

    pub fn p50(&self) -> Duration {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Duration {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(0.99)
    }

    pub fn summary(&self) -> String {
        // one sort serves all three percentiles
        let p = self.percentiles(&[0.50, 0.95, 0.99]);
        format!(
            "n={} mean={:?} p50={:?} p95={:?} p99={:?}",
            self.count(),
            self.mean(),
            p[0],
            p[1],
            p[2]
        )
    }
}

/// Nearest-rank percentile of an already-sorted sample slice.
fn percentile_of(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Engine tick durations, split by what the tick did.
///
/// The incremental-prefill scheduler bounds how much prompt ingestion a
/// single engine tick may perform (`prefill_chunks_per_tick`), so that
/// resident decode lanes keep producing a token per tick at a flat
/// cadence while a long prompt admits. This split makes that claim
/// measurable: `decode` records ticks that only stepped resident lanes,
/// `prefill` records ticks that also ingested prompt chunks. A healthy
/// engine shows `prefill` p99 within roughly one chunk's GEMM cost of
/// `decode` p99 — not a multi-hundred-tick stall per long prompt.
#[derive(Debug, Default, Clone)]
pub struct TickLatencySplit {
    /// Ticks that ingested at least one prompt chunk (plus any decode
    /// work they also did).
    pub prefill: LatencyRecorder,
    /// Pure decode ticks (no prompt ingestion).
    pub decode: LatencyRecorder,
}

impl TickLatencySplit {
    /// One-line report of both distributions.
    pub fn summary(&self) -> String {
        format!(
            "decode-ticks[{}] prefill-ticks[{}]",
            self.decode.summary(),
            self.prefill.summary()
        )
    }
}

/// Prefix-reuse state-cache telemetry (the engine's
/// `--state-cache-mb` path): admission-time cache consultations and the
/// evictions the byte budget forced. `hits + misses` counts admissions
/// that consulted the cache (prefill-capable backend, cache enabled);
/// the companion `EngineStats::prompt_tokens_skipped` counter records
/// how many prompt tokens those hits avoided re-prefilling.
#[derive(Debug, Default, Clone)]
pub struct StateCacheCounters {
    /// Admissions that restored a cached prefix snapshot.
    pub hits: u64,
    /// Admissions that consulted the cache and found no usable prefix.
    pub misses: u64,
    /// Entries evicted by the LRU byte budget.
    pub evictions: u64,
}

impl StateCacheCounters {
    /// Fraction of consultations that hit (0.0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// One-line report.
    pub fn summary(&self) -> String {
        format!(
            "hits={} misses={} evictions={} hit-rate={:.2}",
            self.hits,
            self.misses,
            self.evictions,
            self.hit_rate()
        )
    }
}

/// Throughput counter over a wall-clock window.
#[derive(Debug, Clone)]
pub struct Throughput {
    start: std::time::Instant,
    pub items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput {
            start: std::time::Instant::now(),
            items: 0,
        }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn per_sec(&self) -> f64 {
        self.items as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_dim_conversion() {
        assert!((bits_per_dim(std::f64::consts::LN_2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_nll_uniform() {
        // uniform logits over 4 classes: nll = ln 4
        let logits = vec![0.0f32; 8];
        let nll = mean_nll(&logits, 4, &[0, 3]);
        assert!((nll - (4.0f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn mean_nll_confident() {
        let mut logits = vec![-50.0f32; 4];
        logits[2] = 50.0;
        assert!(mean_nll(&logits, 4, &[2]) < 1e-6);
    }

    #[test]
    fn edit_distance_cases() {
        assert_eq!(edit_distance(&[], &[]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 3], &[1, 2, 3]), 1); // insertion
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(edit_distance(&[], &[5, 6]), 2);
    }

    #[test]
    fn edit_distance_symmetry_property() {
        crate::propcheck::check("edit-distance-symmetry", 40, |g| {
            let la = g.usize_in(0, 12);
            let a: Vec<u32> = g.vec_usize(la, 0, 5).iter().map(|&x| x as u32).collect();
            let lb = g.usize_in(0, 12);
            let b: Vec<u32> = g.vec_usize(lb, 0, 5).iter().map(|&x| x as u32).collect();
            let d1 = edit_distance(&a, &b);
            let d2 = edit_distance(&b, &a);
            if d1 != d2 {
                return Err(format!("asymmetric: {d1} vs {d2}"));
            }
            // triangle-ish sanity: distance bounded by max length
            if d1 > a.len().max(b.len()) {
                return Err("distance exceeds max length".into());
            }
            Ok(())
        });
    }

    #[test]
    fn per_math() {
        let pairs = vec![(vec![1, 2, 3], vec![1, 2, 4]), (vec![1], vec![1])];
        // 1 error over 4 reference symbols = 25%
        assert!((phoneme_error_rate(&pairs) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn ctc_greedy_collapses() {
        // frames argmax: [1, 1, 0, 2, 2, 0, 2] -> [1, 2, 2]
        let v = 3;
        let mk = |c: usize| {
            let mut row = vec![-10.0f32; v];
            row[c] = 0.0;
            row
        };
        let frames = [1usize, 1, 0, 2, 2, 0, 2];
        let logp: Vec<f32> = frames.iter().flat_map(|&c| mk(c)).collect();
        assert_eq!(ctc_greedy_decode(&logp, frames.len(), v, 0), vec![1, 2, 2]);
    }

    #[test]
    fn ctc_greedy_survives_nan_frames() {
        // regression: partial_cmp().unwrap() panicked on any NaN frame —
        // the exact pattern that used to kill the engine worker in
        // sampling.rs. NaN must rank below every real log-prob, and an
        // all-NaN frame must still resolve to a deterministic symbol
        // (the tie over -inf keys goes to the last index, 2 here).
        let v = 3;
        #[rustfmt::skip]
        let logp = vec![
            0.0, 1.0, -1.0,               // argmax 1
            f32::NAN, f32::NAN, f32::NAN, // all NaN -> deterministic 2
            2.0, f32::NAN, -1.0,          // NaN never beats a real: 0 = blank
            -1.0, f32::NAN, 2.0,          // NaN ranks below real -> argmax 2
        ];
        assert_eq!(ctc_greedy_decode(&logp, 4, v, 0), vec![1, 2, 2]);
    }

    #[test]
    fn latency_recorder_is_bounded_with_exact_count_and_mean() {
        let mut r = LatencyRecorder::new();
        let n = 20_000u64;
        for i in 1..=n {
            r.record(Duration::from_micros(i % 1000 + 1));
        }
        assert_eq!(r.count() as u64, n, "count must stay exact past the reservoir");
        assert!(
            r.stored() <= LATENCY_RESERVOIR,
            "reservoir must cap retained samples, holds {}",
            r.stored()
        );
        // mean of (1..=1000)µs repeating is ~500.5µs, tracked exactly
        let mean = r.mean();
        assert!(
            mean >= Duration::from_micros(495) && mean <= Duration::from_micros(506),
            "mean {mean:?} must stay exact"
        );
        // percentile estimates stay in the sampled range and ordered
        assert!(r.p50() >= Duration::from_micros(1));
        assert!(r.p50() <= r.p95() && r.p95() <= r.p99());
        assert!(r.p99() <= Duration::from_micros(1000));
        // the single-sort batch path agrees with the per-call getters
        let p = r.percentiles(&[0.50, 0.95, 0.99]);
        assert_eq!(p, vec![r.p50(), r.p95(), r.p99()]);
    }

    #[test]
    fn latency_percentiles_ordered() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(Duration::from_millis(i));
        }
        assert!(r.p50() <= r.p95() && r.p95() <= r.p99());
        assert_eq!(r.count(), 100);
        assert!(r.p50() >= Duration::from_millis(45) && r.p50() <= Duration::from_millis(55));
    }

    #[test]
    fn tick_latency_split_keeps_kinds_apart() {
        let mut split = TickLatencySplit::default();
        for _ in 0..10 {
            split.decode.record(Duration::from_micros(100));
        }
        split.prefill.record(Duration::from_micros(900));
        assert_eq!(split.decode.count(), 10);
        assert_eq!(split.prefill.count(), 1);
        assert!(split.prefill.mean() > split.decode.mean());
        let s = split.summary();
        assert!(s.contains("decode-ticks[") && s.contains("prefill-ticks["), "{s}");
    }

    #[test]
    fn state_cache_counters_report() {
        let mut c = StateCacheCounters::default();
        assert_eq!(c.hit_rate(), 0.0, "no consultations: rate must not divide by zero");
        c.hits = 3;
        c.misses = 1;
        c.evictions = 2;
        assert!((c.hit_rate() - 0.75).abs() < 1e-12);
        let s = c.summary();
        assert!(s.contains("hits=3") && s.contains("evictions=2"), "{s}");
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.items, 15);
        assert!(t.per_sec() > 0.0);
    }
}
