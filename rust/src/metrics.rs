//! Evaluation metrics + serving telemetry.
//!
//! * [`bits_per_dim`] — image-modeling metric of Tables 1–2.
//! * [`edit_distance`] / [`phoneme_error_rate`] — Table 3's PER.
//! * [`LatencyRecorder`] — p50/p95/p99 request latency for the engine.
//! * [`Counter`]-style throughput accounting used by the coordinator.

use std::time::Duration;

/// Cross entropy (nats) -> bits per dimension.
pub fn bits_per_dim(nats: f64) -> f64 {
    nats / std::f64::consts::LN_2
}

/// Mean negative log likelihood (nats) of `targets` under `logits` rows.
/// `logits` is [n, vocab] row-major, already unnormalized.
pub fn mean_nll(logits: &[f32], vocab: usize, targets: &[u32]) -> f64 {
    assert_eq!(logits.len(), vocab * targets.len());
    let mut total = 0.0f64;
    for (i, &t) in targets.iter().enumerate() {
        let row = &logits[i * vocab..(i + 1) * vocab];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 = row.iter().map(|&x| (x - max).exp()).sum::<f32>().ln() + max;
        total += (lse - row[t as usize]) as f64;
    }
    total / targets.len() as f64
}

/// Levenshtein edit distance between two symbol sequences.
pub fn edit_distance(a: &[u32], b: &[u32]) -> usize {
    if a.is_empty() {
        return b.len();
    }
    if b.is_empty() {
        return a.len();
    }
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Phoneme error rate: total edit distance / total reference length.
pub fn phoneme_error_rate(pairs: &[(Vec<u32>, Vec<u32>)]) -> f64 {
    let mut errs = 0usize;
    let mut total = 0usize;
    for (hyp, reference) in pairs {
        errs += edit_distance(hyp, reference);
        total += reference.len();
    }
    if total == 0 {
        0.0
    } else {
        100.0 * errs as f64 / total as f64
    }
}

/// CTC greedy decode: argmax per frame, collapse repeats, drop blanks.
pub fn ctc_greedy_decode(logp: &[f32], frames: usize, vocab: usize, blank: u32) -> Vec<u32> {
    assert_eq!(logp.len(), frames * vocab);
    let mut out = Vec::new();
    let mut prev = u32::MAX;
    for f in 0..frames {
        let row = &logp[f * vocab..(f + 1) * vocab];
        let arg = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32;
        if arg != prev && arg != blank {
            out.push(arg);
        }
        prev = arg;
    }
    out
}

/// Online latency statistics (stores samples; fine for bench-scale counts).
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples: Vec<Duration>,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples.push(d);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        self.samples.iter().sum::<Duration>() / self.samples.len() as u32
    }

    pub fn percentile(&self, q: f64) -> Duration {
        if self.samples.is_empty() {
            return Duration::ZERO;
        }
        let mut s = self.samples.clone();
        s.sort();
        s[((s.len() - 1) as f64 * q).round() as usize]
    }

    pub fn p50(&self) -> Duration {
        self.percentile(0.50)
    }

    pub fn p95(&self) -> Duration {
        self.percentile(0.95)
    }

    pub fn p99(&self) -> Duration {
        self.percentile(0.99)
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:?} p50={:?} p95={:?} p99={:?}",
            self.count(),
            self.mean(),
            self.p50(),
            self.p95(),
            self.p99()
        )
    }
}

/// Throughput counter over a wall-clock window.
#[derive(Debug, Clone)]
pub struct Throughput {
    start: std::time::Instant,
    pub items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput {
            start: std::time::Instant::now(),
            items: 0,
        }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn per_sec(&self) -> f64 {
        self.items as f64 / self.start.elapsed().as_secs_f64().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_dim_conversion() {
        assert!((bits_per_dim(std::f64::consts::LN_2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn mean_nll_uniform() {
        // uniform logits over 4 classes: nll = ln 4
        let logits = vec![0.0f32; 8];
        let nll = mean_nll(&logits, 4, &[0, 3]);
        assert!((nll - (4.0f64).ln()).abs() < 1e-6);
    }

    #[test]
    fn mean_nll_confident() {
        let mut logits = vec![-50.0f32; 4];
        logits[2] = 50.0;
        assert!(mean_nll(&logits, 4, &[2]) < 1e-6);
    }

    #[test]
    fn edit_distance_cases() {
        assert_eq!(edit_distance(&[], &[]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 2, 3]), 0);
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 3]), 1); // deletion
        assert_eq!(edit_distance(&[1, 3], &[1, 2, 3]), 1); // insertion
        assert_eq!(edit_distance(&[1, 2, 3], &[1, 9, 3]), 1); // substitution
        assert_eq!(edit_distance(&[], &[5, 6]), 2);
    }

    #[test]
    fn edit_distance_symmetry_property() {
        crate::propcheck::check("edit-distance-symmetry", 40, |g| {
            let la = g.usize_in(0, 12);
            let a: Vec<u32> = g.vec_usize(la, 0, 5).iter().map(|&x| x as u32).collect();
            let lb = g.usize_in(0, 12);
            let b: Vec<u32> = g.vec_usize(lb, 0, 5).iter().map(|&x| x as u32).collect();
            let d1 = edit_distance(&a, &b);
            let d2 = edit_distance(&b, &a);
            if d1 != d2 {
                return Err(format!("asymmetric: {d1} vs {d2}"));
            }
            // triangle-ish sanity: distance bounded by max length
            if d1 > a.len().max(b.len()) {
                return Err("distance exceeds max length".into());
            }
            Ok(())
        });
    }

    #[test]
    fn per_math() {
        let pairs = vec![(vec![1, 2, 3], vec![1, 2, 4]), (vec![1], vec![1])];
        // 1 error over 4 reference symbols = 25%
        assert!((phoneme_error_rate(&pairs) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn ctc_greedy_collapses() {
        // frames argmax: [1, 1, 0, 2, 2, 0, 2] -> [1, 2, 2]
        let v = 3;
        let mk = |c: usize| {
            let mut row = vec![-10.0f32; v];
            row[c] = 0.0;
            row
        };
        let frames = [1usize, 1, 0, 2, 2, 0, 2];
        let logp: Vec<f32> = frames.iter().flat_map(|&c| mk(c)).collect();
        assert_eq!(ctc_greedy_decode(&logp, frames.len(), v, 0), vec![1, 2, 2]);
    }

    #[test]
    fn latency_percentiles_ordered() {
        let mut r = LatencyRecorder::new();
        for i in 1..=100 {
            r.record(Duration::from_millis(i));
        }
        assert!(r.p50() <= r.p95() && r.p95() <= r.p99());
        assert_eq!(r.count(), 100);
        assert!(r.p50() >= Duration::from_millis(45) && r.p50() <= Duration::from_millis(55));
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.add(10);
        t.add(5);
        assert_eq!(t.items, 15);
        assert!(t.per_sec() > 0.0);
    }
}
