//! PJRT runtime: load and execute the AOT artifacts from `make artifacts`.
//!
//! The request path is: manifest.json -> [`Bundle`] (artifact registry) ->
//! [`Runtime::load`] (HLO text -> `HloModuleProto` -> compile on the CPU
//! PJRT client, cached) -> [`LoadedArtifact::run`] with [`Value`] tensors.
//!
//! HLO *text* is the interchange format — the image's xla_extension 0.5.1
//! rejects jax>=0.5 serialized protos (64-bit instruction ids); the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//!
//! The `xla` bindings are only available in environments that bake them
//! in, so everything touching them is gated behind the `pjrt` cargo
//! feature. Without it the same API compiles, [`Runtime::open`] returns a
//! descriptive error, and every PJRT-dependent test/bench skips at runtime.

pub mod bundle;

pub use bundle::{ArtifactSpec, Bundle, Dtype, ModelSpec, TensorSpec};

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::time::Instant;

use anyhow::bail;
#[cfg(feature = "pjrt")]
use anyhow::Context;

use crate::tensor::Tensor;

/// A host tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    F32(Vec<usize>, Vec<f32>),
    I32(Vec<usize>, Vec<i32>),
}

impl Value {
    pub fn scalar_f32(x: f32) -> Value {
        Value::F32(vec![], vec![x])
    }

    pub fn scalar_i32(x: i32) -> Value {
        Value::I32(vec![], vec![x])
    }

    pub fn from_tensor(t: &Tensor) -> Value {
        Value::F32(t.shape.clone(), t.data.clone())
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(s, _) | Value::I32(s, _) => s,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            Value::F32(..) => Dtype::F32,
            Value::I32(..) => Dtype::I32,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product::<usize>().max(1)
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            Value::F32(_, d) => Ok(d),
            Value::I32(..) => bail!("expected f32 value, got i32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            Value::I32(_, d) => Ok(d),
            Value::F32(..) => bail!("expected i32 value, got f32"),
        }
    }

    pub fn scalar(&self) -> anyhow::Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("expected scalar, got {:?}", self.shape());
        }
        Ok(d[0])
    }

    pub fn into_tensor(self) -> anyhow::Result<Tensor> {
        match self {
            Value::F32(shape, data) => {
                let shape = if shape.is_empty() { vec![1] } else { shape };
                Ok(Tensor::from_vec(&shape, data))
            }
            Value::I32(..) => bail!("cannot view i32 value as Tensor"),
        }
    }
}

#[cfg(feature = "pjrt")]
impl Value {
    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let (ty, dims, bytes): (xla::ElementType, &[usize], Vec<u8>) = match self {
            Value::F32(shape, data) => (
                xla::ElementType::F32,
                shape,
                data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
            Value::I32(shape, data) => (
                xla::ElementType::S32,
                shape,
                data.iter().flat_map(|x| x.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, dims, &bytes)
            .context("building literal")
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> anyhow::Result<Value> {
        match spec.dtype {
            Dtype::F32 => Ok(Value::F32(spec.shape.clone(), lit.to_vec::<f32>()?)),
            Dtype::I32 => Ok(Value::I32(spec.shape.clone(), lit.to_vec::<i32>()?)),
        }
    }
}

/// A compiled artifact, ready to execute.
#[cfg(feature = "pjrt")]
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// wall time spent in `run` (telemetry for EXPERIMENTS.md §Perf)
    pub exec_time: std::cell::Cell<std::time::Duration>,
    pub exec_count: std::cell::Cell<u64>,
}

#[cfg(feature = "pjrt")]
impl LoadedArtifact {
    /// Execute with shape/dtype validation against the manifest.
    pub fn run(&self, inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (v, s) in inputs.iter().zip(&self.spec.inputs) {
            if v.shape() != s.shape.as_slice() || v.dtype() != s.dtype {
                bail!(
                    "{}: input {:?} expects {:?} {:?}, got {:?} {:?}",
                    self.spec.name,
                    s.name,
                    s.dtype,
                    s.shape,
                    v.dtype(),
                    v.shape()
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|v| v.to_literal())
            .collect::<anyhow::Result<_>>()?;
        let t0 = Instant::now();
        let buffers = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = buffers[0][0].to_literal_sync()?;
        self.exec_time
            .set(self.exec_time.get() + t0.elapsed());
        self.exec_count.set(self.exec_count.get() + 1);
        // lowered with return_tuple=True: a single tuple literal
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| Value::from_literal(lit, spec))
            .collect()
    }

    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.spec.inputs.iter().position(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.spec.outputs.iter().position(|s| s.name == name)
    }
}

/// The PJRT runtime: client + manifest + compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    pub bundle: Bundle,
    dir: PathBuf,
    client: xla::PjRtClient,
    cache: HashMap<String, std::rc::Rc<LoadedArtifact>>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (reads manifest.json, creates the CPU
    /// PJRT client; compilation happens lazily per artifact).
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let bundle = Bundle::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            bundle,
            dir,
            client,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (compile) an artifact by manifest name; cached.
    pub fn load(&mut self, name: &str) -> anyhow::Result<std::rc::Rc<LoadedArtifact>> {
        if let Some(a) = self.cache.get(name) {
            return Ok(a.clone());
        }
        let spec = self
            .bundle
            .artifact(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        eprintln!(
            "[runtime] compiled {name} in {:.2}s",
            t0.elapsed().as_secs_f64()
        );
        let loaded = std::rc::Rc::new(LoadedArtifact {
            spec,
            exe,
            exec_time: std::cell::Cell::new(std::time::Duration::ZERO),
            exec_count: std::cell::Cell::new(0),
        });
        self.cache.insert(name.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Load a model's initial weights bundle.
    pub fn load_weights(&self, model: &str) -> anyhow::Result<crate::weights::WeightBundle> {
        let spec = self
            .bundle
            .model(model)
            .with_context(|| format!("model {model:?} not in manifest"))?;
        crate::weights::WeightBundle::load(self.dir.join(&spec.weights))
    }
}

// ---------------------------------------------------------------------------
// no-pjrt stubs: same API, runtime errors instead of XLA execution
// ---------------------------------------------------------------------------

/// Stub of the compiled artifact when built without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    pub exec_time: std::cell::Cell<std::time::Duration>,
    pub exec_count: std::cell::Cell<u64>,
}

#[cfg(not(feature = "pjrt"))]
impl LoadedArtifact {
    pub fn run(&self, _inputs: &[Value]) -> anyhow::Result<Vec<Value>> {
        bail!(
            "cannot execute artifact {:?}: built without the `pjrt` feature",
            self.spec.name
        )
    }

    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.spec.inputs.iter().position(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.spec.outputs.iter().position(|s| s.name == name)
    }
}

/// Stub runtime when built without the `pjrt` feature: [`Runtime::open`]
/// always fails, so PJRT-dependent callers degrade with a clear error
/// while the native decode paths stay fully functional.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub bundle: Bundle,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        bail!(
            "cannot open artifact dir {}: this build has no PJRT support \
             (rebuild with --features pjrt and the xla bindings); the native \
             engine and all pure-rust paths remain available",
            dir.as_ref().display()
        )
    }

    pub fn platform(&self) -> String {
        "pjrt-disabled".into()
    }

    pub fn load(&mut self, name: &str) -> anyhow::Result<std::rc::Rc<LoadedArtifact>> {
        bail!("cannot load artifact {name:?}: built without the `pjrt` feature")
    }

    pub fn load_weights(&self, model: &str) -> anyhow::Result<crate::weights::WeightBundle> {
        bail!("cannot load weights for {model:?}: built without the `pjrt` feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_accessors() {
        let v = Value::F32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(v.shape(), &[2, 2]);
        assert_eq!(v.numel(), 4);
        assert!(v.as_i32().is_err());
        assert_eq!(v.as_f32().unwrap()[3], 4.0);
        let s = Value::scalar_f32(7.5);
        assert_eq!(s.scalar().unwrap(), 7.5);
        assert!(v.scalar().is_err());
    }

    #[test]
    fn value_tensor_roundtrip() {
        let t = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let v = Value::from_tensor(&t);
        assert_eq!(v.into_tensor().unwrap(), t);
    }

    #[test]
    fn i32_value() {
        let v = Value::I32(vec![3], vec![1, 2, 3]);
        assert_eq!(v.dtype(), Dtype::I32);
        assert!(v.into_tensor().is_err());
    }
}
