//! Manifest-driven artifact registry.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) is the
//! single source of truth for what was lowered: artifact -> HLO file +
//! typed input/output specs, model -> config + parameter order + weights.
//! The structs here parse it with the crate's own [`crate::json`].

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context};

use crate::config::ModelConfig;
use crate::json::Json;

/// Tensor dtype crossing the boundary (everything is f32 or i32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// One named input/output of an artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .context("spec missing name")?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(|v| v.as_usize_vec())
                .context("spec missing shape")?,
            dtype: Dtype::parse(
                j.get("dtype").and_then(|v| v.as_str()).context("spec missing dtype")?,
            )?,
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One lowered computation.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub model: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One model definition (config + canonical parameter order + weights).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub task: String,
    pub attention: String,
    pub config: ModelConfig,
    pub raw_config: Json,
    pub params: Vec<String>,
    pub param_shapes: BTreeMap<String, Vec<usize>>,
    pub weights: String,
}

/// The parsed manifest.
#[derive(Debug, Default)]
pub struct Bundle {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub models: BTreeMap<String, ModelSpec>,
}

impl Bundle {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Bundle> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Bundle> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        match j.get("format").and_then(|v| v.as_str()) {
            Some("hlo-text-v1") => {}
            other => bail!("unsupported manifest format {other:?}"),
        }
        let mut bundle = Bundle::default();

        let arts = j
            .get("artifacts")
            .and_then(|v| v.as_obj())
            .context("manifest missing artifacts")?;
        for (name, a) in arts {
            let parse_specs = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(|v| v.as_arr())
                    .with_context(|| format!("{name}: missing {key}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            bundle.artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a
                        .get("file")
                        .and_then(|v| v.as_str())
                        .with_context(|| format!("{name}: missing file"))?
                        .to_string(),
                    model: a
                        .get("model")
                        .and_then(|v| v.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }

        let models = j
            .get("models")
            .and_then(|v| v.as_obj())
            .context("manifest missing models")?;
        for (name, m) in models {
            let raw_config = m.get("config").cloned().unwrap_or(Json::Null);
            // bilstm model configs have a different schema; keep raw json
            // and parse ModelConfig only when the fields exist
            let config = ModelConfig::from_json(&raw_config).unwrap_or_else(|_| ModelConfig {
                vocab: 0,
                d_model: 0,
                n_heads: 1,
                n_layers: 0,
                max_len: 0,
                d_ff: 0,
                chunk: 1,
                causal: false,
                lsh_rounds: 1,
                lsh_buckets: 2,
                lsh_chunk: 1,
            });
            let params: Vec<String> = m
                .get("params")
                .and_then(|v| v.as_arr())
                .with_context(|| format!("model {name}: missing params"))?
                .iter()
                .map(|v| v.as_str().unwrap_or_default().to_string())
                .collect();
            let mut param_shapes = BTreeMap::new();
            if let Some(obj) = m.get("param_shapes").and_then(|v| v.as_obj()) {
                for (k, v) in obj {
                    if let Some(shape) = v.as_usize_vec() {
                        param_shapes.insert(k.clone(), shape);
                    }
                }
            }
            bundle.models.insert(
                name.clone(),
                ModelSpec {
                    name: name.clone(),
                    task: m
                        .get("task")
                        .and_then(|v| v.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    attention: m
                        .get("attention")
                        .and_then(|v| v.as_str())
                        .unwrap_or_default()
                        .to_string(),
                    config,
                    raw_config,
                    params,
                    param_shapes,
                    weights: m
                        .get("weights")
                        .and_then(|v| v.as_str())
                        .unwrap_or_default()
                        .to_string(),
                },
            );
        }
        Ok(bundle)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn model(&self, name: &str) -> Option<&ModelSpec> {
        self.models.get(name)
    }

    /// Artifact names matching a predicate (e.g. all `*_train`).
    pub fn artifact_names_where(&self, pred: impl Fn(&str) -> bool) -> Vec<String> {
        self.artifacts
            .keys()
            .filter(|k| pred(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text-v1",
      "models": {
        "copy_linear": {
          "task": "copy", "attention": "linear",
          "config": {"vocab": 13, "d_model": 128, "n_heads": 4, "n_layers": 4,
                     "max_len": 128, "d_ff": 512, "chunk": 16, "causal": true,
                     "lsh_rounds": 1, "lsh_buckets": 16, "lsh_chunk": 32,
                     "attention": "linear"},
          "params": ["embed.tok", "head.w"],
          "param_shapes": {"embed.tok": [13, 128], "head.w": [128, 13]},
          "weights": "copy_linear_init.ltw"
        }
      },
      "artifacts": {
        "copy_linear_train": {
          "file": "copy_linear_train.hlo.txt",
          "model": "copy_linear",
          "inputs": [{"name": "param:embed.tok", "shape": [13, 128], "dtype": "f32"},
                     {"name": "in:inputs", "shape": [32, 128], "dtype": "i32"}],
          "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let b = Bundle::parse(SAMPLE).unwrap();
        let a = b.artifact("copy_linear_train").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].dtype, Dtype::I32);
        assert_eq!(a.outputs[0].shape, Vec::<usize>::new());
        let m = b.model("copy_linear").unwrap();
        assert_eq!(m.config.vocab, 13);
        assert_eq!(m.params, vec!["embed.tok", "head.w"]);
        assert_eq!(m.param_shapes["embed.tok"], vec![13, 128]);
    }

    #[test]
    fn rejects_wrong_format() {
        assert!(Bundle::parse(r#"{"format": "v999", "models": {}, "artifacts": {}}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let b = Bundle::parse(&text).unwrap();
            assert!(b.artifacts.len() >= 30, "expected full artifact set");
            let m = b.model("copy_linear").unwrap();
            assert_eq!(m.config.vocab, 13);
            // every train artifact's input count = 3 * params + 2 + batch
            let a = b.artifact("copy_linear_train").unwrap();
            assert_eq!(a.inputs.len(), 3 * m.params.len() + 2 + 3);
        }
    }
}
