//! Dense f32 tensor substrate for the native inference path.
//!
//! Deliberately small: row-major `Vec<f32>` storage, shape metadata, and
//! the handful of kernels a transformer needs (GEMM, GEMV, layernorm,
//! softmax, elu+1, outer-product updates, per-head column
//! gather/scatter for the decode and prefill chunk passes). The GEMM
//! uses the i-k-j loop
//! order so the inner loop streams rows of `b` — LLVM auto-vectorizes it;
//! see EXPERIMENTS.md §Perf for measured numbers.

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::rng::Rng) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(shape.iter().product(), std),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "dims2 on rank-{} tensor", self.rank());
        (self.shape[0], self.shape[1])
    }

    /// Borrow row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let (r, c) = self.dims2();
        assert!(i < r);
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (r, c) = self.dims2();
        assert!(i < r);
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.numel());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose (copies).
    pub fn transpose2(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Elementwise map (copies).
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

// ---------------------------------------------------------------------------
// GEMM / GEMV kernels (operate on raw slices for the hot paths)
// ---------------------------------------------------------------------------

/// c[m,n] = a[m,k] @ b[k,n]  (i-k-j order: inner loop streams rows of b).
/// The inner loop is [`axpy`], so it runs on the active SIMD tier —
/// per-element order is tier-independent (see [`crate::simd`]).
// lintra: bitwise-critical
pub fn matmul_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            axpy(crow, aik, &b[kk * n..(kk + 1) * n]);
        }
    }
}

// ---------------------------------------------------------------------------
// pooled kernel variants (multi-core, bit-identical to the serial forms)
// ---------------------------------------------------------------------------
//
// Each `_pooled` kernel partitions its work over *output rows/lanes only*
// and runs the plain serial kernel on every block, so the float-op order
// of each output row is unchanged and `pooled == serial` holds bitwise
// under any thread count (asserted by the `pooled_*` tests below and the
// batched-parity suites). `None` (or work under the fan-out threshold)
// falls straight through to the serial kernel.

use crate::parallel::ThreadPool;

// The dispatch thresholds migrated to the central tunables module
// (PR 10); the re-export keeps the historical `tensor::PAR_*` paths
// working for call sites and tests.
pub use crate::tunables::{PAR_MIN_GEMV_COLS, PAR_MIN_ROW_ELEMS, PAR_MIN_WORK};

use crate::tunables::{GEMM_PACK_MIN_ROWS, NR};

/// [`matmul_into`] partitioned over row blocks of `c` across the pool.
// lintra: bitwise-critical
pub fn matmul_into_pooled(
    pool: Option<&ThreadPool>,
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && m >= 2 && m * k * n >= PAR_MIN_WORK => {
            assert_eq!(a.len(), m * k);
            assert_eq!(b.len(), k * n);
            assert_eq!(c.len(), m * n);
            p.for_row_blocks(m, n, c, |row0, cblk| {
                let rows = cblk.len() / n;
                matmul_into(cblk, &a[row0 * k..(row0 + rows) * k], b, rows, k, n);
            });
        }
        Some(p) if p.threads() > 1 && m == 1 && n >= PAR_MIN_GEMV_COLS && k * n >= PAR_MIN_WORK => {
            // a single output row is a GEMV: split output *columns*
            // instead (disjoint per-thread slices, each column's dot
            // product still serial — see vecmat_into_cols_pooled)
            vecmat_into_cols_pooled(Some(p), c, a, b, k, n)
        }
        _ => matmul_into(c, a, b, m, k, n),
    }
}

/// [`batched_outer_acc`] partitioned over lanes of `s` across the pool.
// lintra: bitwise-critical
pub fn batched_outer_acc_pooled(
    pool: Option<&ThreadPool>,
    s: &mut [f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    d: usize,
    m: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && b >= 2 && b * d * m >= PAR_MIN_WORK => {
            assert_eq!(s.len(), b * d * m);
            assert_eq!(k.len(), b * d);
            assert_eq!(v.len(), b * m);
            p.for_row_blocks(b, d * m, s, |r0, sblk| {
                let lanes = sblk.len() / (d * m);
                batched_outer_acc(
                    sblk,
                    &k[r0 * d..(r0 + lanes) * d],
                    &v[r0 * m..(r0 + lanes) * m],
                    lanes,
                    d,
                    m,
                );
            });
        }
        _ => batched_outer_acc(s, k, v, b, d, m),
    }
}

/// [`batched_contract`] partitioned over lanes of `out` across the pool.
// lintra: bitwise-critical
pub fn batched_contract_pooled(
    pool: Option<&ThreadPool>,
    out: &mut [f32],
    q: &[f32],
    s: &[f32],
    b: usize,
    d: usize,
    m: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && b >= 2 && b * d * m >= PAR_MIN_WORK => {
            assert_eq!(out.len(), b * m);
            assert_eq!(q.len(), b * d);
            assert_eq!(s.len(), b * d * m);
            p.for_row_blocks(b, m, out, |r0, oblk| {
                let lanes = oblk.len() / m;
                batched_contract(
                    oblk,
                    &q[r0 * d..(r0 + lanes) * d],
                    &s[r0 * d * m..(r0 + lanes) * d * m],
                    lanes,
                    d,
                    m,
                );
            });
        }
        _ => batched_contract(out, q, s, b, d, m),
    }
}

/// [`layer_norm_rows`] partitioned over rows of `out` across the pool.
// lintra: bitwise-critical
pub fn layer_norm_rows_pooled(
    pool: Option<&ThreadPool>,
    out: &mut [f32],
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    b: usize,
) {
    let n = gamma.len();
    match pool {
        Some(p) if p.threads() > 1 && b >= 2 && b * n >= PAR_MIN_ROW_ELEMS => {
            assert_eq!(out.len(), b * n);
            assert_eq!(x.len(), b * n);
            p.for_row_blocks(b, n, out, |r0, oblk| {
                let rows = oblk.len() / n;
                layer_norm_rows(oblk, &x[r0 * n..(r0 + rows) * n], gamma, beta, rows);
            });
        }
        _ => layer_norm_rows(out, x, gamma, beta, b),
    }
}

/// a[m,k] @ b[k,n] allocating the output.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(&mut out.data, &a.data, &b.data, m, k, n);
    out
}

/// y[n] = x[k] @ b[k,n] — GEMV against a row-major matrix.
///
/// Deliberately the simple streaming loop: the decode hot path is
/// weight-bandwidth bound (§Perf — ~18 GB/s effective on this core, at the
/// practical roofline), and both a 2-row unroll and target-cpu=native
/// measured within noise (<5%), so the clearest form wins.
// lintra: bitwise-critical
pub fn vecmat_into(y: &mut [f32], x: &[f32], b: &[f32], k: usize, n: usize) {
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), n);
    assert!(b.len() >= k * n);
    y.fill(0.0);
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        axpy(y, xv, &b[kk * n..(kk + 1) * n]);
    }
}

// ---------------------------------------------------------------------------
// batched decode kernels (structure-of-arrays over B independent lanes)
// ---------------------------------------------------------------------------

/// Batched outer-product accumulate: `s[r] += k[r] ⊗ v[r]` for every lane.
///
/// `s: [b, d, m]`, `k: [b, d]`, `v: [b, m]` — eq. 18 of the paper applied
/// to all B decode lanes in one sweep over contiguous memory.
// lintra: bitwise-critical
pub fn batched_outer_acc(s: &mut [f32], k: &[f32], v: &[f32], b: usize, d: usize, m: usize) {
    assert_eq!(s.len(), b * d * m);
    assert_eq!(k.len(), b * d);
    assert_eq!(v.len(), b * m);
    for r in 0..b {
        let kr = &k[r * d..(r + 1) * d];
        let vr = &v[r * m..(r + 1) * m];
        let sr = &mut s[r * d * m..(r + 1) * d * m];
        for (t, &kt) in kr.iter().enumerate() {
            if kt != 0.0 {
                axpy(&mut sr[t * m..(t + 1) * m], kt, vr);
            }
        }
    }
}

/// Batched per-lane contraction: `out[r] = q[r]^T · s[r]` for every lane.
///
/// `out: [b, m]`, `q: [b, d]`, `s: [b, d, m]` — the numerator of eq. 20
/// for all B decode lanes.
// lintra: bitwise-critical
pub fn batched_contract(out: &mut [f32], q: &[f32], s: &[f32], b: usize, d: usize, m: usize) {
    assert_eq!(out.len(), b * m);
    assert_eq!(q.len(), b * d);
    assert_eq!(s.len(), b * d * m);
    for r in 0..b {
        let qr = &q[r * d..(r + 1) * d];
        let sr = &s[r * d * m..(r + 1) * d * m];
        let or = &mut out[r * m..(r + 1) * m];
        or.fill(0.0);
        for (t, &qt) in qr.iter().enumerate() {
            if qt != 0.0 {
                axpy(or, qt, &sr[t * m..(t + 1) * m]);
            }
        }
    }
}

/// Row-wise phi: `dst = elu(src) + 1` over a `[b, d]` block.
pub fn elu_plus_one_map(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (o, &x) in dst.iter_mut().zip(src) {
        *o = elu_plus_one(x);
    }
}

/// Layer norm over the last axis of every row of a `[b, n]` block.
// lintra: bitwise-critical
pub fn layer_norm_rows(out: &mut [f32], x: &[f32], gamma: &[f32], beta: &[f32], b: usize) {
    let n = gamma.len();
    assert_eq!(out.len(), b * n);
    assert_eq!(x.len(), b * n);
    for r in 0..b {
        layer_norm_into(&mut out[r * n..(r + 1) * n], &x[r * n..(r + 1) * n], gamma, beta);
    }
}

/// Add a bias vector to every row of a `[b, n]` block.
pub fn add_bias_rows(x: &mut [f32], bias: &[f32], b: usize) {
    let n = bias.len();
    assert_eq!(x.len(), b * n);
    for r in 0..b {
        for (xv, &bv) in x[r * n..(r + 1) * n].iter_mut().zip(bias) {
            *xv += bv;
        }
    }
}

/// Gather a column block out of a `[rows, src_cols]` matrix:
/// `dst[r, :] = src[r, col0 .. col0 + nc]`.
///
/// This is the per-head slice step of both the decode tick and the
/// prefill chunk pass (pull one head's `[·, d_head]` columns out of the
/// fused `[·, d_model]` QKV projections).
pub fn gather_cols(
    dst: &mut [f32],
    src: &[f32],
    rows: usize,
    src_cols: usize,
    col0: usize,
    nc: usize,
) {
    assert_eq!(dst.len(), rows * nc);
    assert!(src.len() >= rows * src_cols);
    assert!(col0 + nc <= src_cols);
    for r in 0..rows {
        let s = r * src_cols + col0;
        dst[r * nc..(r + 1) * nc].copy_from_slice(&src[s..s + nc]);
    }
}

/// Scatter a column block back into a `[rows, dst_cols]` matrix:
/// `dst[r, col0 .. col0 + nc] = src[r, :]` — the inverse of [`gather_cols`].
pub fn scatter_cols(
    dst: &mut [f32],
    src: &[f32],
    rows: usize,
    dst_cols: usize,
    col0: usize,
    nc: usize,
) {
    assert_eq!(src.len(), rows * nc);
    assert!(dst.len() >= rows * dst_cols);
    assert!(col0 + nc <= dst_cols);
    for r in 0..rows {
        let d = r * dst_cols + col0;
        dst[d..d + nc].copy_from_slice(&src[r * nc..(r + 1) * nc]);
    }
}

/// dot product.
// lintra: bitwise-critical
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// y += alpha * x — dispatched to the active SIMD tier
/// ([`crate::simd::axpy`]). Every tier updates each element with one
/// accumulator in ascending index order (separate mul-then-add), so the
/// result is identical on all of them; this single dispatch point is
/// what vectorizes `vecmat_into` / `matmul_into` / the batched
/// attention kernels in one move.
// lintra: bitwise-critical
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    crate::simd::axpy(y, alpha, x);
}

// ---------------------------------------------------------------------------
// neural-net primitives
// ---------------------------------------------------------------------------

/// In-place stable softmax over a row.
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// Layer norm over the last axis of a row, writing into `out`.
pub fn layer_norm_into(out: &mut [f32], x: &[f32], gamma: &[f32], beta: &[f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * gamma[i] + beta[i];
    }
}

/// The paper's feature map phi(x) = elu(x) + 1 (eq. 7).
#[inline]
pub fn elu_plus_one(x: f32) -> f32 {
    if x >= 0.0 {
        x + 1.0
    } else {
        x.exp() // elu(x)+1 = exp(x)-1+1
    }
}

/// Apply phi in place.
pub fn elu_plus_one_inplace(row: &mut [f32]) {
    for x in row.iter_mut() {
        *x = elu_plus_one(*x);
    }
}

/// GELU (tanh approximation, matches jax.nn.gelu's default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.797_884_56) * (x + 0.044_715 * x * x * x)).tanh())
}

// ---------------------------------------------------------------------------
// low-precision weight storage (f16 / bf16 / int8-per-row-scale)
// ---------------------------------------------------------------------------
//
// The decode hot path is weight-bandwidth bound (see `vecmat_into`), so
// the projection matrices can be *stored* narrow and widened to f32 in
// registers inside the kernel inner loop: compute stays f32, only the
// bytes streamed from memory shrink. The numeric contract lives in the
// accumulation order: every widening kernel computes each output element
// with a single accumulator in pure k-ascending order, so a given
// (weights, input) pair produces bit-identical results regardless of the
// batch row count, prompt chunking, or how a pool partitions output
// columns. The f32 `WeightMat` variant reproduces `vecmat_into` /
// `matmul_into` per-element order exactly (including the zero-skip), so
// routing f32 weights through these kernels stays bitwise with the
// legacy path.

/// Conversion: f32 -> IEEE 754 binary16 bits, round-to-nearest-even.
#[inline]
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // inf stays inf; NaN keeps NaN-ness via the quiet bit
        let mant = if abs > 0x7f80_0000 { 0x0200 } else { 0 };
        return sign | 0x7c00 | mant;
    }
    if abs >= 0x4780_0000 {
        // >= 2^16: past the largest finite f16 even after rounding
        return sign | 0x7c00;
    }
    if abs < 0x3880_0000 {
        // below the smallest f16 normal (2^-14): subnormal or zero
        if abs < 0x3300_0000 {
            return sign; // < 2^-25 rounds to (signed) zero
        }
        let e = (abs >> 23) as i32; // 102..=112
        let mant = (abs & 0x007f_ffff) | 0x0080_0000;
        let shift = (126 - e) as u32; // 14..=24
        let mut h = (mant >> shift) as u16;
        let rem = mant & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        if rem > halfway || (rem == halfway && (h & 1) == 1) {
            h += 1; // a carry lands exactly on the smallest normal
        }
        return sign | h;
    }
    // normal range: rebias exponent, round 13 mantissa bits away
    let e = ((abs >> 23) as u32) - 112; // 1..=30
    let mant = abs & 0x007f_ffff;
    let mut h = ((e << 10) | (mant >> 13)) as u16;
    let rem = mant & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
        h += 1; // mantissa carry walks into the exponent; 65520..65536
                // correctly lands on the inf encoding this way
    }
    sign | h
}

/// Conversion: IEEE 754 binary16 bits -> f32 (exact).
#[inline]
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13) // inf / NaN
    } else if exp == 0 {
        if mant == 0 {
            sign // signed zero
        } else {
            // subnormal: normalize into an f32 normal
            let mut e = 113u32;
            let mut m = mant;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Conversion: f32 -> bfloat16 bits, round-to-nearest-even.
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if bits & 0x7fff_ffff > 0x7f80_0000 {
        // NaN: rounding could carry the payload up into inf; quiet it
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x7fff + lsb) >> 16) as u16
}

/// Conversion: bfloat16 bits -> f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Quantize one weight row to int8 with a shared absmax scale; returns
/// the scale (`value ~= q * scale`). An all-zero row gets scale 0.
pub fn quantize_row_i8(row: &[f32], out: &mut [i8]) -> f32 {
    assert_eq!(row.len(), out.len());
    let max_abs = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if max_abs == 0.0 {
        out.fill(0);
        return 0.0;
    }
    let inv = 127.0 / max_abs;
    for (o, &v) in out.iter_mut().zip(row) {
        *o = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    max_abs / 127.0
}

/// Storage precision for model weights (activations stay f32 everywhere).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightDtype {
    /// 4 bytes/elem — the bitwise reference path.
    F32,
    /// 2 bytes/elem, IEEE binary16 (10-bit mantissa).
    F16,
    /// 2 bytes/elem, bfloat16 (8-bit mantissa, f32 exponent range).
    Bf16,
    /// 1 byte/elem plus one f32 absmax scale per weight row.
    Int8,
}

impl WeightDtype {
    /// Parse a user-facing dtype name (trimmed, case-insensitive).
    pub fn parse(s: &str) -> Option<WeightDtype> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(WeightDtype::F32),
            "f16" | "fp16" | "float16" | "half" => Some(WeightDtype::F16),
            "bf16" | "bfloat16" => Some(WeightDtype::Bf16),
            "int8" | "i8" => Some(WeightDtype::Int8),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WeightDtype::F32 => "f32",
            WeightDtype::F16 => "f16",
            WeightDtype::Bf16 => "bf16",
            WeightDtype::Int8 => "int8",
        }
    }

    /// Bytes per element for the packed payload (int8 scales excluded).
    pub fn bytes_per_elem(self) -> usize {
        match self {
            WeightDtype::F32 => 4,
            WeightDtype::F16 | WeightDtype::Bf16 => 2,
            WeightDtype::Int8 => 1,
        }
    }
}

/// A packed `[k, n]` weight matrix (row-major, like the `Tensor` it came
/// from). Shape is carried by the call sites, exactly as the raw-slice
/// kernels above do.
#[derive(Clone, Debug)]
pub enum WeightMat {
    F32 { data: Vec<f32> },
    F16 { bits: Vec<u16> },
    Bf16 { bits: Vec<u16> },
    Int8 { packed: Vec<i8>, scales: Vec<f32> },
}

impl WeightMat {
    /// Pack a row-major `[rows, cols]` f32 matrix at the given precision.
    ///
    /// Quantization is idempotent: packing the widened (`dequantize`d)
    /// matrix again yields the same bits for f16/bf16 (the widened values
    /// are exactly representable), which is what makes an offline
    /// `lintra cast` bundle reproduce the in-memory cast exactly.
    pub fn quantize(data: &[f32], rows: usize, cols: usize, dtype: WeightDtype) -> WeightMat {
        assert_eq!(data.len(), rows * cols);
        match dtype {
            WeightDtype::F32 => WeightMat::F32 { data: data.to_vec() },
            WeightDtype::F16 => WeightMat::F16 {
                bits: data.iter().map(|&v| f32_to_f16_bits(v)).collect(),
            },
            WeightDtype::Bf16 => WeightMat::Bf16 {
                bits: data.iter().map(|&v| f32_to_bf16_bits(v)).collect(),
            },
            WeightDtype::Int8 => {
                let mut packed = vec![0i8; rows * cols];
                let mut scales = vec![0.0f32; rows];
                for r in 0..rows {
                    scales[r] =
                        quantize_row_i8(&data[r * cols..(r + 1) * cols], &mut packed[r * cols..(r + 1) * cols]);
                }
                WeightMat::Int8 { packed, scales }
            }
        }
    }

    pub fn dtype(&self) -> WeightDtype {
        match self {
            WeightMat::F32 { .. } => WeightDtype::F32,
            WeightMat::F16 { .. } => WeightDtype::F16,
            WeightMat::Bf16 { .. } => WeightDtype::Bf16,
            WeightMat::Int8 { .. } => WeightDtype::Int8,
        }
    }

    /// Widen every element back to f32 (`cols` is the row length, needed
    /// to apply the int8 per-row scales).
    pub fn dequantize(&self, cols: usize) -> Vec<f32> {
        match self {
            WeightMat::F32 { data } => data.clone(),
            WeightMat::F16 { bits } => bits.iter().map(|&b| f16_bits_to_f32(b)).collect(),
            WeightMat::Bf16 { bits } => bits.iter().map(|&b| bf16_bits_to_f32(b)).collect(),
            WeightMat::Int8 { packed, scales } => {
                let mut out = Vec::with_capacity(packed.len());
                for (r, row) in packed.chunks_exact(cols).enumerate() {
                    let s = scales[r];
                    out.extend(row.iter().map(|&q| q as f32 * s));
                }
                out
            }
        }
    }

    /// Bytes this matrix streams from memory per full GEMV pass.
    pub fn weight_bytes(&self) -> usize {
        match self {
            WeightMat::F32 { data } => data.len() * 4,
            WeightMat::F16 { bits } | WeightMat::Bf16 { bits } => bits.len() * 2,
            WeightMat::Int8 { packed, scales } => packed.len() + scales.len() * 4,
        }
    }
}

// ---------------------------------------------------------------------------
// widening GEMV/GEMM microkernels over packed weights
// ---------------------------------------------------------------------------

/// Core widening GEMV over a column range: writes
/// `y[j] = sum_k coeff(k) * widen(w[k, col0 + j])` for `j in 0..y.len()`.
///
/// NR-wide column tiles with a 4-unrolled k loop; every output element
/// uses ONE accumulator updated in k-ascending order (the unroll issues
/// its four adds sequentially), so results are independent of the column
/// partition, the tile width, and the unroll — the property the pooled
/// column split and the batched/single-row call sites all rely on.
/// Unlike the f32 path there is no `== 0.0` skip: the dense decode
/// stream almost never carries exact zeros, and the branch would stall
/// the unrolled loads.
// lintra: bitwise-critical
#[inline(always)]
fn gemv_cols_widen<W: Copy>(
    y: &mut [f32],
    w: &[W],
    k: usize,
    n: usize,
    col0: usize,
    coeff: impl Fn(usize) -> f32,
    widen: impl Fn(W) -> f32 + Copy,
) {
    let nc = y.len();
    assert!(col0 + nc <= n);
    assert!(w.len() >= k * n);
    let mut j = 0;
    while j + NR <= nc {
        let base = col0 + j;
        let mut acc = [0.0f32; NR];
        let mut kk = 0;
        while kk + 4 <= k {
            let c0 = coeff(kk);
            let c1 = coeff(kk + 1);
            let c2 = coeff(kk + 2);
            let c3 = coeff(kk + 3);
            let r0 = &w[kk * n + base..kk * n + base + NR];
            let r1 = &w[(kk + 1) * n + base..(kk + 1) * n + base + NR];
            let r2 = &w[(kk + 2) * n + base..(kk + 2) * n + base + NR];
            let r3 = &w[(kk + 3) * n + base..(kk + 3) * n + base + NR];
            for t in 0..NR {
                let mut a = acc[t];
                a += c0 * widen(r0[t]);
                a += c1 * widen(r1[t]);
                a += c2 * widen(r2[t]);
                a += c3 * widen(r3[t]);
                acc[t] = a;
            }
            kk += 4;
        }
        while kk < k {
            let c = coeff(kk);
            let row = &w[kk * n + base..kk * n + base + NR];
            for t in 0..NR {
                acc[t] += c * widen(row[t]);
            }
            kk += 1;
        }
        y[j..j + NR].copy_from_slice(&acc);
        j += NR;
    }
    while j < nc {
        let col = col0 + j;
        let mut acc = 0.0f32;
        for kk in 0..k {
            acc += coeff(kk) * widen(w[kk * n + col]);
        }
        y[j] = acc;
        j += 1;
    }
}

/// f32 GEMV over a column range, replicating [`vecmat_into`]'s
/// per-element float-op order exactly (k-ascending with the zero-skip),
/// so a column-partitioned run is bit-identical to the serial kernel.
/// The inner loop is [`axpy`], so it runs on the active SIMD tier.
// lintra: bitwise-critical
fn gemv_cols_f32(y: &mut [f32], x: &[f32], b: &[f32], k: usize, n: usize, col0: usize) {
    let nc = y.len();
    assert_eq!(x.len(), k);
    assert!(col0 + nc <= n);
    assert!(b.len() >= k * n);
    y.fill(0.0);
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        axpy(y, xv, &b[kk * n + col0..kk * n + col0 + nc]);
    }
}

/// Dispatch one GEMV column range against a packed weight matrix. The
/// narrow dtypes first offer the range to the [`crate::simd`] widening
/// kernels (taken on the `Avx2` tier, bitwise-identical — the widening
/// conversions are exact and the accumulation order matches); a declined
/// offer falls back to the scalar [`gemv_cols_widen`], the single source
/// of truth for the reference order.
// lintra: bitwise-critical
fn gemv_cols_w(y: &mut [f32], x: &[f32], w: &WeightMat, k: usize, n: usize, col0: usize) {
    assert_eq!(x.len(), k);
    match w {
        WeightMat::F32 { data } => gemv_cols_f32(y, x, data, k, n, col0),
        WeightMat::F16 { bits } => {
            if !crate::simd::try_gemv_cols_f16(y, bits, x, k, n, col0) {
                gemv_cols_widen(y, bits, k, n, col0, |kk| x[kk], f16_bits_to_f32)
            }
        }
        WeightMat::Bf16 { bits } => {
            if !crate::simd::try_gemv_cols_bf16(y, bits, x, k, n, col0) {
                gemv_cols_widen(y, bits, k, n, col0, |kk| x[kk], bf16_bits_to_f32)
            }
        }
        WeightMat::Int8 { packed, scales } => {
            assert!(scales.len() >= k);
            if !crate::simd::try_gemv_cols_i8(y, packed, scales, x, k, n, col0) {
                // fold the per-row scale into the input coefficient once
                // per row: one multiply per element in the inner loop,
                // same as f16
                gemv_cols_widen(y, packed, k, n, col0, |kk| x[kk] * scales[kk], |q: i8| q as f32)
            }
        }
    }
}

/// y[n] = x[k] @ w[k,n] against a packed weight matrix ([`vecmat_into`]
/// for [`WeightMat`]; bitwise-equal to it on the `F32` variant).
// lintra: bitwise-critical
pub fn vecmat_into_w(y: &mut [f32], x: &[f32], w: &WeightMat, k: usize, n: usize) {
    assert_eq!(y.len(), n);
    gemv_cols_w(y, x, w, k, n, 0);
}

/// c[m,n] = a[m,k] @ w[k,n] against a packed weight matrix. Each output
/// row runs the exact single-row kernel, so results never depend on `m`
/// (prefill chunking == decode ticks, like the f32 path). At
/// [`GEMM_PACK_MIN_ROWS`] rows and above the cache-blocked
/// [`matmul_into_w_packed`] takes over — bitwise-identical by
/// construction (packing is pure data movement), just faster.
// lintra: bitwise-critical
pub fn matmul_into_w(c: &mut [f32], a: &[f32], w: &WeightMat, m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(c.len(), m * n);
    if m >= GEMM_PACK_MIN_ROWS && n >= NR && k > 0 {
        matmul_into_w_packed(c, a, w, m, k, n);
        return;
    }
    for i in 0..m {
        gemv_cols_w(&mut c[i * n..(i + 1) * n], &a[i * k..(i + 1) * k], w, k, n, 0);
    }
}

thread_local! {
    /// Panel scratch for [`matmul_into_w_packed`]: one widened k×NR
    /// column panel plus a k-length coefficient row, reused across calls
    /// so the packed path only allocates on first use (or growth) per
    /// thread — the steady-state prefill loop is allocation-free.
    static PACK_SCRATCH: std::cell::RefCell<Vec<f32>> = std::cell::RefCell::new(Vec::new());
}

/// Widen one k×[`NR`] column panel of `w` (columns `col0..col0+NR`) into
/// row-major `panel[kk * NR + t]`. Pure data movement: these are the
/// exact same widened f32 values the streaming kernels read in place
/// ([`f16_bits_to_f32`] / [`bf16_bits_to_f32`] / `i8 as f32` are all
/// exact conversions), so consuming the panel cannot change a bit.
fn pack_panel_w(panel: &mut [f32], w: &WeightMat, k: usize, n: usize, col0: usize) {
    debug_assert!(panel.len() >= k * NR);
    match w {
        WeightMat::F32 { data } => {
            for kk in 0..k {
                panel[kk * NR..kk * NR + NR]
                    .copy_from_slice(&data[kk * n + col0..kk * n + col0 + NR]);
            }
        }
        WeightMat::F16 { bits } => {
            for kk in 0..k {
                let row = &bits[kk * n + col0..kk * n + col0 + NR];
                for (t, &b) in row.iter().enumerate() {
                    panel[kk * NR + t] = f16_bits_to_f32(b);
                }
            }
        }
        WeightMat::Bf16 { bits } => {
            for kk in 0..k {
                let row = &bits[kk * n + col0..kk * n + col0 + NR];
                for (t, &b) in row.iter().enumerate() {
                    panel[kk * NR + t] = bf16_bits_to_f32(b);
                }
            }
        }
        WeightMat::Int8 { packed, .. } => {
            for kk in 0..k {
                let row = &packed[kk * n + col0..kk * n + col0 + NR];
                for (t, &q) in row.iter().enumerate() {
                    panel[kk * NR + t] = q as f32;
                }
            }
        }
    }
}

/// Cache-blocked GEMM over a packed weight matrix: for each NR-wide
/// column tile, widen the k×NR panel once into thread-local scratch and
/// stream every row of `a` through it, amortizing the dtype conversion
/// `m` ways and turning the strided column-tile walk into sequential
/// loads. Bitwise contract: every output element still accumulates its
/// full k range in ascending order through ONE accumulator (the panel
/// row kernels in [`crate::simd`] enforce this at both ISA tiers), and
/// the panel holds the exact widened values the streaming path reads,
/// so packed == streaming bitwise for every dtype. The f32 tile kernel
/// keeps the `== 0.0` coefficient skip; the widened dtypes stay dense —
/// both exactly as in the streaming kernels.
// lintra: bitwise-critical
fn matmul_into_w_packed(c: &mut [f32], a: &[f32], w: &WeightMat, m: usize, k: usize, n: usize) {
    let tiles = n / NR;
    PACK_SCRATCH.with(|cell| {
        let mut buf = cell.borrow_mut();
        buf.resize(k * (NR + 1), 0.0);
        let (panel, coeffs) = buf.split_at_mut(k * NR);
        for tile in 0..tiles {
            let col0 = tile * NR;
            pack_panel_w(panel, w, k, n, col0);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let out = &mut c[i * n + col0..i * n + col0 + NR];
                match w {
                    WeightMat::F32 { .. } => crate::simd::panel_row_f32_skip(out, arow, panel),
                    WeightMat::F16 { .. } | WeightMat::Bf16 { .. } => {
                        crate::simd::panel_row_dense(out, arow, panel)
                    }
                    WeightMat::Int8 { scales, .. } => {
                        // same coefficient the streaming kernel folds per
                        // row: x[kk] * scales[kk], computed once per tile
                        // row instead of once per column tile element
                        for (kk, cf) in coeffs.iter_mut().enumerate() {
                            *cf = arow[kk] * scales[kk];
                        }
                        crate::simd::panel_row_dense(out, coeffs, panel)
                    }
                }
            }
        }
    });
    // remainder columns that don't fill a tile run the streaming kernel
    let done = tiles * NR;
    if done < n {
        for i in 0..m {
            gemv_cols_w(&mut c[i * n + done..(i + 1) * n], &a[i * k..(i + 1) * k], w, k, n, done);
        }
    }
}

/// Pooled column-split GEMV: partitions *output columns* across the pool
/// (each worker owns a disjoint contiguous column range — no reduction
/// is ever split), so a B=1 decode tick finally scales with cores. Each
/// column's dot product runs in the serial kernel's exact float order,
/// so the result is bit-identical to [`vecmat_into`] under any thread
/// count — the partition only decides ownership.
// lintra: bitwise-critical
pub fn vecmat_into_cols_pooled(
    pool: Option<&ThreadPool>,
    y: &mut [f32],
    x: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && n >= PAR_MIN_GEMV_COLS && k * n >= PAR_MIN_WORK => {
            assert_eq!(x.len(), k);
            assert_eq!(y.len(), n);
            assert!(b.len() >= k * n);
            // columns become the "rows" of a [n, 1] output block
            p.for_row_blocks(n, 1, y, |col0, yblk| {
                gemv_cols_f32(yblk, x, b, k, n, col0);
            });
        }
        _ => vecmat_into(y, x, b, k, n),
    }
}

/// [`vecmat_into_w`] with the same pooled column split as
/// [`vecmat_into_cols_pooled`] (widening kernels are column-partition
/// independent by construction, see [`gemv_cols_widen`]).
// lintra: bitwise-critical
pub fn vecmat_into_w_cols_pooled(
    pool: Option<&ThreadPool>,
    y: &mut [f32],
    x: &[f32],
    w: &WeightMat,
    k: usize,
    n: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && n >= PAR_MIN_GEMV_COLS && k * n >= PAR_MIN_WORK => {
            assert_eq!(y.len(), n);
            p.for_row_blocks(n, 1, y, |col0, yblk| {
                gemv_cols_w(yblk, x, w, k, n, col0);
            });
        }
        _ => vecmat_into_w(y, x, w, k, n),
    }
}

/// [`matmul_into_w`] partitioned across the pool: row blocks for m >= 2
/// (like [`matmul_into_pooled`]), the column split for the m == 1 GEMV
/// shape that row partitioning cannot touch.
// lintra: bitwise-critical
pub fn matmul_into_w_pooled(
    pool: Option<&ThreadPool>,
    c: &mut [f32],
    a: &[f32],
    w: &WeightMat,
    m: usize,
    k: usize,
    n: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && m >= 2 && m * k * n >= PAR_MIN_WORK => {
            assert_eq!(a.len(), m * k);
            assert_eq!(c.len(), m * n);
            p.for_row_blocks(m, n, c, |row0, cblk| {
                let rows = cblk.len() / n;
                matmul_into_w(cblk, &a[row0 * k..(row0 + rows) * k], w, rows, k, n);
            });
        }
        Some(p) if p.threads() > 1 && m == 1 && n >= PAR_MIN_GEMV_COLS && k * n >= PAR_MIN_WORK => {
            vecmat_into_w_cols_pooled(Some(p), c, a, w, k, n)
        }
        _ => matmul_into_w(c, a, w, m, k, n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.data[i * k + kk] * b.data[kk * n + j];
                }
                c.data[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (8, 16, 8), (17, 9, 13)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let fast = matmul(&a, &b);
            let slow = naive_matmul(&a, &b);
            assert!(fast.max_abs_diff(&slow) < 1e-4);
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.data[i * 4 + i] = 1.0;
        }
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    #[should_panic(expected = "matmul inner dims")]
    fn matmul_shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        matmul(&a, &b);
    }

    #[test]
    fn vecmat_matches_matmul() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[1, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let full = matmul(&x, &b);
        let mut y = vec![0.0; 5];
        vecmat_into(&mut y, &x.data, &b.data, 7, 5);
        for (a, b) in y.iter().zip(&full.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[5, 9], 1.0, &mut rng);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn softmax_is_distribution_and_order_preserving() {
        let mut row = vec![1.0, 3.0, 2.0, -1.0];
        softmax_inplace(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(row[1] > row[2] && row[2] > row[0] && row[0] > row[3]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut row = vec![1000.0, 1000.0];
        softmax_inplace(&mut row);
        assert!((row[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        layer_norm_into(&mut out, &x, &g, &b);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-2);
    }

    #[test]
    fn elu_plus_one_properties() {
        // positive everywhere in the working range, identity+1 for x >= 0
        for x in [-5.0f32, -1.0, -0.1, 0.0, 0.1, 2.0] {
            let y = elu_plus_one(x);
            assert!(y > 0.0, "phi({x}) = {y}");
        }
        assert_eq!(elu_plus_one(3.0), 4.0);
        assert!((elu_plus_one(-1.0) - (-1.0f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn gelu_reference_points() {
        assert!(gelu(0.0).abs() < 1e-6);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn rows_and_reshape() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.row(2), &[5., 6.]);
    }

    #[test]
    fn batched_outer_acc_matches_per_lane_loops() {
        let (b, d, m) = (3, 4, 5);
        let mut rng = Rng::new(7);
        let k = rng.normal_vec(b * d, 1.0);
        let v = rng.normal_vec(b * m, 1.0);
        let mut s = rng.normal_vec(b * d * m, 1.0);
        let mut expect = s.clone();
        for r in 0..b {
            for t in 0..d {
                for e in 0..m {
                    expect[(r * d + t) * m + e] += k[r * d + t] * v[r * m + e];
                }
            }
        }
        batched_outer_acc(&mut s, &k, &v, b, d, m);
        for (a, x) in s.iter().zip(&expect) {
            assert!((a - x).abs() < 1e-5);
        }
    }

    #[test]
    fn batched_contract_matches_per_lane_vecmat() {
        let (b, d, m) = (3, 4, 5);
        let mut rng = Rng::new(8);
        let q = rng.normal_vec(b * d, 1.0);
        let s = rng.normal_vec(b * d * m, 1.0);
        let mut out = vec![0.0; b * m];
        batched_contract(&mut out, &q, &s, b, d, m);
        for r in 0..b {
            let mut expect = vec![0.0; m];
            let (qr, sr) = (&q[r * d..(r + 1) * d], &s[r * d * m..(r + 1) * d * m]);
            vecmat_into(&mut expect, qr, sr, d, m);
            for e in 0..m {
                assert!((out[r * m + e] - expect[e]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn row_helpers_match_scalar_paths() {
        let (b, n) = (3, 4);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(b * n, 1.0);
        let gamma = rng.normal_vec(n, 1.0);
        let beta = rng.normal_vec(n, 1.0);
        let mut rows = vec![0.0; b * n];
        layer_norm_rows(&mut rows, &x, &gamma, &beta, b);
        for r in 0..b {
            let mut one = vec![0.0; n];
            layer_norm_into(&mut one, &x[r * n..(r + 1) * n], &gamma, &beta);
            for e in 0..n {
                assert!((rows[r * n + e] - one[e]).abs() < 1e-6);
            }
        }

        let mut mapped = vec![0.0; b * n];
        elu_plus_one_map(&mut mapped, &x);
        for (o, &v) in mapped.iter().zip(&x) {
            assert_eq!(*o, elu_plus_one(v));
        }

        let mut biased = x.clone();
        add_bias_rows(&mut biased, &beta, b);
        for r in 0..b {
            for e in 0..n {
                assert!((biased[r * n + e] - (x[r * n + e] + beta[e])).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gather_scatter_cols_roundtrip() {
        let (rows, cols) = (3, 8);
        let mut rng = Rng::new(10);
        let src = rng.normal_vec(rows * cols, 1.0);
        for (col0, nc) in [(0usize, 4usize), (4, 4), (2, 3)] {
            let mut block = vec![0.0; rows * nc];
            gather_cols(&mut block, &src, rows, cols, col0, nc);
            for r in 0..rows {
                for c in 0..nc {
                    assert_eq!(block[r * nc + c], src[r * cols + col0 + c]);
                }
            }
            let mut dst = vec![0.0; rows * cols];
            scatter_cols(&mut dst, &block, rows, cols, col0, nc);
            for r in 0..rows {
                for c in 0..cols {
                    let expect = if c >= col0 && c < col0 + nc {
                        src[r * cols + c]
                    } else {
                        0.0
                    };
                    assert_eq!(dst[r * cols + c], expect);
                }
            }
        }
    }

    #[test]
    fn pooled_matmul_is_bitwise_serial() {
        // shapes on both sides of the fan-out threshold, odd sizes included
        let pool = crate::parallel::ThreadPool::new(4);
        let mut rng = Rng::new(40);
        for &(m, k, n) in &[(1usize, 8usize, 8usize), (7, 33, 65), (33, 64, 96), (64, 128, 128)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut serial = vec![0.0; m * n];
            matmul_into(&mut serial, &a, &b, m, k, n);
            let mut pooled = vec![0.0; m * n];
            matmul_into_pooled(Some(&pool), &mut pooled, &a, &b, m, k, n);
            assert_eq!(pooled, serial, "pooled matmul {m}x{k}x{n} must be bit-identical");
            let mut unpooled = vec![0.0; m * n];
            matmul_into_pooled(None, &mut unpooled, &a, &b, m, k, n);
            assert_eq!(unpooled, serial, "None pool must run the serial kernel");
        }
    }

    #[test]
    fn pooled_batched_kernels_are_bitwise_serial() {
        let pool = crate::parallel::ThreadPool::new(4);
        let mut rng = Rng::new(41);
        for &(b, d, m) in &[(3usize, 4usize, 5usize), (9, 32, 48), (16, 32, 64), (5, 64, 64)] {
            let k = rng.normal_vec(b * d, 1.0);
            let v = rng.normal_vec(b * m, 1.0);
            let q = rng.normal_vec(b * d, 1.0);
            let s0 = rng.normal_vec(b * d * m, 1.0);

            let mut s_serial = s0.clone();
            batched_outer_acc(&mut s_serial, &k, &v, b, d, m);
            let mut s_pooled = s0.clone();
            batched_outer_acc_pooled(Some(&pool), &mut s_pooled, &k, &v, b, d, m);
            assert_eq!(s_pooled, s_serial, "outer_acc [{b},{d},{m}] must be bit-identical");

            let mut o_serial = vec![0.0; b * m];
            batched_contract(&mut o_serial, &q, &s_serial, b, d, m);
            let mut o_pooled = vec![0.0; b * m];
            batched_contract_pooled(Some(&pool), &mut o_pooled, &q, &s_pooled, b, d, m);
            assert_eq!(o_pooled, o_serial, "contract [{b},{d},{m}] must be bit-identical");
        }
    }

    #[test]
    fn pooled_layer_norm_is_bitwise_serial() {
        let pool = crate::parallel::ThreadPool::new(4);
        let mut rng = Rng::new(42);
        for &(b, n) in &[(3usize, 8usize), (17, 96), (64, 64)] {
            let x = rng.normal_vec(b * n, 1.0);
            let gamma = rng.normal_vec(n, 1.0);
            let beta = rng.normal_vec(n, 1.0);
            let mut serial = vec![0.0; b * n];
            layer_norm_rows(&mut serial, &x, &gamma, &beta, b);
            let mut pooled = vec![0.0; b * n];
            layer_norm_rows_pooled(Some(&pool), &mut pooled, &x, &gamma, &beta, b);
            assert_eq!(pooled, serial, "layer norm [{b},{n}] must be bit-identical");
        }
    }

    #[test]
    fn axpy_and_dot() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[3.0, 4.0]);
        assert_eq!(y, vec![7.0, 10.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    // -- low-precision storage ---------------------------------------------

    #[test]
    fn f16_conversion_exact_and_edge_cases() {
        // exactly representable values survive the round trip bitwise
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, -2.5, 1024.0, 65504.0, 6.1035156e-5] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(back.to_bits(), v.to_bits(), "f16 round trip broke {v}");
        }
        // subnormal: 2^-24 is the smallest positive f16
        assert_eq!(f32_to_f16_bits(5.9604645e-8), 0x0001);
        assert_eq!(f16_bits_to_f32(0x0001), 5.9604645e-8);
        // below half the smallest subnormal rounds to zero
        assert_eq!(f32_to_f16_bits(1e-9), 0x0000);
        assert_eq!(f32_to_f16_bits(-1e-9), 0x8000);
        // overflow: 65520 and above round to inf
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00);
        assert_eq!(f32_to_f16_bits(1e30), 0x7c00);
        assert_eq!(f32_to_f16_bits(-1e30), 0xfc00);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // round-to-nearest-even: 1 + 2^-11 is halfway, rounds down to 1.0
        assert_eq!(f32_to_f16_bits(1.0 + 4.8828125e-4), 0x3c00);
        // ... but 1 + 3*2^-11 rounds up to 1 + 2^-9 (even mantissa 2)
        assert_eq!(f32_to_f16_bits(1.0 + 3.0 * 4.8828125e-4), 0x3c02);
    }

    #[test]
    fn bf16_conversion_exact_and_edge_cases() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 256.0, 1.1754944e-38] {
            let back = bf16_bits_to_f32(f32_to_bf16_bits(v));
            assert_eq!(back.to_bits(), v.to_bits(), "bf16 round trip broke {v}");
        }
        assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
        assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
        // max finite f32 rounds up to bf16 inf
        assert_eq!(f32_to_bf16_bits(f32::MAX), 0x7f80);
        // RNE: 1 + 2^-8 is halfway between 1.0 and 1 + 2^-7, rounds to
        // the even mantissa (down)
        assert_eq!(f32_to_bf16_bits(1.0 + 3.90625e-3), 0x3f80);
    }

    #[test]
    fn conversions_are_idempotent_on_quantized_values() {
        let mut rng = Rng::new(50);
        for v in rng.normal_vec(512, 3.0) {
            let h = f32_to_f16_bits(v);
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h, "f16 requantize moved {v}");
            let b = f32_to_bf16_bits(v);
            assert_eq!(f32_to_bf16_bits(bf16_bits_to_f32(b)), b, "bf16 requantize moved {v}");
        }
    }

    #[test]
    fn int8_row_quantization_properties() {
        let mut rng = Rng::new(51);
        let row = rng.normal_vec(64, 1.0);
        let mut q = vec![0i8; 64];
        let s = quantize_row_i8(&row, &mut q);
        assert!(s > 0.0);
        // the absmax element pins the extreme code, nothing exceeds it
        assert_eq!(q.iter().map(|&v| v.abs()).max().unwrap(), 127);
        for (&qi, &v) in q.iter().zip(&row) {
            assert!((qi as f32 * s - v).abs() <= s * 0.5 + 1e-6, "q error above half a step");
        }
        // requantizing the dequantized row reproduces the codes
        let deq: Vec<f32> = q.iter().map(|&qi| qi as f32 * s).collect();
        let mut q2 = vec![0i8; 64];
        let s2 = quantize_row_i8(&deq, &mut q2);
        assert_eq!(q2, q, "int8 requantize must be stable");
        assert!((s2 - s).abs() <= s * 1e-6);
        // zero row: scale 0, all-zero codes
        let mut qz = vec![1i8; 4];
        assert_eq!(quantize_row_i8(&[0.0; 4], &mut qz), 0.0);
        assert_eq!(qz, vec![0i8; 4]);
    }

    /// Reference GEMV replicating the widening kernels' per-element float
    /// formula (single accumulator, k-ascending) with none of the tiling.
    fn naive_w_gemv(x: &[f32], w: &WeightMat, k: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; n];
        for (j, yj) in y.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += match w {
                    WeightMat::F32 { data } => x[kk] * data[kk * n + j],
                    WeightMat::F16 { bits } => x[kk] * f16_bits_to_f32(bits[kk * n + j]),
                    WeightMat::Bf16 { bits } => x[kk] * bf16_bits_to_f32(bits[kk * n + j]),
                    WeightMat::Int8 { packed, scales } => {
                        (x[kk] * scales[kk]) * packed[kk * n + j] as f32
                    }
                };
            }
            *yj = acc;
        }
        y
    }

    #[test]
    fn widening_gemv_matches_untiled_reference_bitwise() {
        // tiling/unrolling must not change any per-element float order:
        // shapes cover NR and 4-unroll remainders
        let mut rng = Rng::new(52);
        for &(k, n) in &[(4usize, 8usize), (7, 13), (32, 40), (33, 65), (128, 96)] {
            let data = rng.normal_vec(k * n, 1.0);
            let x = rng.normal_vec(k, 1.0);
            for dtype in [WeightDtype::F16, WeightDtype::Bf16, WeightDtype::Int8] {
                let w = WeightMat::quantize(&data, k, n, dtype);
                let mut y = vec![0.0f32; n];
                vecmat_into_w(&mut y, &x, &w, k, n);
                let want = naive_w_gemv(&x, &w, k, n);
                assert_eq!(y, want, "{}: tiled kernel diverged at {k}x{n}", dtype.name());
            }
        }
    }

    #[test]
    fn f32_weightmat_path_is_bitwise_vecmat() {
        let mut rng = Rng::new(53);
        let (k, n) = (33, 65);
        let data = rng.normal_vec(k * n, 1.0);
        let mut x = rng.normal_vec(k, 1.0);
        x[5] = 0.0; // exercise the zero-skip branch
        x[17] = 0.0;
        let w = WeightMat::quantize(&data, k, n, WeightDtype::F32);
        let mut y = vec![0.0f32; n];
        vecmat_into_w(&mut y, &x, &w, k, n);
        let mut want = vec![0.0f32; n];
        vecmat_into(&mut want, &x, &data, k, n);
        assert_eq!(y, want);
        // and through the multi-row form, every row matches the GEMV
        let m = 3;
        let a = rng.normal_vec(m * k, 1.0);
        let mut c = vec![0.0f32; m * n];
        matmul_into_w(&mut c, &a, &w, m, k, n);
        let mut cref = vec![0.0f32; m * n];
        matmul_into(&mut cref, &a, &data, m, k, n);
        assert_eq!(c, cref);
    }

    #[test]
    fn widening_matmul_rows_independent_of_batch_shape() {
        let mut rng = Rng::new(54);
        let (m, k, n) = (5, 32, 40);
        let data = rng.normal_vec(k * n, 1.0);
        let a = rng.normal_vec(m * k, 1.0);
        for dtype in [WeightDtype::F16, WeightDtype::Bf16, WeightDtype::Int8] {
            let w = WeightMat::quantize(&data, k, n, dtype);
            let mut c = vec![0.0f32; m * n];
            matmul_into_w(&mut c, &a, &w, m, k, n);
            for i in 0..m {
                let mut row = vec![0.0f32; n];
                vecmat_into_w(&mut row, &a[i * k..(i + 1) * k], &w, k, n);
                assert_eq!(
                    &c[i * n..(i + 1) * n],
                    &row[..],
                    "{}: row {i} depends on m",
                    dtype.name()
                );
            }
        }
    }

    #[test]
    fn dequantize_error_within_dtype_bounds() {
        let mut rng = Rng::new(55);
        let (rows, cols) = (16, 48);
        let data = rng.normal_vec(rows * cols, 0.3);
        for (dtype, rel) in [(WeightDtype::F16, 1.0 / 1024.0), (WeightDtype::Bf16, 1.0 / 128.0)] {
            let w = WeightMat::quantize(&data, rows, cols, dtype);
            let back = w.dequantize(cols);
            for (&b, &v) in back.iter().zip(&data) {
                assert!((b - v).abs() <= v.abs() * rel + 1e-7, "{}: {v} -> {b}", dtype.name());
            }
        }
        let w = WeightMat::quantize(&data, rows, cols, WeightDtype::Int8);
        let back = w.dequantize(cols);
        if let WeightMat::Int8 { ref scales, .. } = w {
            for r in 0..rows {
                for c in 0..cols {
                    let (v, b) = (data[r * cols + c], back[r * cols + c]);
                    assert!((b - v).abs() <= scales[r] * 0.5 + 1e-6, "int8: {v} -> {b}");
                }
            }
        }
        // byte accounting: the whole point of the exercise
        assert_eq!(
            WeightMat::quantize(&data, rows, cols, WeightDtype::F32).weight_bytes(),
            rows * cols * 4
        );
        assert_eq!(
            WeightMat::quantize(&data, rows, cols, WeightDtype::F16).weight_bytes(),
            rows * cols * 2
        );
        assert_eq!(w.weight_bytes(), rows * cols + rows * 4);
    }

    #[test]
    fn pooled_column_split_gemv_is_bitwise_serial() {
        let mut rng = Rng::new(56);
        let (k, n) = (128, 256); // over both engagement thresholds
        let b = rng.normal_vec(k * n, 1.0);
        let mut x = rng.normal_vec(k, 1.0);
        x[3] = 0.0; // zero-skip must survive the split
        let mut serial = vec![0.0f32; n];
        vecmat_into(&mut serial, &x, &b, k, n);
        for threads in [2usize, 3, 4] {
            let pool = crate::parallel::ThreadPool::new(threads);
            let mut pooled = vec![0.0f32; n];
            vecmat_into_cols_pooled(Some(&pool), &mut pooled, &x, &b, k, n);
            assert_eq!(pooled, serial, "column split diverged at {threads} threads");
            // the m == 1 route through the generic pooled GEMM entry point
            let mut via_matmul = vec![0.0f32; n];
            matmul_into_pooled(Some(&pool), &mut via_matmul, &x, &b, 1, k, n);
            assert_eq!(via_matmul, serial, "m=1 matmul route diverged at {threads} threads");
        }
        // under-threshold shapes fall back to the serial kernel
        let pool = crate::parallel::ThreadPool::new(4);
        let bs = rng.normal_vec(8 * 8, 1.0);
        let xs = rng.normal_vec(8, 1.0);
        let mut tiny = vec![0.0f32; 8];
        vecmat_into_cols_pooled(Some(&pool), &mut tiny, &xs, &bs, 8, 8);
        let mut tiny_ref = vec![0.0f32; 8];
        vecmat_into(&mut tiny_ref, &xs, &bs, 8, 8);
        assert_eq!(tiny, tiny_ref);
    }

    #[test]
    fn pooled_widening_kernels_are_bitwise_serial() {
        let mut rng = Rng::new(57);
        let pool = crate::parallel::ThreadPool::new(4);
        let (k, n) = (128, 192);
        let data = rng.normal_vec(k * n, 1.0);
        let x = rng.normal_vec(k, 1.0);
        let a = rng.normal_vec(6 * k, 1.0);
        for dtype in [WeightDtype::F32, WeightDtype::F16, WeightDtype::Bf16, WeightDtype::Int8] {
            let w = WeightMat::quantize(&data, k, n, dtype);
            let mut serial = vec![0.0f32; n];
            vecmat_into_w(&mut serial, &x, &w, k, n);
            let mut pooled = vec![0.0f32; n];
            vecmat_into_w_cols_pooled(Some(&pool), &mut pooled, &x, &w, k, n);
            assert_eq!(pooled, serial, "{}: pooled GEMV diverged", dtype.name());
            let mut via_mm = vec![0.0f32; n];
            matmul_into_w_pooled(Some(&pool), &mut via_mm, &x, &w, 1, k, n);
            assert_eq!(via_mm, serial, "{}: m=1 pooled GEMM route diverged", dtype.name());

            let mut mm_serial = vec![0.0f32; 6 * n];
            matmul_into_w(&mut mm_serial, &a, &w, 6, k, n);
            let mut mm_pooled = vec![0.0f32; 6 * n];
            matmul_into_w_pooled(Some(&pool), &mut mm_pooled, &a, &w, 6, k, n);
            assert_eq!(mm_pooled, mm_serial, "{}: row-split GEMM diverged", dtype.name());
        }
    }

    #[test]
    fn packed_gemm_is_bitwise_streaming() {
        // m >= GEMM_PACK_MIN_ROWS engages the cache-blocked packed path;
        // every row must still match the streaming single-row kernel
        // bitwise, including ragged column tails (n % NR != 0) and
        // k below the unroll width
        let mut rng = Rng::new(58);
        let shapes = [(GEMM_PACK_MIN_ROWS, 3usize, 8usize), (5, 1, 13), (8, 33, 65), (16, 64, 96)];
        for &(m, k, n) in &shapes {
            let data = rng.normal_vec(k * n, 1.0);
            let mut a = rng.normal_vec(m * k, 1.0);
            a[0] = 0.0; // the f32 zero-skip must survive packing
            let dtypes = [WeightDtype::F32, WeightDtype::F16, WeightDtype::Bf16, WeightDtype::Int8];
            for dtype in dtypes {
                let w = WeightMat::quantize(&data, k, n, dtype);
                let mut packed = vec![0.0f32; m * n];
                matmul_into_w(&mut packed, &a, &w, m, k, n);
                for i in 0..m {
                    let mut row = vec![0.0f32; n];
                    vecmat_into_w(&mut row, &a[i * k..(i + 1) * k], &w, k, n);
                    assert_eq!(
                        &packed[i * n..(i + 1) * n],
                        &row[..],
                        "{}: packed row {i} diverged at {m}x{k}x{n}",
                        dtype.name()
                    );
                }
            }
        }
    }

    #[test]
    fn weight_dtype_parses_user_names() {
        assert_eq!(WeightDtype::parse(" F16 "), Some(WeightDtype::F16));
        assert_eq!(WeightDtype::parse("bfloat16"), Some(WeightDtype::Bf16));
        assert_eq!(WeightDtype::parse("int8"), Some(WeightDtype::Int8));
        assert_eq!(WeightDtype::parse("f32"), Some(WeightDtype::F32));
        assert_eq!(WeightDtype::parse("q4"), None);
        assert_eq!(WeightDtype::F16.name(), "f16");
        assert_eq!(WeightDtype::Int8.bytes_per_elem(), 1);
    }
}
