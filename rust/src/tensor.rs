//! Dense f32 tensor substrate for the native inference path.
//!
//! Deliberately small: row-major `Vec<f32>` storage, shape metadata, and
//! the handful of kernels a transformer needs (GEMM, GEMV, layernorm,
//! softmax, elu+1, outer-product updates, per-head column
//! gather/scatter for the decode and prefill chunk passes). The GEMM
//! uses the i-k-j loop
//! order so the inner loop streams rows of `b` — LLVM auto-vectorizes it;
//! see EXPERIMENTS.md §Perf for measured numbers.

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::rng::Rng) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(shape.iter().product(), std),
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Rows/cols of a rank-2 tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.rank(), 2, "dims2 on rank-{} tensor", self.rank());
        (self.shape[0], self.shape[1])
    }

    /// Borrow row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let (r, c) = self.dims2();
        assert!(i < r);
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let (r, c) = self.dims2();
        assert!(i < r);
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.numel());
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose (copies).
    pub fn transpose2(&self) -> Tensor {
        let (r, c) = self.dims2();
        let mut out = Tensor::zeros(&[c, r]);
        for i in 0..r {
            for j in 0..c {
                out.data[j * r + i] = self.data[i * c + j];
            }
        }
        out
    }

    /// Elementwise map (copies).
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

// ---------------------------------------------------------------------------
// GEMM / GEMV kernels (operate on raw slices for the hot paths)
// ---------------------------------------------------------------------------

/// c[m,n] = a[m,k] @ b[k,n]  (i-k-j order: inner loop streams rows of b).
pub fn matmul_into(c: &mut [f32], a: &[f32], b: &[f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (kk, &aik) in arow.iter().enumerate() {
            if aik == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += aik * bj;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// pooled kernel variants (multi-core, bit-identical to the serial forms)
// ---------------------------------------------------------------------------
//
// Each `_pooled` kernel partitions its work over *output rows/lanes only*
// and runs the plain serial kernel on every block, so the float-op order
// of each output row is unchanged and `pooled == serial` holds bitwise
// under any thread count (asserted by the `pooled_*` tests below and the
// batched-parity suites). `None` (or work under the fan-out threshold)
// falls straight through to the serial kernel.

use crate::parallel::ThreadPool;

/// Mul-add count below which a pooled GEMM-shaped kernel stays serial:
/// one pool dispatch costs a few microseconds, so only real work fans out.
pub const PAR_MIN_WORK: usize = 16 * 1024;

/// Element count below which pooled row-wise kernels (layer norm) stay
/// serial — cheaper per element than a GEMM row, so the bar is lower.
pub const PAR_MIN_ROW_ELEMS: usize = 2048;

/// [`matmul_into`] partitioned over row blocks of `c` across the pool.
pub fn matmul_into_pooled(
    pool: Option<&ThreadPool>,
    c: &mut [f32],
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && m >= 2 && m * k * n >= PAR_MIN_WORK => {
            assert_eq!(a.len(), m * k);
            assert_eq!(b.len(), k * n);
            assert_eq!(c.len(), m * n);
            p.for_row_blocks(m, n, c, |row0, cblk| {
                let rows = cblk.len() / n;
                matmul_into(cblk, &a[row0 * k..(row0 + rows) * k], b, rows, k, n);
            });
        }
        _ => matmul_into(c, a, b, m, k, n),
    }
}

/// [`batched_outer_acc`] partitioned over lanes of `s` across the pool.
pub fn batched_outer_acc_pooled(
    pool: Option<&ThreadPool>,
    s: &mut [f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    d: usize,
    m: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && b >= 2 && b * d * m >= PAR_MIN_WORK => {
            assert_eq!(s.len(), b * d * m);
            assert_eq!(k.len(), b * d);
            assert_eq!(v.len(), b * m);
            p.for_row_blocks(b, d * m, s, |r0, sblk| {
                let lanes = sblk.len() / (d * m);
                batched_outer_acc(
                    sblk,
                    &k[r0 * d..(r0 + lanes) * d],
                    &v[r0 * m..(r0 + lanes) * m],
                    lanes,
                    d,
                    m,
                );
            });
        }
        _ => batched_outer_acc(s, k, v, b, d, m),
    }
}

/// [`batched_contract`] partitioned over lanes of `out` across the pool.
pub fn batched_contract_pooled(
    pool: Option<&ThreadPool>,
    out: &mut [f32],
    q: &[f32],
    s: &[f32],
    b: usize,
    d: usize,
    m: usize,
) {
    match pool {
        Some(p) if p.threads() > 1 && b >= 2 && b * d * m >= PAR_MIN_WORK => {
            assert_eq!(out.len(), b * m);
            assert_eq!(q.len(), b * d);
            assert_eq!(s.len(), b * d * m);
            p.for_row_blocks(b, m, out, |r0, oblk| {
                let lanes = oblk.len() / m;
                batched_contract(
                    oblk,
                    &q[r0 * d..(r0 + lanes) * d],
                    &s[r0 * d * m..(r0 + lanes) * d * m],
                    lanes,
                    d,
                    m,
                );
            });
        }
        _ => batched_contract(out, q, s, b, d, m),
    }
}

/// [`layer_norm_rows`] partitioned over rows of `out` across the pool.
pub fn layer_norm_rows_pooled(
    pool: Option<&ThreadPool>,
    out: &mut [f32],
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    b: usize,
) {
    let n = gamma.len();
    match pool {
        Some(p) if p.threads() > 1 && b >= 2 && b * n >= PAR_MIN_ROW_ELEMS => {
            assert_eq!(out.len(), b * n);
            assert_eq!(x.len(), b * n);
            p.for_row_blocks(b, n, out, |r0, oblk| {
                let rows = oblk.len() / n;
                layer_norm_rows(oblk, &x[r0 * n..(r0 + rows) * n], gamma, beta, rows);
            });
        }
        _ => layer_norm_rows(out, x, gamma, beta, b),
    }
}

/// a[m,k] @ b[k,n] allocating the output.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = a.dims2();
    let (k2, n) = b.dims2();
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(&mut out.data, &a.data, &b.data, m, k, n);
    out
}

/// y[n] = x[k] @ b[k,n] — GEMV against a row-major matrix.
///
/// Deliberately the simple streaming loop: the decode hot path is
/// weight-bandwidth bound (§Perf — ~18 GB/s effective on this core, at the
/// practical roofline), and both a 2-row unroll and target-cpu=native
/// measured within noise (<5%), so the clearest form wins.
pub fn vecmat_into(y: &mut [f32], x: &[f32], b: &[f32], k: usize, n: usize) {
    assert_eq!(x.len(), k);
    assert_eq!(y.len(), n);
    assert!(b.len() >= k * n);
    y.fill(0.0);
    for (kk, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let brow = &b[kk * n..(kk + 1) * n];
        for (yj, &bj) in y.iter_mut().zip(brow) {
            *yj += xv * bj;
        }
    }
}

// ---------------------------------------------------------------------------
// batched decode kernels (structure-of-arrays over B independent lanes)
// ---------------------------------------------------------------------------

/// Batched outer-product accumulate: `s[r] += k[r] ⊗ v[r]` for every lane.
///
/// `s: [b, d, m]`, `k: [b, d]`, `v: [b, m]` — eq. 18 of the paper applied
/// to all B decode lanes in one sweep over contiguous memory.
pub fn batched_outer_acc(s: &mut [f32], k: &[f32], v: &[f32], b: usize, d: usize, m: usize) {
    assert_eq!(s.len(), b * d * m);
    assert_eq!(k.len(), b * d);
    assert_eq!(v.len(), b * m);
    for r in 0..b {
        let kr = &k[r * d..(r + 1) * d];
        let vr = &v[r * m..(r + 1) * m];
        let sr = &mut s[r * d * m..(r + 1) * d * m];
        for (t, &kt) in kr.iter().enumerate() {
            if kt != 0.0 {
                axpy(&mut sr[t * m..(t + 1) * m], kt, vr);
            }
        }
    }
}

/// Batched per-lane contraction: `out[r] = q[r]^T · s[r]` for every lane.
///
/// `out: [b, m]`, `q: [b, d]`, `s: [b, d, m]` — the numerator of eq. 20
/// for all B decode lanes.
pub fn batched_contract(out: &mut [f32], q: &[f32], s: &[f32], b: usize, d: usize, m: usize) {
    assert_eq!(out.len(), b * m);
    assert_eq!(q.len(), b * d);
    assert_eq!(s.len(), b * d * m);
    for r in 0..b {
        let qr = &q[r * d..(r + 1) * d];
        let sr = &s[r * d * m..(r + 1) * d * m];
        let or = &mut out[r * m..(r + 1) * m];
        or.fill(0.0);
        for (t, &qt) in qr.iter().enumerate() {
            if qt != 0.0 {
                axpy(or, qt, &sr[t * m..(t + 1) * m]);
            }
        }
    }
}

/// Row-wise phi: `dst = elu(src) + 1` over a `[b, d]` block.
pub fn elu_plus_one_map(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (o, &x) in dst.iter_mut().zip(src) {
        *o = elu_plus_one(x);
    }
}

/// Layer norm over the last axis of every row of a `[b, n]` block.
pub fn layer_norm_rows(out: &mut [f32], x: &[f32], gamma: &[f32], beta: &[f32], b: usize) {
    let n = gamma.len();
    assert_eq!(out.len(), b * n);
    assert_eq!(x.len(), b * n);
    for r in 0..b {
        layer_norm_into(&mut out[r * n..(r + 1) * n], &x[r * n..(r + 1) * n], gamma, beta);
    }
}

/// Add a bias vector to every row of a `[b, n]` block.
pub fn add_bias_rows(x: &mut [f32], bias: &[f32], b: usize) {
    let n = bias.len();
    assert_eq!(x.len(), b * n);
    for r in 0..b {
        for (xv, &bv) in x[r * n..(r + 1) * n].iter_mut().zip(bias) {
            *xv += bv;
        }
    }
}

/// Gather a column block out of a `[rows, src_cols]` matrix:
/// `dst[r, :] = src[r, col0 .. col0 + nc]`.
///
/// This is the per-head slice step of both the decode tick and the
/// prefill chunk pass (pull one head's `[·, d_head]` columns out of the
/// fused `[·, d_model]` QKV projections).
pub fn gather_cols(
    dst: &mut [f32],
    src: &[f32],
    rows: usize,
    src_cols: usize,
    col0: usize,
    nc: usize,
) {
    assert_eq!(dst.len(), rows * nc);
    assert!(src.len() >= rows * src_cols);
    assert!(col0 + nc <= src_cols);
    for r in 0..rows {
        let s = r * src_cols + col0;
        dst[r * nc..(r + 1) * nc].copy_from_slice(&src[s..s + nc]);
    }
}

/// Scatter a column block back into a `[rows, dst_cols]` matrix:
/// `dst[r, col0 .. col0 + nc] = src[r, :]` — the inverse of [`gather_cols`].
pub fn scatter_cols(
    dst: &mut [f32],
    src: &[f32],
    rows: usize,
    dst_cols: usize,
    col0: usize,
    nc: usize,
) {
    assert_eq!(src.len(), rows * nc);
    assert!(dst.len() >= rows * dst_cols);
    assert!(col0 + nc <= dst_cols);
    for r in 0..rows {
        let d = r * dst_cols + col0;
        dst[d..d + nc].copy_from_slice(&src[r * nc..(r + 1) * nc]);
    }
}

/// dot product.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// y += alpha * x
#[inline]
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

// ---------------------------------------------------------------------------
// neural-net primitives
// ---------------------------------------------------------------------------

/// In-place stable softmax over a row.
pub fn softmax_inplace(row: &mut [f32]) {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in row.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// Layer norm over the last axis of a row, writing into `out`.
pub fn layer_norm_into(out: &mut [f32], x: &[f32], gamma: &[f32], beta: &[f32]) {
    let n = x.len() as f32;
    let mean = x.iter().sum::<f32>() / n;
    let var = x.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / n;
    let inv = 1.0 / (var + 1e-5).sqrt();
    for i in 0..x.len() {
        out[i] = (x[i] - mean) * inv * gamma[i] + beta[i];
    }
}

/// The paper's feature map phi(x) = elu(x) + 1 (eq. 7).
#[inline]
pub fn elu_plus_one(x: f32) -> f32 {
    if x >= 0.0 {
        x + 1.0
    } else {
        x.exp() // elu(x)+1 = exp(x)-1+1
    }
}

/// Apply phi in place.
pub fn elu_plus_one_inplace(row: &mut [f32]) {
    for x in row.iter_mut() {
        *x = elu_plus_one(*x);
    }
}

/// GELU (tanh approximation, matches jax.nn.gelu's default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.797_884_56) * (x + 0.044_715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.data[i * k + kk] * b.data[kk * n + j];
                }
                c.data[i * n + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (8, 16, 8), (17, 9, 13)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let fast = matmul(&a, &b);
            let slow = naive_matmul(&a, &b);
            assert!(fast.max_abs_diff(&slow) < 1e-4);
        }
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&[4, 4], 1.0, &mut rng);
        let mut eye = Tensor::zeros(&[4, 4]);
        for i in 0..4 {
            eye.data[i * 4 + i] = 1.0;
        }
        assert!(matmul(&a, &eye).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    #[should_panic(expected = "matmul inner dims")]
    fn matmul_shape_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        matmul(&a, &b);
    }

    #[test]
    fn vecmat_matches_matmul() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[1, 7], 1.0, &mut rng);
        let b = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let full = matmul(&x, &b);
        let mut y = vec![0.0; 5];
        vecmat_into(&mut y, &x.data, &b.data, 7, 5);
        for (a, b) in y.iter().zip(&full.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[5, 9], 1.0, &mut rng);
        assert_eq!(a.transpose2().transpose2(), a);
    }

    #[test]
    fn softmax_is_distribution_and_order_preserving() {
        let mut row = vec![1.0, 3.0, 2.0, -1.0];
        softmax_inplace(&mut row);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
        assert!(row[1] > row[2] && row[2] > row[0] && row[0] > row[3]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut row = vec![1000.0, 1000.0];
        softmax_inplace(&mut row);
        assert!((row[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn layer_norm_zero_mean_unit_var() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        layer_norm_into(&mut out, &x, &g, &b);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-2);
    }

    #[test]
    fn elu_plus_one_properties() {
        // positive everywhere in the working range, identity+1 for x >= 0
        for x in [-5.0f32, -1.0, -0.1, 0.0, 0.1, 2.0] {
            let y = elu_plus_one(x);
            assert!(y > 0.0, "phi({x}) = {y}");
        }
        assert_eq!(elu_plus_one(3.0), 4.0);
        assert!((elu_plus_one(-1.0) - (-1.0f32).exp()).abs() < 1e-6);
    }

    #[test]
    fn gelu_reference_points() {
        assert!(gelu(0.0).abs() < 1e-6);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn rows_and_reshape() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        let r = t.clone().reshape(&[3, 2]);
        assert_eq!(r.row(2), &[5., 6.]);
    }

    #[test]
    fn batched_outer_acc_matches_per_lane_loops() {
        let (b, d, m) = (3, 4, 5);
        let mut rng = Rng::new(7);
        let k = rng.normal_vec(b * d, 1.0);
        let v = rng.normal_vec(b * m, 1.0);
        let mut s = rng.normal_vec(b * d * m, 1.0);
        let mut expect = s.clone();
        for r in 0..b {
            for t in 0..d {
                for e in 0..m {
                    expect[(r * d + t) * m + e] += k[r * d + t] * v[r * m + e];
                }
            }
        }
        batched_outer_acc(&mut s, &k, &v, b, d, m);
        for (a, x) in s.iter().zip(&expect) {
            assert!((a - x).abs() < 1e-5);
        }
    }

    #[test]
    fn batched_contract_matches_per_lane_vecmat() {
        let (b, d, m) = (3, 4, 5);
        let mut rng = Rng::new(8);
        let q = rng.normal_vec(b * d, 1.0);
        let s = rng.normal_vec(b * d * m, 1.0);
        let mut out = vec![0.0; b * m];
        batched_contract(&mut out, &q, &s, b, d, m);
        for r in 0..b {
            let mut expect = vec![0.0; m];
            let (qr, sr) = (&q[r * d..(r + 1) * d], &s[r * d * m..(r + 1) * d * m]);
            vecmat_into(&mut expect, qr, sr, d, m);
            for e in 0..m {
                assert!((out[r * m + e] - expect[e]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn row_helpers_match_scalar_paths() {
        let (b, n) = (3, 4);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(b * n, 1.0);
        let gamma = rng.normal_vec(n, 1.0);
        let beta = rng.normal_vec(n, 1.0);
        let mut rows = vec![0.0; b * n];
        layer_norm_rows(&mut rows, &x, &gamma, &beta, b);
        for r in 0..b {
            let mut one = vec![0.0; n];
            layer_norm_into(&mut one, &x[r * n..(r + 1) * n], &gamma, &beta);
            for e in 0..n {
                assert!((rows[r * n + e] - one[e]).abs() < 1e-6);
            }
        }

        let mut mapped = vec![0.0; b * n];
        elu_plus_one_map(&mut mapped, &x);
        for (o, &v) in mapped.iter().zip(&x) {
            assert_eq!(*o, elu_plus_one(v));
        }

        let mut biased = x.clone();
        add_bias_rows(&mut biased, &beta, b);
        for r in 0..b {
            for e in 0..n {
                assert!((biased[r * n + e] - (x[r * n + e] + beta[e])).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gather_scatter_cols_roundtrip() {
        let (rows, cols) = (3, 8);
        let mut rng = Rng::new(10);
        let src = rng.normal_vec(rows * cols, 1.0);
        for (col0, nc) in [(0usize, 4usize), (4, 4), (2, 3)] {
            let mut block = vec![0.0; rows * nc];
            gather_cols(&mut block, &src, rows, cols, col0, nc);
            for r in 0..rows {
                for c in 0..nc {
                    assert_eq!(block[r * nc + c], src[r * cols + col0 + c]);
                }
            }
            let mut dst = vec![0.0; rows * cols];
            scatter_cols(&mut dst, &block, rows, cols, col0, nc);
            for r in 0..rows {
                for c in 0..cols {
                    let expect = if c >= col0 && c < col0 + nc {
                        src[r * cols + c]
                    } else {
                        0.0
                    };
                    assert_eq!(dst[r * cols + c], expect);
                }
            }
        }
    }

    #[test]
    fn pooled_matmul_is_bitwise_serial() {
        // shapes on both sides of the fan-out threshold, odd sizes included
        let pool = crate::parallel::ThreadPool::new(4);
        let mut rng = Rng::new(40);
        for &(m, k, n) in &[(1usize, 8usize, 8usize), (7, 33, 65), (33, 64, 96), (64, 128, 128)] {
            let a = rng.normal_vec(m * k, 1.0);
            let b = rng.normal_vec(k * n, 1.0);
            let mut serial = vec![0.0; m * n];
            matmul_into(&mut serial, &a, &b, m, k, n);
            let mut pooled = vec![0.0; m * n];
            matmul_into_pooled(Some(&pool), &mut pooled, &a, &b, m, k, n);
            assert_eq!(pooled, serial, "pooled matmul {m}x{k}x{n} must be bit-identical");
            let mut unpooled = vec![0.0; m * n];
            matmul_into_pooled(None, &mut unpooled, &a, &b, m, k, n);
            assert_eq!(unpooled, serial, "None pool must run the serial kernel");
        }
    }

    #[test]
    fn pooled_batched_kernels_are_bitwise_serial() {
        let pool = crate::parallel::ThreadPool::new(4);
        let mut rng = Rng::new(41);
        for &(b, d, m) in &[(3usize, 4usize, 5usize), (9, 32, 48), (16, 32, 64), (5, 64, 64)] {
            let k = rng.normal_vec(b * d, 1.0);
            let v = rng.normal_vec(b * m, 1.0);
            let q = rng.normal_vec(b * d, 1.0);
            let s0 = rng.normal_vec(b * d * m, 1.0);

            let mut s_serial = s0.clone();
            batched_outer_acc(&mut s_serial, &k, &v, b, d, m);
            let mut s_pooled = s0.clone();
            batched_outer_acc_pooled(Some(&pool), &mut s_pooled, &k, &v, b, d, m);
            assert_eq!(s_pooled, s_serial, "outer_acc [{b},{d},{m}] must be bit-identical");

            let mut o_serial = vec![0.0; b * m];
            batched_contract(&mut o_serial, &q, &s_serial, b, d, m);
            let mut o_pooled = vec![0.0; b * m];
            batched_contract_pooled(Some(&pool), &mut o_pooled, &q, &s_pooled, b, d, m);
            assert_eq!(o_pooled, o_serial, "contract [{b},{d},{m}] must be bit-identical");
        }
    }

    #[test]
    fn pooled_layer_norm_is_bitwise_serial() {
        let pool = crate::parallel::ThreadPool::new(4);
        let mut rng = Rng::new(42);
        for &(b, n) in &[(3usize, 8usize), (17, 96), (64, 64)] {
            let x = rng.normal_vec(b * n, 1.0);
            let gamma = rng.normal_vec(n, 1.0);
            let beta = rng.normal_vec(n, 1.0);
            let mut serial = vec![0.0; b * n];
            layer_norm_rows(&mut serial, &x, &gamma, &beta, b);
            let mut pooled = vec![0.0; b * n];
            layer_norm_rows_pooled(Some(&pool), &mut pooled, &x, &gamma, &beta, b);
            assert_eq!(pooled, serial, "layer norm [{b},{n}] must be bit-identical");
        }
    }

    #[test]
    fn axpy_and_dot() {
        let mut y = vec![1.0, 2.0];
        axpy(&mut y, 2.0, &[3.0, 4.0]);
        assert_eq!(y, vec![7.0, 10.0]);
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }
}
