//! `lintra` — the linear-transformer coordinator CLI.
//!
//! Subcommands:
//!   info                          inspect artifacts + models
//!   train    --task --variant     run a training loop over a train artifact
//!   generate --task               autoregressive generation (native or pjrt)
//!   serve    --task --bind        TCP serving engine
//!   eval     --task --variant     teacher-forced eval loss via eval artifact
//!   cast     --weights --out      re-encode an .ltw bundle at a lower weight precision
//!   analyze  [--deny] [--format json] [--baseline f] [paths…]
//!                                 interprocedural static analysis (see `analysis` module)
//!
//! Run `lintra <cmd> --help-flags` to see the flags each command reads.

use std::sync::Arc;

use anyhow::{bail, Context};
use linear_transformer::cli::Args;
use linear_transformer::config::{ServeConfig, TrainConfig};
use linear_transformer::coordinator::engine::{NativeEngine, PjrtEngine, PjrtEngineSpec};
use linear_transformer::coordinator::server::Server;
use linear_transformer::data::ImageKind;
use linear_transformer::nn::TransformerLM;
use linear_transformer::runtime::{Runtime, Value};
use linear_transformer::trainer::{self, Trainer};

const FLAGS: &[&str] = &[
    "task", "variant", "steps", "lr", "lr-drop", "batch-log", "log-every", "csv",
    "checkpoint", "seed", "artifacts", "bind", "max-batch", "max-wait-us",
    "num-threads", "prefill-chunks-per-tick", "prefill-chunk-budget", "state-cache-mb",
    "prompt-len", "max-new", "temperature", "count", "backend", "weights", "batches",
    "weight-dtype", "out", "dtype", "format", "baseline", "attention-backend", "simd",
];

/// Boolean flags: never consume the following token, so positional args
/// (e.g. `analyze --deny rust/src`) parse as paths.
const SWITCHES: &[&str] = &["deny", "help-flags", "write-baseline"];

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env_with_switches(FLAGS, SWITCHES)?;
    if args.switch("help-flags") {
        eprintln!("flags: {}", FLAGS.join(", "));
        eprintln!("switches: {}", SWITCHES.join(", "));
        return Ok(());
    }
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("generate") => cmd_generate(&args),
        Some("serve") => cmd_serve(&args),
        Some("eval") => cmd_eval(&args),
        Some("cast") => cmd_cast(&args),
        Some("analyze") => cmd_analyze(&args),
        other => {
            bail!(
                "unknown subcommand {other:?}; available: info, train, generate, \
                 serve, eval, cast, analyze"
            )
        }
    }
}

/// `lintra analyze [--deny] [--format text|json] [--baseline file.json]
/// [--write-baseline] [paths…]`
///
/// Run the repo-invariant static-analysis pass
/// ([`linear_transformer::analysis`]) over the given files/directories
/// (default: `rust/src examples`, the self-hosting scope CI gates).
///
/// * `--format json` emits the findings + scope summary as one JSON
///   document (the CI artifact) instead of text.
/// * `--baseline <file>` diffs findings against a committed baseline:
///   matching findings are suppressed debt, anything beyond it is fresh.
/// * `--write-baseline` (requires `--baseline`) regenerates the baseline
///   file to cover exactly the current findings — the ratchet commit.
/// * `--deny` exits non-zero when any (fresh, if a baseline is given)
///   finding survives, which is how CI turns the pass into a hard gate.
fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let paths: Vec<String> = if args.positional.is_empty() {
        vec!["rust/src".into(), "examples".into()]
    } else {
        args.positional.clone()
    };
    let analysis = linear_transformer::analysis::analyze_paths(&paths)?;
    if args.switch("write-baseline") {
        let path = args
            .flag("baseline")
            .context("--write-baseline requires --baseline <file>")?;
        let b = linear_transformer::analysis::Baseline::from_findings(&analysis.findings);
        std::fs::write(path, b.to_json()).with_context(|| format!("writing {path}"))?;
        eprintln!(
            "analyze: wrote {} baseline entr(ies) covering {} finding(s) to {path}",
            b.entries.len(),
            analysis.findings.len()
        );
        return Ok(());
    }
    let diff = match args.flag("baseline") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading baseline {path}"))?;
            let b = linear_transformer::analysis::Baseline::parse(&text)?;
            Some(b.diff(&analysis.findings))
        }
        None => None,
    };
    match args.flag_or("format", "text").as_str() {
        "json" => print!(
            "{}",
            linear_transformer::analysis::to_json(&analysis, diff.as_ref())
        ),
        "text" => print!(
            "{}",
            linear_transformer::analysis::report(&analysis, diff.as_ref())
        ),
        other => bail!("unknown --format {other:?} (text|json)"),
    }
    let gating = match &diff {
        Some(d) => d.fresh.len(),
        None => analysis.findings.len(),
    };
    if args.switch("deny") && gating > 0 {
        bail!("analyze --deny: {gating} finding(s)");
    }
    Ok(())
}

fn artifacts_dir(args: &Args) -> String {
    args.flag_or("artifacts", "artifacts")
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let rt = Runtime::open(artifacts_dir(args))?;
    println!("platform: {}", rt.platform());
    println!("models:");
    for (name, m) in &rt.bundle.models {
        println!(
            "  {name:<18} task={:<7} attention={:<8} params={} weights={}",
            m.task,
            m.attention,
            m.params.len(),
            m.weights
        );
    }
    println!("artifacts:");
    for (name, a) in &rt.bundle.artifacts {
        println!(
            "  {name:<26} inputs={:<3} outputs={:<3} file={}",
            a.inputs.len(),
            a.outputs.len(),
            a.file
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let task = args.flag_or("task", "copy");
    let variant = args.flag_or("variant", "linear");
    let cfg = TrainConfig {
        task: task.clone(),
        variant: variant.clone(),
        steps: args.usize_flag("steps", 200)?,
        lr: args.f32_flag("lr", 1e-3)?,
        lr_drop_step: Some(args.usize_flag("lr-drop", 3000)?),
        log_every: args.usize_flag("log-every", 10)?,
        eval_every: 0,
        seed: args.u64_flag("seed", 0)?,
        out_csv: args.flag("csv").map(String::from),
        checkpoint: args.flag("checkpoint").map(String::from),
    };
    let mut rt = Runtime::open(artifacts_dir(args))?;
    let mut tr = Trainer::new(&mut rt, &task, &variant)?;
    let specs = tr.batch_specs().to_vec();
    let batch = specs[0].shape[0];
    let seq = if specs[0].shape.len() > 1 { specs[0].shape[1] } else { 0 };
    let seed = cfg.seed;
    let mut batch_fn: Box<dyn FnMut(usize) -> Vec<Value>> = match task.as_str() {
        "copy" => Box::new(trainer::copy_batch_fn(seq, batch, seed)),
        "mnist" => Box::new(trainer::image_batch_fn(ImageKind::MnistLike, batch, seed)),
        "cifar" => Box::new(trainer::image_batch_fn(ImageKind::CifarLike, batch, seed)),
        "speech" => {
            let max_labels = specs[2].shape[1];
            Box::new(trainer::speech_batch_fn(seq, batch, max_labels, seed))
        }
        other => bail!("unknown task {other:?}"),
    };
    trainer::train_loop(&mut tr, &cfg, |s| batch_fn(s))?;
    eprintln!(
        "[train] done: final loss {:.4}, mean step {:?}",
        tr.history.last().map(|s| s.loss).unwrap_or(f32::NAN),
        tr.mean_step_time()
    );
    Ok(())
}

fn model_config_for(task: &str) -> anyhow::Result<linear_transformer::config::ModelConfig> {
    Ok(match task {
        "copy" => linear_transformer::config::ModelConfig::small_copy(),
        "mnist" => linear_transformer::config::ModelConfig::mnist(),
        "cifar" => linear_transformer::config::ModelConfig::cifar(),
        other => bail!("unknown task {other:?}"),
    })
}

fn load_native_model(args: &Args, task: &str) -> anyhow::Result<TransformerLM> {
    let cfg = model_config_for(task)?;
    // --attention-backend {linear,softmax} wins, else
    // LINTRA_ATTENTION_BACKEND, else linear — resolved here at model
    // construction: the serving backend IS the model's attention kind
    // (weights are shared between the formulations; only the decode
    // recurrence differs), so downstream code just follows model.kind
    let kind = linear_transformer::config::resolve_attention_backend(parse_attention_backend(
        args.flag("attention-backend"),
    )?)
    .kind();
    match args.flag("weights") {
        Some(path) => {
            let bundle = linear_transformer::weights::WeightBundle::load(path)?;
            TransformerLM::from_bundle(&cfg, kind, &bundle)
        }
        None => {
            // default to the AOT initial weights so native == pjrt numerics
            let dir = artifacts_dir(args);
            let rt = Runtime::open(&dir)?;
            let bundle = rt.load_weights(&format!("{task}_linear"))?;
            TransformerLM::from_bundle(&cfg, kind, &bundle)
        }
    }
}

/// Parse an optional `--attention-backend` value, failing loudly on an
/// unrecognized name (unlike the env var, which silently falls back to
/// linear — see [`linear_transformer::config::resolve_attention_backend`]).
fn parse_attention_backend(
    flag: Option<&str>,
) -> anyhow::Result<Option<linear_transformer::config::AttentionBackend>> {
    match flag {
        None => Ok(None),
        Some(s) => match linear_transformer::config::AttentionBackend::parse(s) {
            Some(b) => Ok(Some(b)),
            None => bail!("unknown attention backend {s:?} (linear|softmax)"),
        },
    }
}

fn cmd_generate(args: &Args) -> anyhow::Result<()> {
    let task = args.flag_or("task", "copy");
    let count = args.usize_flag("count", 1)?;
    let max_new = args.usize_flag("max-new", 32)?;
    let temperature = args.f32_flag("temperature", 1.0)?;
    let model = load_native_model(args, &task)?;
    let mut rng = linear_transformer::rng::Rng::new(args.u64_flag("seed", 0)?);
    let t0 = std::time::Instant::now();
    let mut total_tokens = 0usize;
    for i in 0..count {
        let prompt = vec![0u32];
        let mut sess = model.session();
        let out = sess.generate(&prompt, max_new, temperature, &mut rng);
        total_tokens += out.len();
        if i == 0 {
            println!("sample 0: {out:?}");
        }
    }
    let dt = t0.elapsed();
    println!(
        "{count} sequences, {total_tokens} tokens in {:.2}s ({:.1} tok/s)",
        dt.as_secs_f64(),
        total_tokens as f64 / dt.as_secs_f64()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let task = args.flag_or("task", "copy");
    // --simd {auto,off} wins, else LINTRA_SIMD, else auto-detect; the
    // resolved ISA tier is process-wide and logged in the serving line
    // below (it can never change outputs — see ARCHITECTURE.md §Kernel
    // dispatch & SIMD contract — but it should be visible in every
    // serving log a perf number gets read from)
    let isa_tier = linear_transformer::simd::configure(parse_simd(args.flag("simd"))?);
    let serve_cfg = ServeConfig {
        max_batch: args.usize_flag("max-batch", 8)?,
        max_wait_us: args.u64_flag("max-wait-us", 500)?,
        max_sessions: 256,
        bind: args.flag_or("bind", "127.0.0.1:7411"),
        temperature: args.f32_flag("temperature", 1.0)?,
        seed: args.u64_flag("seed", 0)?,
        // 0 = auto: LINTRA_NUM_THREADS if set, else one thread per core
        // (resolved by parallel::resolve_threads at pool construction)
        num_threads: args.usize_flag("num-threads", 0)?,
        // chunks of prompt a still-admitting slot may ingest per engine
        // tick; bounds admission work so resident decode latency stays
        // flat under long-prompt traffic (greedy outputs identical at
        // any value; see ServeConfig::prefill_chunks_per_tick)
        prefill_chunks_per_tick: args.usize_flag("prefill-chunks-per-tick", 1)?,
        // global cap across all admitting slots per tick (0 = unlimited):
        // K simultaneous admissions then cost at most the budget, not K
        // chunks (see ServeConfig::prefill_chunk_budget)
        prefill_chunk_budget: args.usize_flag("prefill-chunk-budget", 0)?,
        // prefix-reuse state cache in MiB; 0 = off unless
        // LINTRA_STATE_CACHE_MB is set (config::resolve_state_cache_mb)
        state_cache_mb: args.usize_flag("state-cache-mb", 0)?,
        // weight storage precision; unset = LINTRA_WEIGHT_DTYPE if set,
        // else f32 (config::resolve_weight_dtype)
        weight_dtype: parse_weight_dtype(args.flag("weight-dtype"))?,
    };
    let backend = args.flag_or("backend", "native");
    let handle = match backend.as_str() {
        "native" => {
            let model = load_native_model(args, &task)?;
            NativeEngine::spawn(model, serve_cfg.clone())?
        }
        "pjrt" => PjrtEngine::spawn(
            PjrtEngineSpec {
                artifacts_dir: artifacts_dir(args),
                task: task.clone(),
                model_cfg: model_config_for(&task)?,
            },
            serve_cfg.clone(),
        )?,
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    };
    let engine = Arc::new(handle);
    let server = Server::start(&serve_cfg.bind, engine.clone())
        .with_context(|| format!("binding {}", serve_cfg.bind))?;
    println!(
        "serving task={task} backend={backend} on {} (max_batch={}, gemm_threads={}, simd={})",
        server.addr,
        serve_cfg.max_batch,
        linear_transformer::parallel::resolve_threads(serve_cfg.num_threads),
        isa_tier.label()
    );
    println!("protocol: one json per line: {{\"id\":1,\"prompt\":[0],\"max_new\":16}}");
    // run until ctrl-c
    loop {
        std::thread::sleep(std::time::Duration::from_secs(5));
        let st = engine.stats();
        if st.requests > 0 {
            eprintln!(
                "[stats] req={} done={} tokens={} prompt-tokens={} occupancy={:.2} {}",
                st.requests,
                st.completed,
                st.tokens_generated,
                st.prompt_tokens_ingested,
                st.mean_batch_occupancy(),
                st.latency.summary()
            );
            eprintln!("[ticks] {}", st.tick_latency.summary());
            if st.state_cache.hits + st.state_cache.misses > 0 {
                eprintln!(
                    "[prefix-cache] {} tokens-skipped={}",
                    st.state_cache.summary(),
                    st.prompt_tokens_skipped
                );
            }
        }
    }
}

/// Parse an optional `--simd` value, failing loudly on an unrecognized
/// name (unlike the env var, which silently falls back to auto — see
/// [`linear_transformer::config::resolve_simd`]).
fn parse_simd(
    flag: Option<&str>,
) -> anyhow::Result<Option<linear_transformer::config::SimdMode>> {
    match flag {
        None => Ok(None),
        Some(s) => match linear_transformer::config::SimdMode::parse(s) {
            Some(m) => Ok(Some(m)),
            None => bail!("unknown simd mode {s:?} (auto|off)"),
        },
    }
}

/// Parse an optional `--weight-dtype`/`--dtype` value, failing loudly on an
/// unrecognized name (unlike the env var, which silently falls back to f32).
fn parse_weight_dtype(
    flag: Option<&str>,
) -> anyhow::Result<Option<linear_transformer::tensor::WeightDtype>> {
    match flag {
        None => Ok(None),
        Some(s) => match linear_transformer::tensor::WeightDtype::parse(s) {
            Some(d) => Ok(Some(d)),
            None => bail!("unknown weight dtype {s:?} (f32|f16|bf16|int8)"),
        },
    }
}

/// `lintra cast --weights in.ltw --out out.ltw --dtype f16`
///
/// Re-encode a weight bundle at a lower storage precision. Only the
/// GEMV-shaped projection matrices ([`linear_transformer::nn::quantized_param`])
/// are narrowed; embeddings, norms, and biases stay f32, mirroring what the
/// runtime quantizes in memory — so serving the cast bundle produces the same
/// outputs as serving the f32 bundle with `--weight-dtype` set.
fn cmd_cast(args: &Args) -> anyhow::Result<()> {
    let src = args
        .flag("weights")
        .context("cast requires --weights <in.ltw>")?;
    let out = args.flag("out").context("cast requires --out <out.ltw>")?;
    let dtype = parse_weight_dtype(args.flag("dtype"))?
        .context("cast requires --dtype <f16|bf16|int8|f32>")?;
    let bundle = linear_transformer::weights::WeightBundle::load(src)?;
    bundle.save_as(out, |t| {
        if linear_transformer::nn::quantized_param(&t.name) {
            dtype
        } else {
            linear_transformer::tensor::WeightDtype::F32
        }
    })?;
    let before: usize = std::fs::metadata(src).map(|m| m.len() as usize).unwrap_or(0);
    let after: usize = std::fs::metadata(out).map(|m| m.len() as usize).unwrap_or(0);
    println!(
        "cast {} -> {} ({}): {} bytes -> {} bytes",
        src,
        out,
        dtype.name(),
        before,
        after
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    let task = args.flag_or("task", "copy");
    let variant = args.flag_or("variant", "linear");
    let batches = args.usize_flag("batches", 4)?;
    let mut rt = Runtime::open(artifacts_dir(args))?;
    let model_key = format!("{task}_{variant}");
    let eval = rt.load(&format!("{model_key}_eval"))?;
    let weights = rt.load_weights(&model_key)?;
    let spec = rt.bundle.model(&model_key).unwrap().clone();
    let params: Vec<Value> = spec
        .params
        .iter()
        .map(|n| Value::from_tensor(weights.req(n)))
        .collect();
    let batch_shape = &eval.spec.inputs[params.len()].shape;
    let (b, n) = (batch_shape[0], batch_shape[1]);
    let seed = args.u64_flag("seed", 0)?;
    let mut batch_fn: Box<dyn FnMut(usize) -> Vec<Value>> = match task.as_str() {
        "copy" => Box::new(trainer::copy_batch_fn(n, b, seed)),
        "mnist" => Box::new(trainer::image_batch_fn(ImageKind::MnistLike, b, seed)),
        "cifar" => Box::new(trainer::image_batch_fn(ImageKind::CifarLike, b, seed)),
        other => bail!("eval unsupported for task {other:?}"),
    };
    let mut total = 0.0f64;
    for i in 0..batches {
        let mut inputs = params.clone();
        inputs.extend(batch_fn(i));
        let out = eval.run(&inputs)?;
        total += out[0].scalar()? as f64;
    }
    let nats = total / batches as f64;
    println!(
        "{model_key}: eval loss {:.4} nats ({:.4} bits/dim)",
        nats,
        linear_transformer::metrics::bits_per_dim(nats)
    );
    Ok(())
}
