//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports the `lintra <subcommand> --flag value --switch` shape used by
//! the binary and examples. Flags may appear as `--key value` or
//! `--key=value`; unknown flags are an error so typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{bail, Context};

/// Parsed command line: a subcommand, positional args, and string flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        raw: I,
        known_flags: &[&str],
    ) -> anyhow::Result<Args> {
        Self::parse_with_switches(raw, known_flags, &[])
    }

    /// Like [`Args::parse`], but flags named in `known_switches` are
    /// boolean: they never consume the following token, so
    /// `analyze --deny rust/src` keeps `rust/src` positional instead of
    /// swallowing it as the value of `--deny`.
    pub fn parse_with_switches<I: IntoIterator<Item = String>>(
        raw: I,
        known_flags: &[&str],
        known_switches: &[&str],
    ) -> anyhow::Result<Args> {
        let mut args = Args {
            known: known_flags
                .iter()
                .chain(known_switches.iter())
                .map(|s| s.to_string())
                .collect(),
            ..Default::default()
        };
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                let (key, inline_val) = match name.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (name.to_string(), None),
                };
                if !args.known.iter().any(|k| k == &key) {
                    bail!("unknown flag --{key} (known: {})", args.known.join(", "));
                }
                let is_switch = known_switches.iter().any(|s| s == &key);
                if let Some(v) = inline_val {
                    args.flags.insert(key, v);
                } else if is_switch {
                    args.switches.push(key);
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    match it.next() {
                        Some(v) => {
                            args.flags.insert(key, v);
                        }
                        None => args.switches.push(key),
                    }
                } else {
                    args.switches.push(key);
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// From the process environment.
    pub fn from_env(known_flags: &[&str]) -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1), known_flags)
    }

    /// From the process environment, with declared boolean switches.
    pub fn from_env_with_switches(
        known_flags: &[&str],
        known_switches: &[&str],
    ) -> anyhow::Result<Args> {
        Self::parse_with_switches(std::env::args().skip(1), known_flags, known_switches)
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn flag_or(&self, key: &str, default: &str) -> String {
        self.flag(key).unwrap_or(default).to_string()
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }

    pub fn f32_flag(&self, key: &str, default: f32) -> anyhow::Result<f32> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not a number")),
        }
    }

    pub fn u64_flag(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?} is not an integer")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str], known: &[&str]) -> anyhow::Result<Args> {
        Args::parse(tokens.iter().map(|s| s.to_string()), known)
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(
            &["train", "--task", "copy", "--steps", "100", "--verbose"],
            &["task", "steps", "verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.flag("task"), Some("copy"));
        assert_eq!(a.usize_flag("steps", 0).unwrap(), 100);
        assert!(a.switch("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["x", "--lr=0.001"], &["lr"]).unwrap();
        assert!((a.f32_flag("lr", 0.0).unwrap() - 0.001).abs() < 1e-9);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse(&["x", "--bogus", "1"], &["real"]).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["serve"], &["port"]).unwrap();
        assert_eq!(a.usize_flag("port", 7070).unwrap(), 7070);
        assert_eq!(a.flag_or("port", "7070"), "7070");
    }

    #[test]
    fn positional_args() {
        let a = parse(&["eval", "model.ltw", "data.bin"], &[]).unwrap();
        assert_eq!(a.positional, vec!["model.ltw", "data.bin"]);
    }

    #[test]
    fn bad_numeric_flag_is_error() {
        let a = parse(&["x", "--steps", "abc"], &["steps"]).unwrap();
        assert!(a.usize_flag("steps", 1).is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["bench", "--quick"], &["quick"]).unwrap();
        assert!(a.switch("quick"));
        assert_eq!(a.flag("quick"), None);
    }

    #[test]
    fn declared_switch_keeps_following_positional() {
        let a = Args::parse_with_switches(
            ["analyze", "--deny", "rust/src", "examples"]
                .iter()
                .map(|s| s.to_string()),
            &[],
            &["deny"],
        )
        .unwrap();
        assert!(a.switch("deny"));
        assert_eq!(a.positional, vec!["rust/src", "examples"]);
    }

    #[test]
    fn declared_switch_rejects_unknown() {
        assert!(Args::parse_with_switches(
            ["x", "--bogus"].iter().map(|s| s.to_string()),
            &["real"],
            &["deny"],
        )
        .is_err());
    }
}
