//! Synthetic speech dataset for the §4.3 CTC experiment (WSJ stand-in).
//!
//! A left-to-right HMM generates phoneme sequences; each phoneme emits a
//! random-duration run of 40-dim "filterbank" frames drawn from a
//! phoneme-specific spectral prototype (smooth formant-like bumps) plus
//! noise and temporal smoothing. The result has the properties the CTC
//! encoder actually exploits: piecewise-stationary frames aligned to a
//! shorter label sequence. Vocab = 40 phonemes + blank(0) = 41.

use crate::rng::Rng;

pub const N_MELS: usize = 40;
pub const N_PHONEMES: usize = 40;
pub const BLANK: u32 = 0;
pub const VOCAB: usize = N_PHONEMES + 1;

/// One utterance: frames [frames, N_MELS] row-major + phoneme labels.
#[derive(Clone, Debug)]
pub struct Utterance {
    pub frames: Vec<f32>,
    pub n_frames: usize,
    pub labels: Vec<u32>, // in 1..=N_PHONEMES (0 is blank, never a label)
}

/// Generator of synthetic utterances.
#[derive(Clone, Debug)]
pub struct SpeechDataset {
    pub max_frames: usize,
    pub min_phones: usize,
    pub max_phones: usize,
    prototypes: Vec<[f32; N_MELS]>,
    rng: Rng,
}

impl SpeechDataset {
    pub fn new(max_frames: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5eec_da7a);
        // spectral prototype per phoneme: 2-3 smooth formant bumps
        let mut prototypes = Vec::with_capacity(N_PHONEMES + 1);
        for _ in 0..=N_PHONEMES {
            let mut proto = [0.0f32; N_MELS];
            let n_formants = 2 + rng.below(2) as usize;
            for _ in 0..n_formants {
                let center = rng.uniform_range(2.0, (N_MELS - 3) as f32);
                let width = rng.uniform_range(1.5, 4.0);
                let amp = rng.uniform_range(0.8, 2.0);
                for (m, p) in proto.iter_mut().enumerate() {
                    let d = (m as f32 - center) / width;
                    *p += amp * (-0.5 * d * d).exp();
                }
            }
            prototypes.push(proto);
        }
        SpeechDataset {
            max_frames,
            min_phones: 3,
            max_phones: (max_frames / 8).max(4),
            prototypes,
            rng: Rng::new(seed),
        }
    }

    /// Sample one utterance (frames zero-padded to max_frames).
    pub fn sample(&mut self) -> Utterance {
        let n_phones = self.min_phones
            + self.rng.below((self.max_phones - self.min_phones + 1) as u64) as usize;
        let mut labels = Vec::with_capacity(n_phones);
        let mut spans: Vec<(u32, usize)> = Vec::new();
        let mut total = 0usize;
        for _ in 0..n_phones {
            let ph = 1 + self.rng.below(N_PHONEMES as u64) as u32;
            // duration 3..10 frames, long tail clipped by max_frames
            let dur = 3 + self.rng.below(8) as usize;
            if total + dur + 2 > self.max_frames {
                break;
            }
            labels.push(ph);
            spans.push((ph, dur));
            total += dur;
        }
        let n_frames = total.max(4);

        let mut frames = vec![0.0f32; self.max_frames * N_MELS];
        let mut t = 0usize;
        for (ph, dur) in spans {
            let proto = &self.prototypes[ph as usize];
            for _ in 0..dur {
                let row = &mut frames[t * N_MELS..(t + 1) * N_MELS];
                for (m, r) in row.iter_mut().enumerate() {
                    *r = proto[m] + self.rng.normal() * 0.25;
                }
                t += 1;
            }
        }
        // temporal smoothing (exponential moving average) over valid frames
        for m in 0..N_MELS {
            let mut prev = frames[m];
            for f in 1..n_frames {
                let cur = frames[f * N_MELS + m];
                let sm = 0.6 * cur + 0.4 * prev;
                frames[f * N_MELS + m] = sm;
                prev = sm;
            }
        }
        Utterance {
            frames,
            n_frames,
            labels,
        }
    }

    /// Batch in the layout the `speech_*` artifacts expect:
    /// (feats [B*T*F], frame_len [B], labels [B*max_labels], label_len [B]).
    pub fn batch(
        &mut self,
        batch: usize,
        max_labels: usize,
    ) -> (Vec<f32>, Vec<i32>, Vec<i32>, Vec<i32>) {
        let t = self.max_frames;
        let mut feats = Vec::with_capacity(batch * t * N_MELS);
        let mut frame_len = Vec::with_capacity(batch);
        let mut labels = vec![0i32; batch * max_labels];
        let mut label_len = Vec::with_capacity(batch);
        for bi in 0..batch {
            let mut u = self.sample();
            u.labels.truncate(max_labels);
            feats.extend_from_slice(&u.frames);
            frame_len.push(u.n_frames as i32);
            for (i, &l) in u.labels.iter().enumerate() {
                labels[bi * max_labels + i] = l as i32;
            }
            label_len.push(u.labels.len() as i32);
        }
        (feats, frame_len, labels, label_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utterance_shapes() {
        let mut d = SpeechDataset::new(256, 0);
        let u = d.sample();
        assert_eq!(u.frames.len(), 256 * N_MELS);
        assert!(u.n_frames <= 256 && u.n_frames >= 4);
        assert!(!u.labels.is_empty());
        assert!(u.labels.iter().all(|&l| l >= 1 && l <= N_PHONEMES as u32));
    }

    #[test]
    fn padding_is_zero() {
        let mut d = SpeechDataset::new(128, 1);
        let u = d.sample();
        for f in u.n_frames..128 {
            for m in 0..N_MELS {
                assert_eq!(u.frames[f * N_MELS + m], 0.0);
            }
        }
    }

    #[test]
    fn phonemes_are_spectrally_distinct() {
        // frames of different phonemes should differ more than frames of
        // the same phoneme — that's what makes CTC learnable
        let mut d = SpeechDataset::new(256, 2);
        let protos = d.prototypes.clone();
        let dist = |a: &[f32; N_MELS], b: &[f32; N_MELS]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>()
        };
        let mut cross = 0.0;
        let mut count = 0;
        for i in 1..10 {
            for j in (i + 1)..10 {
                cross += dist(&protos[i], &protos[j]);
                count += 1;
            }
        }
        assert!(cross / count as f32 > 0.5, "prototypes nearly identical");
        let _ = d.sample();
    }

    #[test]
    fn batch_layout() {
        let mut d = SpeechDataset::new(64, 3);
        let (feats, fl, labels, ll) = d.batch(3, 16);
        assert_eq!(feats.len(), 3 * 64 * N_MELS);
        assert_eq!(fl.len(), 3);
        assert_eq!(labels.len(), 3 * 16);
        assert_eq!(ll.len(), 3);
        for b in 0..3 {
            let l = ll[b] as usize;
            assert!(l >= 1 && l <= 16);
            for i in l..16 {
                assert_eq!(labels[b * 16 + i], 0, "label padding must be blank");
            }
        }
    }

    #[test]
    fn label_count_tracks_frame_count() {
        // more frames -> statistically more phonemes
        let mut d = SpeechDataset::new(256, 4);
        let mut frames = 0usize;
        let mut labels = 0usize;
        for _ in 0..20 {
            let u = d.sample();
            frames += u.n_frames;
            labels += u.labels.len();
        }
        let per = frames as f64 / labels as f64;
        assert!((3.0..=11.0).contains(&per), "frames per phoneme = {per}");
    }
}
