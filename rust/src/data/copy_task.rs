//! The §4.1 copy task: duplicate a symbol sequence across a separator.
//!
//! Token layout (vocab = 13, matching the `copy` artifacts):
//!   0        = PAD
//!   1        = SEP
//!   2..=11   = the 10 payload symbols
//!   12       = BOS
//!
//! A sample of payload width `w` is
//!   `BOS s_1 .. s_w SEP s_1 .. s_w` padded with PAD to `seq_len`,
//! and the loss mask covers exactly the second copy (the model must
//! reproduce the payload; everything before it is context).

use crate::rng::Rng;

pub const PAD: u32 = 0;
pub const SEP: u32 = 1;
pub const SYMBOL_BASE: u32 = 2;
pub const N_SYMBOLS: u32 = 10;
pub const BOS: u32 = 12;
pub const VOCAB: usize = 13;

/// Copy-task batch generator.
#[derive(Clone, Debug)]
pub struct CopyTask {
    pub seq_len: usize,
    /// Payload width range [min_w, max_w]; paper uses up to 128-long
    /// sequences, i.e. max_w = (seq_len - 2) / 2.
    pub min_w: usize,
    pub max_w: usize,
    rng: Rng,
}

/// One teacher-forced LM batch in flat row-major layout.
#[derive(Clone, Debug)]
pub struct LmBatch {
    pub batch: usize,
    pub seq_len: usize,
    /// [batch * seq_len] model inputs
    pub inputs: Vec<u32>,
    /// [batch * seq_len] next-token targets
    pub targets: Vec<u32>,
    /// [batch * seq_len] 1.0 where the loss applies
    pub mask: Vec<f32>,
}

impl CopyTask {
    pub fn new(seq_len: usize, seed: u64) -> Self {
        assert!(seq_len >= 6, "sequence too short for a copy sample");
        let max_w = (seq_len - 2) / 2;
        CopyTask {
            seq_len,
            min_w: max_w.min(4),
            max_w,
            rng: Rng::new(seed),
        }
    }

    /// Build one full token sequence of length seq_len + 1 (for input/target
    /// shifting), returning (tokens, copy_start, copy_end) over that string.
    fn sample_tokens(&mut self) -> (Vec<u32>, usize, usize) {
        let w = self.min_w + self.rng.below((self.max_w - self.min_w + 1) as u64) as usize;
        let mut toks = Vec::with_capacity(self.seq_len + 1);
        toks.push(BOS);
        let payload: Vec<u32> = (0..w)
            .map(|_| SYMBOL_BASE + self.rng.below(N_SYMBOLS as u64) as u32)
            .collect();
        toks.extend_from_slice(&payload);
        toks.push(SEP);
        let copy_start = toks.len();
        toks.extend_from_slice(&payload);
        let copy_end = toks.len();
        while toks.len() < self.seq_len + 1 {
            toks.push(PAD);
        }
        (toks, copy_start, copy_end)
    }

    /// Generate a teacher-forced batch.
    pub fn batch(&mut self, batch: usize) -> LmBatch {
        let n = self.seq_len;
        let mut inputs = Vec::with_capacity(batch * n);
        let mut targets = Vec::with_capacity(batch * n);
        let mut mask = Vec::with_capacity(batch * n);
        for _ in 0..batch {
            let (toks, cs, ce) = self.sample_tokens();
            for i in 0..n {
                inputs.push(toks[i]);
                targets.push(toks[i + 1]);
                // target position i predicts token i+1; mask the copy span
                let predicted_index = i + 1;
                mask.push(if predicted_index >= cs && predicted_index < ce {
                    1.0
                } else {
                    0.0
                });
            }
        }
        LmBatch {
            batch,
            seq_len: n,
            inputs,
            targets,
            mask,
        }
    }

    /// A prompt (BOS + payload + SEP) and its expected continuation,
    /// for generation-side evaluation.
    pub fn prompt(&mut self) -> (Vec<u32>, Vec<u32>) {
        let (toks, cs, ce) = self.sample_tokens();
        (toks[..cs].to_vec(), toks[cs..ce].to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let mut t = CopyTask::new(128, 0);
        let b = t.batch(4);
        assert_eq!(b.inputs.len(), 4 * 128);
        assert_eq!(b.targets.len(), 4 * 128);
        assert_eq!(b.mask.len(), 4 * 128);
    }

    #[test]
    fn structure_is_copy() {
        let mut t = CopyTask::new(64, 1);
        let (toks, cs, ce) = t.sample_tokens();
        assert_eq!(toks[0], BOS);
        let w = ce - cs;
        assert_eq!(toks[cs - 1], SEP);
        assert_eq!(&toks[1..1 + w], &toks[cs..ce], "payload must be duplicated");
        assert!(toks[ce..].iter().all(|&t| t == PAD));
    }

    #[test]
    fn mask_covers_exactly_the_copy() {
        let mut t = CopyTask::new(64, 2);
        let b = t.batch(1);
        // masked positions' targets must be payload symbols
        for i in 0..b.seq_len {
            if b.mask[i] == 1.0 {
                let target = b.targets[i];
                assert!((SYMBOL_BASE..SYMBOL_BASE + N_SYMBOLS).contains(&target));
            }
        }
        let count = b.mask.iter().filter(|&&m| m == 1.0).count();
        assert!(count >= 4, "at least min_w masked positions");
    }

    #[test]
    fn targets_are_shifted_inputs() {
        let mut t = CopyTask::new(32, 3);
        let b = t.batch(2);
        for s in 0..2 {
            for i in 0..31 {
                assert_eq!(b.targets[s * 32 + i], b.inputs[s * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn prompt_and_continuation_consistent() {
        let mut t = CopyTask::new(64, 4);
        let (prompt, cont) = t.prompt();
        assert_eq!(prompt[0], BOS);
        assert_eq!(*prompt.last().unwrap(), SEP);
        assert_eq!(&prompt[1..prompt.len() - 1], &cont[..]);
    }

    #[test]
    fn tokens_in_vocab_property() {
        crate::propcheck::check("copy-task-vocab", 30, |g| {
            let seed = g.rng.next_u64();
            let mut t = CopyTask::new(32 + 2 * g.usize_in(0, 16), seed);
            let b = t.batch(2);
            for &tok in b.inputs.iter().chain(&b.targets) {
                if tok as usize >= VOCAB {
                    return Err(format!("token {tok} out of vocab"));
                }
            }
            Ok(())
        });
    }
}
