//! Procedural image datasets standing in for MNIST / CIFAR-10 (§4.2).
//!
//! MNIST-like: 28x28 grayscale digits drawn as anti-aliased polyline
//! strokes from per-class templates with random affine jitter — same
//! sequence length (784), same "mostly-background + smooth strokes"
//! statistics that make autoregressive pixel models learnable.
//!
//! CIFAR-like: 32x32 RGB compositions of gradient sky, textured ground and
//! a geometric object with class-dependent hue — 3072-long sequences with
//! smooth spatial correlations.
//!
//! Pixels are quantized to u8 (0..=255) and flattened row-major
//! (channel-interleaved for RGB), exactly the token streams the `mnist` /
//! `cifar` artifacts expect.

use crate::rng::Rng;

/// Which procedural family to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImageKind {
    /// 28x28 grayscale -> 784 tokens.
    MnistLike,
    /// 32x32 RGB -> 3072 tokens.
    CifarLike,
}

impl ImageKind {
    pub fn seq_len(self) -> usize {
        match self {
            ImageKind::MnistLike => 784,
            ImageKind::CifarLike => 3072,
        }
    }

    pub fn side(self) -> usize {
        match self {
            ImageKind::MnistLike => 28,
            ImageKind::CifarLike => 32,
        }
    }
}

/// Streaming generator of (pixels, class) pairs.
#[derive(Clone, Debug)]
pub struct ImageDataset {
    pub kind: ImageKind,
    rng: Rng,
}

/// Per-digit stroke templates in a [0,1]^2 unit box (polyline key points).
const DIGIT_STROKES: [&[(f32, f32)]; 10] = [
    // 0: oval
    &[(0.5, 0.1), (0.8, 0.3), (0.8, 0.7), (0.5, 0.9), (0.2, 0.7), (0.2, 0.3), (0.5, 0.1)],
    // 1: vertical bar
    &[(0.4, 0.25), (0.55, 0.1), (0.55, 0.9)],
    // 2
    &[(0.2, 0.25), (0.5, 0.1), (0.8, 0.3), (0.2, 0.9), (0.8, 0.9)],
    // 3
    &[(0.2, 0.15), (0.7, 0.2), (0.45, 0.5), (0.75, 0.7), (0.2, 0.9)],
    // 4
    &[(0.65, 0.9), (0.65, 0.1), (0.2, 0.6), (0.85, 0.6)],
    // 5
    &[(0.8, 0.1), (0.25, 0.1), (0.25, 0.5), (0.7, 0.55), (0.7, 0.85), (0.2, 0.9)],
    // 6
    &[(0.7, 0.1), (0.3, 0.45), (0.25, 0.8), (0.6, 0.9), (0.7, 0.6), (0.3, 0.6)],
    // 7
    &[(0.2, 0.1), (0.8, 0.1), (0.45, 0.9)],
    // 8
    &[(0.5, 0.1), (0.75, 0.28), (0.3, 0.6), (0.5, 0.9), (0.72, 0.62), (0.28, 0.3), (0.5, 0.1)],
    // 9
    &[(0.7, 0.4), (0.35, 0.35), (0.35, 0.1), (0.7, 0.12), (0.7, 0.9)],
];

impl ImageDataset {
    pub fn new(kind: ImageKind, seed: u64) -> Self {
        ImageDataset {
            kind,
            rng: Rng::new(seed),
        }
    }

    /// Generate one image; returns (pixels flattened as tokens, class id).
    pub fn sample(&mut self) -> (Vec<u32>, u32) {
        match self.kind {
            ImageKind::MnistLike => {
                let class = self.rng.below(10) as u32;
                (self.render_digit(class as usize), class)
            }
            ImageKind::CifarLike => {
                let class = self.rng.below(10) as u32;
                (self.render_scene(class as usize), class)
            }
        }
    }

    /// A batch of autoregressive (inputs, targets): inputs are the pixels
    /// shifted right with a 0 start-of-image token.
    pub fn lm_batch(&mut self, batch: usize) -> (Vec<u32>, Vec<u32>) {
        let n = self.kind.seq_len();
        let mut inputs = Vec::with_capacity(batch * n);
        let mut targets = Vec::with_capacity(batch * n);
        for _ in 0..batch {
            let (px, _) = self.sample();
            inputs.push(0);
            inputs.extend_from_slice(&px[..n - 1]);
            targets.extend_from_slice(&px);
        }
        (inputs, targets)
    }

    // ---- MNIST-like rendering ---------------------------------------------

    fn render_digit(&mut self, class: usize) -> Vec<u32> {
        let side = 28usize;
        let mut img = vec![0.0f32; side * side];
        let strokes = DIGIT_STROKES[class];

        // random affine jitter: scale, rotation, translation
        let scale = self.rng.uniform_range(0.75, 1.0);
        let theta = self.rng.uniform_range(-0.25, 0.25);
        let (sin, cos) = theta.sin_cos();
        let dx = self.rng.uniform_range(-0.08, 0.08);
        let dy = self.rng.uniform_range(-0.08, 0.08);
        let thickness = self.rng.uniform_range(1.0, 1.8);

        let tf = |p: (f32, f32)| -> (f32, f32) {
            let (x, y) = (p.0 - 0.5, p.1 - 0.5);
            let xr = scale * (x * cos - y * sin) + 0.5 + dx;
            let yr = scale * (x * sin + y * cos) + 0.5 + dy;
            (xr * side as f32, yr * side as f32)
        };

        for pair in strokes.windows(2) {
            let a = tf(pair[0]);
            let b = tf(pair[1]);
            draw_line(&mut img, side, a, b, thickness);
        }
        // mild sensor noise, clamp, quantize
        img.iter()
            .map(|&v| {
                let noisy = v * 255.0 + self.rng.normal() * 6.0;
                noisy.clamp(0.0, 255.0) as u32
            })
            .collect()
    }

    // ---- CIFAR-like rendering ----------------------------------------------

    fn render_scene(&mut self, class: usize) -> Vec<u32> {
        let side = 32usize;
        let mut rgb = vec![0.0f32; side * side * 3];
        // class-dependent base hue + random lighting
        let hue = class as f32 / 10.0;
        let light = self.rng.uniform_range(0.6, 1.0);
        let horizon = self.rng.uniform_range(0.45, 0.7);
        let (r0, g0, b0) = hue_to_rgb(hue);

        for y in 0..side {
            for x in 0..side {
                let fy = y as f32 / side as f32;
                let sky = 1.0 - fy / horizon;
                let idx = (y * side + x) * 3;
                if fy < horizon {
                    // gradient sky tinted toward the class hue
                    rgb[idx] = light * (0.35 + 0.4 * sky + 0.25 * r0);
                    rgb[idx + 1] = light * (0.45 + 0.35 * sky + 0.2 * g0);
                    rgb[idx + 2] = light * (0.6 + 0.3 * sky + 0.1 * b0);
                } else {
                    // textured ground
                    let t = ((x as f32 * 0.9).sin() * (y as f32 * 1.3).cos()) * 0.06;
                    rgb[idx] = light * (0.35 + t + 0.2 * r0);
                    rgb[idx + 1] = light * (0.3 + t + 0.25 * g0);
                    rgb[idx + 2] = light * (0.22 + t);
                }
            }
        }

        // one geometric object: class parity picks circle vs box
        let cx = self.rng.uniform_range(8.0, 24.0);
        let cy = self.rng.uniform_range(12.0, 26.0);
        let rad = self.rng.uniform_range(4.0, 9.0);
        for y in 0..side {
            for x in 0..side {
                let inside = if class % 2 == 0 {
                    let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                    d2 < rad * rad
                } else {
                    (x as f32 - cx).abs() < rad && (y as f32 - cy).abs() < rad * 0.8
                };
                if inside {
                    let idx = (y * side + x) * 3;
                    rgb[idx] = 0.25 + 0.7 * r0;
                    rgb[idx + 1] = 0.25 + 0.7 * g0;
                    rgb[idx + 2] = 0.25 + 0.7 * b0;
                }
            }
        }

        rgb.iter()
            .map(|&v| {
                let noisy = v * 255.0 + self.rng.normal() * 4.0;
                noisy.clamp(0.0, 255.0) as u32
            })
            .collect()
    }
}

/// Anti-aliased thick line segment into a grayscale buffer.
fn draw_line(img: &mut [f32], side: usize, a: (f32, f32), b: (f32, f32), thickness: f32) {
    let (ax, ay) = a;
    let (bx, by) = b;
    let dx = bx - ax;
    let dy = by - ay;
    let len2 = (dx * dx + dy * dy).max(1e-6);
    let x_lo = (ax.min(bx) - thickness - 1.0).floor().max(0.0) as usize;
    let x_hi = ((ax.max(bx) + thickness + 1.0).ceil() as usize).min(side - 1);
    let y_lo = (ay.min(by) - thickness - 1.0).floor().max(0.0) as usize;
    let y_hi = ((ay.max(by) + thickness + 1.0).ceil() as usize).min(side - 1);
    for y in y_lo..=y_hi {
        for x in x_lo..=x_hi {
            let px = x as f32 + 0.5;
            let py = y as f32 + 0.5;
            // distance from pixel to segment
            let t = (((px - ax) * dx + (py - ay) * dy) / len2).clamp(0.0, 1.0);
            let qx = ax + t * dx;
            let qy = ay + t * dy;
            let d = ((px - qx).powi(2) + (py - qy).powi(2)).sqrt();
            let v = (1.0 - (d - thickness * 0.5).max(0.0) / 1.2).clamp(0.0, 1.0);
            let cell = &mut img[y * side + x];
            *cell = cell.max(v);
        }
    }
}

fn hue_to_rgb(h: f32) -> (f32, f32, f32) {
    let h6 = (h.fract()) * 6.0;
    let x = 1.0 - (h6 % 2.0 - 1.0).abs();
    match h6 as usize {
        0 => (1.0, x, 0.0),
        1 => (x, 1.0, 0.0),
        2 => (0.0, 1.0, x),
        3 => (0.0, x, 1.0),
        4 => (x, 0.0, 1.0),
        _ => (1.0, 0.0, x),
    }
}

/// Write a PGM (grayscale) or PPM (RGB) file for qualitative sample grids.
pub fn write_pnm(path: &str, pixels: &[u32], kind: ImageKind) -> std::io::Result<()> {
    let side = kind.side();
    let mut out = Vec::new();
    match kind {
        ImageKind::MnistLike => {
            out.extend_from_slice(format!("P5\n{side} {side}\n255\n").as_bytes());
            out.extend(pixels.iter().map(|&p| p as u8));
        }
        ImageKind::CifarLike => {
            out.extend_from_slice(format!("P6\n{side} {side}\n255\n").as_bytes());
            out.extend(pixels.iter().map(|&p| p as u8));
        }
    }
    std::fs::write(path, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_like_shapes_and_range() {
        let mut d = ImageDataset::new(ImageKind::MnistLike, 0);
        let (px, class) = d.sample();
        assert_eq!(px.len(), 784);
        assert!(class < 10);
        assert!(px.iter().all(|&p| p < 256));
    }

    #[test]
    fn cifar_like_shapes_and_range() {
        let mut d = ImageDataset::new(ImageKind::CifarLike, 0);
        let (px, class) = d.sample();
        assert_eq!(px.len(), 3072);
        assert!(class < 10);
        assert!(px.iter().all(|&p| p < 256));
    }

    #[test]
    fn digits_have_strokes_on_background() {
        let mut d = ImageDataset::new(ImageKind::MnistLike, 1);
        let (px, _) = d.sample();
        let bright = px.iter().filter(|&&p| p > 128).count();
        let dark = px.iter().filter(|&&p| p < 32).count();
        // strokes cover a small but nonzero fraction; most is background
        assert!(bright > 20, "bright={bright}");
        assert!(dark > 400, "dark={dark}");
    }

    #[test]
    fn classes_are_visually_distinct() {
        // render a 0 and a 1 with the same rng stream: expect different
        // stroke masses (the oval covers more pixels than the bar)
        let mut d = ImageDataset::new(ImageKind::MnistLike, 7);
        let mut masses = [0usize; 10];
        for _ in 0..50 {
            let (px, class) = d.sample();
            masses[class as usize] += px.iter().filter(|&&p| p > 100).count();
        }
        assert!(masses.iter().filter(|&&m| m > 0).count() >= 8);
    }

    #[test]
    fn lm_batch_is_shifted() {
        let mut d = ImageDataset::new(ImageKind::MnistLike, 2);
        let (inputs, targets) = d.lm_batch(2);
        assert_eq!(inputs.len(), 2 * 784);
        assert_eq!(targets.len(), 2 * 784);
        for s in 0..2 {
            assert_eq!(inputs[s * 784], 0, "start-of-image token");
            for i in 1..784 {
                assert_eq!(inputs[s * 784 + i], targets[s * 784 + i - 1]);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let (a, _) = ImageDataset::new(ImageKind::MnistLike, 42).sample();
        let (b, _) = ImageDataset::new(ImageKind::MnistLike, 42).sample();
        assert_eq!(a, b);
    }

    #[test]
    fn neighbouring_pixels_correlate() {
        // autoregressive pixel models rely on local smoothness: check the
        // mean absolute horizontal gradient is far below the value range
        let mut d = ImageDataset::new(ImageKind::CifarLike, 3);
        let (px, _) = d.sample();
        let mut grad = 0.0f64;
        let mut count = 0usize;
        for i in 0..px.len() - 3 {
            grad += (px[i] as f64 - px[i + 3] as f64).abs();
            count += 1;
        }
        let mean = grad / count as f64;
        assert!(mean < 40.0, "mean |grad| = {mean}");
    }
}
