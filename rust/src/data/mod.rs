//! Synthetic workload generators — the datasets of the paper's evaluation.
//!
//! No network access exists in this environment, so every dataset is a
//! carefully-shaped synthetic stand-in (documented in DESIGN.md §4):
//!
//! * [`copy_task`] — the §4.1 sequence-duplication task (exact match).
//! * [`images`] — procedural MNIST-like digits (784-long sequences) and
//!   CIFAR-like RGB textures (3072-long) for §4.2.
//! * [`speech`] — HMM-generated filterbank frames + phoneme labels for the
//!   §4.3 CTC experiment.

pub mod copy_task;
pub mod images;
pub mod speech;

pub use copy_task::CopyTask;
pub use images::{ImageDataset, ImageKind};
pub use speech::SpeechDataset;
