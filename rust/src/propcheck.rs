//! Mini property-based testing harness (proptest is unavailable offline).
//!
//! A [`Gen`] wraps the crate PRNG; properties are closures over generated
//! inputs, run for N cases. On failure the harness reports the seed and
//! case index so the exact input can be regenerated, and retries the
//! failing case with "smaller" size hints when the generator supports it
//! (shrinking-lite: we re-run with progressively smaller `size`).
//!
//! Used for the coordinator invariants (batching, routing, sessions), the
//! tensor algebra identities, and the attention-engine equivalences.

use crate::rng::Rng;

/// Random-input generator context with a size hint.
pub struct Gen {
    pub rng: Rng,
    /// Size hint in [1, 100]; generators should scale with it.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(len, std)
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub case: usize,
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (seed {}, size {}): {}",
            self.case, self.seed, self.size, self.message
        )
    }
}

/// Run `prop` for `cases` random cases. The property returns
/// `Err(message)` to fail. Panics with a reproducible report on failure.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    check_seeded(name, base_seed(name), cases, prop)
}

/// Like [`check`] with an explicit base seed (for regression pinning).
pub fn check_seeded(
    name: &str,
    base: u64,
    cases: usize,
    prop: impl Fn(&mut Gen) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // ramp size from small to large so early failures are tiny cases
        let size = 1 + (case * 100) / cases.max(1);
        let mut g = Gen::new(seed, size);
        if let Err(message) = prop(&mut g) {
            // shrinking-lite: retry with smaller sizes to find a smaller repro
            let mut best = Failure {
                seed,
                case,
                size,
                message,
            };
            for s in [1usize, 2, 5, 10, 25] {
                if s >= size {
                    break;
                }
                let mut g2 = Gen::new(seed, s);
                if let Err(m2) = prop(&mut g2) {
                    best = Failure {
                        seed,
                        case,
                        size: s,
                        message: m2,
                    };
                    break;
                }
            }
            panic!("[propcheck:{name}] {best}");
        }
    }
}

/// Env-tunable case count: PROPCHECK_CASES overrides (for soak runs).
pub fn default_cases() -> usize {
    std::env::var("PROPCHECK_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed(name: &str) -> u64 {
    // FNV-1a over the property name: stable across runs, distinct per prop
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-involution", 50, |g| {
            let n = g.usize_in(0, g.size);
            let v = g.vec_usize(n, 0, 100);
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            if r == v {
                Ok(())
            } else {
                Err("reverse twice != identity".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "propcheck:always-fails")]
    fn failing_property_reports() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn size_ramps_up() {
        use std::cell::RefCell;
        let sizes = RefCell::new(Vec::new());
        check("size-ramp", 20, |g| {
            sizes.borrow_mut().push(g.size);
            Ok(())
        });
        let s = sizes.borrow();
        assert!(s.first().unwrap() < s.last().unwrap());
    }

    #[test]
    fn deterministic_per_name() {
        let collect = |name: &str| {
            use std::cell::RefCell;
            let vals = RefCell::new(Vec::new());
            check_seeded(name, base_seed(name), 5, |g| {
                vals.borrow_mut().push(g.rng.next_u64());
                Ok(())
            });
            vals.into_inner()
        };
        assert_eq!(collect("alpha"), collect("alpha"));
        assert_ne!(collect("alpha"), collect("beta"));
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1, 50);
        for _ in 0..100 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
