//! Mini property-based testing harness (proptest is unavailable offline).
//!
//! A [`Gen`] wraps the crate PRNG; properties are closures over generated
//! inputs, run for N cases. On failure the harness reports the seed and
//! case index so the exact input can be regenerated, and retries the
//! failing case with "smaller" size hints when the generator supports it
//! (shrinking-lite: we re-run with progressively smaller `size`).
//!
//! Used for the coordinator invariants (batching, routing, sessions), the
//! tensor algebra identities, and the attention-engine equivalences.

use crate::rng::Rng;

/// Random-input generator context with a size hint.
pub struct Gen {
    pub rng: Rng,
    /// Size hint in [1, 100]; generators should scale with it.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(len, std)
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub case: usize,
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (seed {}, size {}): {}",
            self.case, self.seed, self.size, self.message
        )
    }
}

/// Run `prop` for `cases` random cases. The property returns
/// `Err(message)` to fail. Panics with a reproducible report on failure.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    check_seeded(name, base_seed(name), cases, prop)
}

/// Like [`check`] with an explicit base seed (for regression pinning).
pub fn check_seeded(
    name: &str,
    base: u64,
    cases: usize,
    prop: impl Fn(&mut Gen) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // ramp size from small to large so early failures are tiny cases
        let size = 1 + (case * 100) / cases.max(1);
        let mut g = Gen::new(seed, size);
        if let Err(message) = prop(&mut g) {
            // shrinking-lite: retry with smaller sizes to find a smaller repro
            let mut best = Failure {
                seed,
                case,
                size,
                message,
            };
            for s in [1usize, 2, 5, 10, 25] {
                if s >= size {
                    break;
                }
                let mut g2 = Gen::new(seed, s);
                if let Err(m2) = prop(&mut g2) {
                    best = Failure {
                        seed,
                        case,
                        size: s,
                        message: m2,
                    };
                    break;
                }
            }
            panic!("[propcheck:{name}] {best}");
        }
    }
}

/// ULP distance between two f32s: how many representable floats sit
/// between them (same-sign; opposite signs measure through zero).
/// `f32::MAX` for NaN on either side, 0 for `+0.0` vs `-0.0`.
pub fn ulp_distance(a: f32, b: f32) -> u32 {
    if a.is_nan() || b.is_nan() {
        return u32::MAX;
    }
    // map the bit pattern onto a monotone integer line: both zeros land
    // on 0, negatives mirror below so ordering matches the real line
    fn ordered(x: f32) -> i64 {
        let bits = x.to_bits() as i32 as i64;
        if bits < 0 {
            (i32::MIN as i64) - bits
        } else {
            bits
        }
    }
    (ordered(a) - ordered(b)).unsigned_abs().min(u32::MAX as u64) as u32
}

/// Assert two floats are close under the numeric contract used by the
/// low-precision weight paths: within `max_ulps` representable values of
/// each other, OR within `abs_tol` absolutely (covers results near zero,
/// where ULP distance explodes), OR within `rel_tol` of the larger
/// magnitude. Panics with all three measurements on failure.
///
/// The weight-storage contract (ARCHITECTURE.md §Weight storage &
/// numeric contract) is phrased in these terms: f32 paths are compared
/// bitwise (`max_ulps = 0`), quantized paths at a documented
/// `(rel_tol, abs_tol)` per dtype.
#[track_caller]
pub fn assert_close_ulp(got: f32, want: f32, max_ulps: u32, rel_tol: f32, abs_tol: f32, what: &str) {
    let ulps = ulp_distance(got, want);
    if ulps <= max_ulps {
        return;
    }
    let diff = (got - want).abs();
    if diff <= abs_tol {
        return;
    }
    let scale = got.abs().max(want.abs());
    if diff <= rel_tol * scale {
        return;
    }
    panic!(
        "{what}: got {got}, want {want} \
         (|diff| {diff:.3e} > abs_tol {abs_tol:.3e}, rel {:.3e} > rel_tol {rel_tol:.3e}, \
         {ulps} ulps > {max_ulps})",
        if scale > 0.0 { diff / scale } else { 0.0 },
    );
}

/// Env-tunable case count: `PROPCHECK_CASES` overrides (for soak runs).
/// The env read itself lives in [`crate::config::resolve_propcheck_cases`]
/// — every environment knob resolves in one place, an invariant
/// `lintra analyze` (rule `env`) enforces.
pub fn default_cases() -> usize {
    crate::config::resolve_propcheck_cases(64)
}

/// Per-tick invariants of the serving engine's continuous-batching loop
/// (`coordinator::engine::run_engine`), checked in debug builds only.
///
/// The tick loop maintains a dense lane array mirrored against the slot
/// table, partitioned into a *decode prefix* (lanes `0..n_dec`, stepped
/// together each tick) and a *prefill suffix* (lanes `n_dec..len`,
/// absorbing prompt chunks). Everything the sampling and compaction code
/// does assumes this discipline; a violation surfaces here — at the tick
/// that broke it — instead of as a wrong token several ticks later. CI
/// runs the release-mode test leg with `-C debug-assertions` so these
/// checks also cover the optimized build.
pub mod engine_invariants {
    use crate::coordinator::sessions::{SlotPhase, SlotTable};
    use crate::coordinator::state_cache::StateCache;

    /// A borrow of the engine's per-tick scheduling state.
    pub struct TickView<'a> {
        /// `backend.lanes()` — the backend's live lane count.
        pub backend_lanes: usize,
        /// Decode-prefix width: lanes `0..n_dec` are decoding.
        pub n_dec: usize,
        /// Engine-side lane → slot map.
        pub lane_slots: &'a [usize],
        /// The slot table the lane map points into.
        pub slots: &'a SlotTable,
        /// The prefix-reuse cache, when enabled.
        pub cache: Option<&'a StateCache>,
    }

    /// Validate one tick's scheduling state. A no-op (and essentially
    /// free) unless debug assertions are enabled.
    pub fn check_tick(v: &TickView<'_>) {
        if !cfg!(debug_assertions) {
            return;
        }
        debug_assert_eq!(
            v.backend_lanes,
            v.lane_slots.len(),
            "backend lanes and the engine lane map must agree"
        );
        debug_assert_eq!(
            v.lane_slots.len(),
            v.slots.active(),
            "every lane maps to exactly one active slot"
        );
        debug_assert!(
            v.n_dec <= v.lane_slots.len(),
            "decode prefix {} wider than the lane array {}",
            v.n_dec,
            v.lane_slots.len()
        );
        let mut seen = v.lane_slots.to_vec();
        seen.sort_unstable();
        debug_assert!(
            seen.windows(2).all(|w| w[0] != w[1]),
            "a slot occupies two lanes"
        );
        for (lane, &slot) in v.lane_slots.iter().enumerate() {
            let info = v.slots.get(slot);
            debug_assert!(info.is_some(), "lane {lane} maps to dead slot {slot}");
            let Some(info) = info else { continue };
            debug_assert!(
                info.cursor <= info.prompt.len(),
                "slot {slot} cursor {} overran its prompt ({} tokens)",
                info.cursor,
                info.prompt.len()
            );
            if lane < v.n_dec {
                debug_assert_eq!(
                    info.phase,
                    SlotPhase::Decoding,
                    "decode-prefix lane {lane} holds a mid-prefill slot"
                );
            } else {
                debug_assert_eq!(
                    info.phase,
                    SlotPhase::Prefilling,
                    "prefill-suffix lane {lane} holds a decoding slot"
                );
            }
        }
        if let Some(cache) = v.cache {
            cache.debug_check_accounting();
        }
    }
}

fn base_seed(name: &str) -> u64 {
    // FNV-1a over the property name: stable across runs, distinct per prop
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-involution", 50, |g| {
            let n = g.usize_in(0, g.size);
            let v = g.vec_usize(n, 0, 100);
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            if r == v {
                Ok(())
            } else {
                Err("reverse twice != identity".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "propcheck:always-fails")]
    fn failing_property_reports() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn size_ramps_up() {
        use std::cell::RefCell;
        let sizes = RefCell::new(Vec::new());
        check("size-ramp", 20, |g| {
            sizes.borrow_mut().push(g.size);
            Ok(())
        });
        let s = sizes.borrow();
        assert!(s.first().unwrap() < s.last().unwrap());
    }

    #[test]
    fn deterministic_per_name() {
        let collect = |name: &str| {
            use std::cell::RefCell;
            let vals = RefCell::new(Vec::new());
            check_seeded(name, base_seed(name), 5, |g| {
                vals.borrow_mut().push(g.rng.next_u64());
                Ok(())
            });
            vals.into_inner()
        };
        assert_eq!(collect("alpha"), collect("alpha"));
        assert_ne!(collect("alpha"), collect("beta"));
    }

    #[test]
    fn ulp_distance_counts_representable_steps() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_distance(-1.0, f32::from_bits((-1.0f32).to_bits() + 1)), 1);
        // crossing zero: smallest positive vs smallest negative subnormal
        assert_eq!(ulp_distance(f32::from_bits(1), -f32::from_bits(1)), 2);
        assert_eq!(ulp_distance(f32::NAN, 1.0), u32::MAX);
        assert!(ulp_distance(1.0, 2.0) > 1_000_000);
    }

    #[test]
    fn assert_close_ulp_accepts_each_gate() {
        assert_close_ulp(1.0, 1.0, 0, 0.0, 0.0, "bitwise");
        let next = f32::from_bits(1.0f32.to_bits() + 1);
        assert_close_ulp(1.0, next, 1, 0.0, 0.0, "one ulp");
        assert_close_ulp(1e-9, -1e-9, 0, 0.0, 1e-8, "abs tol near zero");
        assert_close_ulp(100.0, 100.4, 0, 5e-3, 0.0, "rel tol");
    }

    #[test]
    #[should_panic(expected = "tolerance-breach")]
    fn assert_close_ulp_rejects_out_of_contract() {
        assert_close_ulp(1.0, 1.1, 4, 1e-3, 1e-6, "tolerance-breach");
    }

    #[test]
    fn engine_invariants_accept_a_coherent_tick() {
        use crate::coordinator::sessions::{SlotInfo, SlotTable};
        let mut slots = SlotTable::new(4);
        let a = slots.alloc(SlotInfo::new(1, std::time::Instant::now(), vec![1, 2], 4, 0.0, 0));
        let b = slots.alloc(SlotInfo::new(2, std::time::Instant::now(), vec![3, 4], 4, 0.0, 0));
        let (a, b) = (a.unwrap(), b.unwrap());
        slots.get_mut(b).unwrap().start_prefill();
        // lane 0 decoding, lane 1 mid-prefill: exactly the discipline
        engine_invariants::check_tick(&engine_invariants::TickView {
            backend_lanes: 2,
            n_dec: 1,
            lane_slots: &[a, b],
            slots: &slots,
            cache: None,
        });
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "decode-prefix lane")]
    fn engine_invariants_reject_a_mid_prefill_slot_in_the_decode_prefix() {
        use crate::coordinator::sessions::{SlotInfo, SlotTable};
        let mut slots = SlotTable::new(4);
        let a = slots.alloc(SlotInfo::new(1, std::time::Instant::now(), vec![1, 2], 4, 0.0, 0));
        let b = slots.alloc(SlotInfo::new(2, std::time::Instant::now(), vec![3, 4], 4, 0.0, 0));
        let (a, b) = (a.unwrap(), b.unwrap());
        slots.get_mut(b).unwrap().start_prefill();
        // n_dec = 2 claims lane 1 is decoding, but its slot is prefilling
        engine_invariants::check_tick(&engine_invariants::TickView {
            backend_lanes: 2,
            n_dec: 2,
            lane_slots: &[a, b],
            slots: &slots,
            cache: None,
        });
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "a slot occupies two lanes")]
    fn engine_invariants_reject_a_duplicated_slot_mapping() {
        use crate::coordinator::sessions::{SlotInfo, SlotTable};
        let mut slots = SlotTable::new(4);
        let a = slots
            .alloc(SlotInfo::new(1, std::time::Instant::now(), vec![1, 2], 4, 0.0, 0))
            .unwrap();
        let _b = slots
            .alloc(SlotInfo::new(2, std::time::Instant::now(), vec![3, 4], 4, 0.0, 0))
            .unwrap();
        engine_invariants::check_tick(&engine_invariants::TickView {
            backend_lanes: 2,
            n_dec: 2,
            lane_slots: &[a, a],
            slots: &slots,
            cache: None,
        });
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1, 50);
        for _ in 0..100 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
