//! Mini property-based testing harness (proptest is unavailable offline).
//!
//! A [`Gen`] wraps the crate PRNG; properties are closures over generated
//! inputs, run for N cases. On failure the harness reports the seed and
//! case index so the exact input can be regenerated, and retries the
//! failing case with "smaller" size hints when the generator supports it
//! (shrinking-lite: we re-run with progressively smaller `size`).
//!
//! Used for the coordinator invariants (batching, routing, sessions), the
//! tensor algebra identities, and the attention-engine equivalences.

use crate::rng::Rng;

/// Random-input generator context with a size hint.
pub struct Gen {
    pub rng: Rng,
    /// Size hint in [1, 100]; generators should scale with it.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(len, std)
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct Failure {
    pub seed: u64,
    pub case: usize,
    pub size: usize,
    pub message: String,
}

impl std::fmt::Display for Failure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "property failed at case {} (seed {}, size {}): {}",
            self.case, self.seed, self.size, self.message
        )
    }
}

/// Run `prop` for `cases` random cases. The property returns
/// `Err(message)` to fail. Panics with a reproducible report on failure.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    check_seeded(name, base_seed(name), cases, prop)
}

/// Like [`check`] with an explicit base seed (for regression pinning).
pub fn check_seeded(
    name: &str,
    base: u64,
    cases: usize,
    prop: impl Fn(&mut Gen) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        // ramp size from small to large so early failures are tiny cases
        let size = 1 + (case * 100) / cases.max(1);
        let mut g = Gen::new(seed, size);
        if let Err(message) = prop(&mut g) {
            // shrinking-lite: retry with smaller sizes to find a smaller repro
            let mut best = Failure {
                seed,
                case,
                size,
                message,
            };
            for s in [1usize, 2, 5, 10, 25] {
                if s >= size {
                    break;
                }
                let mut g2 = Gen::new(seed, s);
                if let Err(m2) = prop(&mut g2) {
                    best = Failure {
                        seed,
                        case,
                        size: s,
                        message: m2,
                    };
                    break;
                }
            }
            panic!("[propcheck:{name}] {best}");
        }
    }
}

/// ULP distance between two f32s: how many representable floats sit
/// between them (same-sign; opposite signs measure through zero).
/// `f32::MAX` for NaN on either side, 0 for `+0.0` vs `-0.0`.
pub fn ulp_distance(a: f32, b: f32) -> u32 {
    if a.is_nan() || b.is_nan() {
        return u32::MAX;
    }
    // map the bit pattern onto a monotone integer line: both zeros land
    // on 0, negatives mirror below so ordering matches the real line
    fn ordered(x: f32) -> i64 {
        let bits = x.to_bits() as i32 as i64;
        if bits < 0 {
            (i32::MIN as i64) - bits
        } else {
            bits
        }
    }
    (ordered(a) - ordered(b)).unsigned_abs().min(u32::MAX as u64) as u32
}

/// Assert two floats are close under the numeric contract used by the
/// low-precision weight paths: within `max_ulps` representable values of
/// each other, OR within `abs_tol` absolutely (covers results near zero,
/// where ULP distance explodes), OR within `rel_tol` of the larger
/// magnitude. Panics with all three measurements on failure.
///
/// The weight-storage contract (ARCHITECTURE.md §Weight storage &
/// numeric contract) is phrased in these terms: f32 paths are compared
/// bitwise (`max_ulps = 0`), quantized paths at a documented
/// `(rel_tol, abs_tol)` per dtype.
#[track_caller]
pub fn assert_close_ulp(got: f32, want: f32, max_ulps: u32, rel_tol: f32, abs_tol: f32, what: &str) {
    let ulps = ulp_distance(got, want);
    if ulps <= max_ulps {
        return;
    }
    let diff = (got - want).abs();
    if diff <= abs_tol {
        return;
    }
    let scale = got.abs().max(want.abs());
    if diff <= rel_tol * scale {
        return;
    }
    panic!(
        "{what}: got {got}, want {want} \
         (|diff| {diff:.3e} > abs_tol {abs_tol:.3e}, rel {:.3e} > rel_tol {rel_tol:.3e}, \
         {ulps} ulps > {max_ulps})",
        if scale > 0.0 { diff / scale } else { 0.0 },
    );
}

/// Env-tunable case count: PROPCHECK_CASES overrides (for soak runs).
pub fn default_cases() -> usize {
    std::env::var("PROPCHECK_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

fn base_seed(name: &str) -> u64 {
    // FNV-1a over the property name: stable across runs, distinct per prop
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse-involution", 50, |g| {
            let n = g.usize_in(0, g.size);
            let v = g.vec_usize(n, 0, 100);
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            if r == v {
                Ok(())
            } else {
                Err("reverse twice != identity".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "propcheck:always-fails")]
    fn failing_property_reports() {
        check("always-fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn size_ramps_up() {
        use std::cell::RefCell;
        let sizes = RefCell::new(Vec::new());
        check("size-ramp", 20, |g| {
            sizes.borrow_mut().push(g.size);
            Ok(())
        });
        let s = sizes.borrow();
        assert!(s.first().unwrap() < s.last().unwrap());
    }

    #[test]
    fn deterministic_per_name() {
        let collect = |name: &str| {
            use std::cell::RefCell;
            let vals = RefCell::new(Vec::new());
            check_seeded(name, base_seed(name), 5, |g| {
                vals.borrow_mut().push(g.rng.next_u64());
                Ok(())
            });
            vals.into_inner()
        };
        assert_eq!(collect("alpha"), collect("alpha"));
        assert_ne!(collect("alpha"), collect("beta"));
    }

    #[test]
    fn ulp_distance_counts_representable_steps() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(ulp_distance(-1.0, f32::from_bits((-1.0f32).to_bits() + 1)), 1);
        // crossing zero: smallest positive vs smallest negative subnormal
        assert_eq!(ulp_distance(f32::from_bits(1), -f32::from_bits(1)), 2);
        assert_eq!(ulp_distance(f32::NAN, 1.0), u32::MAX);
        assert!(ulp_distance(1.0, 2.0) > 1_000_000);
    }

    #[test]
    fn assert_close_ulp_accepts_each_gate() {
        assert_close_ulp(1.0, 1.0, 0, 0.0, 0.0, "bitwise");
        let next = f32::from_bits(1.0f32.to_bits() + 1);
        assert_close_ulp(1.0, next, 1, 0.0, 0.0, "one ulp");
        assert_close_ulp(1e-9, -1e-9, 0, 0.0, 1e-8, "abs tol near zero");
        assert_close_ulp(100.0, 100.4, 0, 5e-3, 0.0, "rel tol");
    }

    #[test]
    #[should_panic(expected = "tolerance-breach")]
    fn assert_close_ulp_rejects_out_of_contract() {
        assert_close_ulp(1.0, 1.1, 4, 1e-3, 1e-6, "tolerance-breach");
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen::new(1, 50);
        for _ in 0..100 {
            let v = g.usize_in(3, 7);
            assert!((3..=7).contains(&v));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }
}
