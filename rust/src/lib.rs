//! # linear-transformer
//!
//! Production-shaped reproduction of *“Transformers are RNNs: Fast
//! Autoregressive Transformers with Linear Attention”* (Katharopoulos,
//! Vyas, Pappas, Fleuret — ICML 2020) as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! This crate is **Layer 3**: the coordinator. It owns the event loop,
//! the serving engine, the trainer, the CLI, and every substrate the
//! paper's evaluation needs — a tensor library, four attention engines
//! (linear / softmax / stateful-softmax / LSH), a pure-rust transformer
//! and Bi-LSTM, synthetic workload generators, metrics, and a PJRT
//! runtime that loads the HLO artifacts lowered by the build-time Python
//! layers (L2 JAX model, L1 Pallas kernels).
//!
//! Two inference paths coexist by design (see DESIGN.md §2):
//!
//! * [`runtime`] executes AOT artifacts (`artifacts/*.hlo.txt`) through
//!   the PJRT CPU client — training steps and batched decode.
//! * [`nn`] + [`attention`] run the same weights natively in rust — the
//!   level playing field for the paper's Figure 1 / Tables 1–5 sweeps,
//!   and the demonstration of the supplementary's claim that linear-RNN
//!   inference is CPU-friendly.
//!
//! `ARCHITECTURE.md` at the repo root walks the serving stack end to
//! end (request lifecycle, the `DecodeBackend` contract, incremental
//! prefill scheduling, the snapshot/restore contract behind the
//! prefix-reuse state cache, the thread-pool bitwise-parity invariant);
//! `README.md` has the serve-binary quickstart.

pub mod analysis;
pub mod attention;
pub mod benchkit;
pub mod benchkit_gen;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod json;
pub mod metrics;
pub mod nn;
pub mod parallel;
pub mod propcheck;
pub mod rng;
pub mod runtime;
pub mod sampling;
pub mod simd;
pub mod tensor;
pub mod trainer;
pub mod tunables;
pub mod weights;

/// Crate-wide result type (thin alias over `anyhow`).
pub type Result<T> = anyhow::Result<T>;
