//! Pure-rust neural-net substrate: the native inference path.
//!
//! [`TransformerLM`] mirrors `python/compile/model.py` exactly (pre-norm
//! blocks, gelu FF, learned positional embeddings, per-head column-block
//! projections) so that LTW1 weights trained through the PJRT path drop
//! straight in. Parity with the jax model is asserted by
//! `rust/tests/parity.rs`.
//!
//! Generation backends implement the paper's four decode strategies:
//! linear RNN state (O(1)/token), stateful-softmax KV cache (O(t)/token),
//! naive softmax (full recompute, O(t²)/token) and LSH (full recompute —
//! Reformer cannot decode statefully; see §C.1 of the paper).

pub mod lstm;
pub mod softmax_session;

pub use softmax_session::BatchedSoftmaxSession;

use std::sync::Arc;

use crate::attention::{linear, lsh, softmax, stateful_softmax, AttentionKind};
use crate::config::ModelConfig;
use crate::parallel::ThreadPool;
use crate::rng::Rng;
use crate::tensor::{
    add_bias_rows, gather_cols, gelu, layer_norm_into, layer_norm_rows_pooled,
    matmul_into_pooled, matmul_into_w, matmul_into_w_pooled, scatter_cols, vecmat_into,
    vecmat_into_cols_pooled, vecmat_into_w, vecmat_into_w_cols_pooled, Tensor, WeightDtype,
    WeightMat,
};
use crate::weights::{NamedTensor, WeightBundle};

/// Does the serving path store this parameter at the active
/// [`WeightDtype`]? True exactly for the GEMV-shaped matrices the decode
/// tick streams — the QKV/output projections, both FF matrices, and the
/// lm-head. Embeddings (consumed by row gathers, not GEMVs), layer
/// norms, and biases stay f32: they are a rounding error of the byte
/// traffic and keep the normalization math full-precision.
///
/// `lintra cast` uses the same predicate when writing a low-precision
/// bundle, so an offline cast quantizes exactly the tensors an in-memory
/// cast would (see [`crate::weights::WeightBundle::save_as`]).
pub fn quantized_param(name: &str) -> bool {
    name == "head.w"
        || [".attn.wq", ".attn.wk", ".attn.wv", ".attn.wo", ".ff.w1", ".ff.w2"]
            .iter()
            .any(|s| name.ends_with(s))
}

/// One block's packed low-precision weights (mirrors [`BlockWeights`]'
/// GEMV-shaped matrices).
#[derive(Clone, Debug)]
struct QuantBlock {
    wq: WeightMat,
    wk: WeightMat,
    wv: WeightMat,
    wo: WeightMat,
    ff_w1: WeightMat,
    ff_w2: WeightMat,
}

/// Packed copies of every quantized parameter, built by
/// [`TransformerLM::cast_weights`] when a non-f32 dtype is active. The
/// f32 [`Tensor`]s stay resident as the cast source (re-casting is
/// always exact) and as the reference for tooling; inference consumes
/// the packed side whenever it is present.
#[derive(Clone, Debug)]
struct QuantWeights {
    dtype: WeightDtype,
    blocks: Vec<QuantBlock>,
    head_w: WeightMat,
}

/// Route a `[m,k] x [k,n]` projection: packed widening kernel when a
/// quantized copy exists, the legacy f32 kernel otherwise. Both sides
/// share the pooled row/column partitioning rules.
fn mm_w(
    pool: Option<&ThreadPool>,
    c: &mut [f32],
    a: &[f32],
    quant: Option<&WeightMat>,
    f32w: &Tensor,
    m: usize,
    k: usize,
    n: usize,
) {
    match quant {
        Some(w) => matmul_into_w_pooled(pool, c, a, w, m, k, n),
        None => matmul_into_pooled(pool, c, a, &f32w.data, m, k, n),
    }
}

/// Route an allocating `[m,k] x [k,n]` projection (full-sequence forward
/// path, where the caller wants a fresh [`Tensor`]).
fn mm_alloc(a: &Tensor, quant: Option<&WeightMat>, f32w: &Tensor) -> Tensor {
    match quant {
        Some(w) => {
            let (m, k) = a.dims2();
            let n = f32w.dims2().1;
            let mut out = Tensor::zeros(&[m, n]);
            matmul_into_w(&mut out.data, &a.data, w, m, k, n);
            out
        }
        None => crate::tensor::matmul(a, f32w),
    }
}

/// Route a serial GEMV (single-row decode paths).
fn vm_w(y: &mut [f32], x: &[f32], quant: Option<&WeightMat>, f32w: &Tensor, k: usize, n: usize) {
    match quant {
        Some(w) => vecmat_into_w(y, x, w, k, n),
        None => vecmat_into(y, x, &f32w.data, k, n),
    }
}

/// Route a pooled column-split GEMV (the lm-head at the end of a
/// prefill, where `n = vocab` dwarfs every other shape).
fn vm_w_pooled(
    pool: Option<&ThreadPool>,
    y: &mut [f32],
    x: &[f32],
    quant: Option<&WeightMat>,
    f32w: &Tensor,
    k: usize,
    n: usize,
) {
    match quant {
        Some(w) => vecmat_into_w_cols_pooled(pool, y, x, w, k, n),
        None => vecmat_into_cols_pooled(pool, y, x, &f32w.data, k, n),
    }
}

/// Weights of one transformer block.
#[derive(Clone, Debug)]
pub struct BlockWeights {
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
    pub ff_w1: Tensor,
    pub ff_b1: Tensor,
    pub ff_w2: Tensor,
    pub ff_b2: Tensor,
}

/// The full language model.
#[derive(Clone, Debug)]
pub struct TransformerLM {
    pub cfg: ModelConfig,
    pub kind: AttentionKind,
    pub tok_embed: Tensor,
    pub pos_embed: Tensor,
    pub blocks: Vec<BlockWeights>,
    pub final_ln_g: Tensor,
    pub final_ln_b: Tensor,
    pub head_w: Tensor,
    pub head_b: Tensor,
    /// LSH rotation bank (derived, not learned), present for lsh models.
    lsh_rotations: Vec<Vec<f32>>,
    lsh_cfg: lsh::LshConfig,
    /// Packed low-precision weights when a non-f32 [`WeightDtype`] is
    /// active; `None` means every kernel reads the f32 tensors directly.
    quant: Option<QuantWeights>,
}

impl TransformerLM {
    /// Load from an LTW1 bundle written by `aot.py` (or a trainer checkpoint).
    pub fn from_bundle(
        cfg: &ModelConfig,
        kind: AttentionKind,
        bundle: &WeightBundle,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let t = |name: &str| -> anyhow::Result<Tensor> {
            bundle
                .get(name)
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("bundle missing parameter {name:?}"))
        };
        let mut blocks = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layer{i}");
            blocks.push(BlockWeights {
                ln1_g: t(&format!("{p}.ln1.g"))?,
                ln1_b: t(&format!("{p}.ln1.b"))?,
                wq: t(&format!("{p}.attn.wq"))?,
                wk: t(&format!("{p}.attn.wk"))?,
                wv: t(&format!("{p}.attn.wv"))?,
                wo: t(&format!("{p}.attn.wo"))?,
                ln2_g: t(&format!("{p}.ln2.g"))?,
                ln2_b: t(&format!("{p}.ln2.b"))?,
                ff_w1: t(&format!("{p}.ff.w1"))?,
                ff_b1: t(&format!("{p}.ff.b1"))?,
                ff_w2: t(&format!("{p}.ff.w2"))?,
                ff_b2: t(&format!("{p}.ff.b2"))?,
            });
        }
        let lsh_cfg = lsh::LshConfig {
            rounds: match kind {
                AttentionKind::Lsh { rounds } => rounds,
                _ => cfg.lsh_rounds,
            },
            buckets: cfg.lsh_buckets,
            chunk: cfg.lsh_chunk,
            seed: 0,
        };
        let lsh_rotations = make_lsh_rotations(&lsh_cfg, cfg.d_head());
        let mut model = TransformerLM {
            cfg: cfg.clone(),
            kind,
            tok_embed: t("embed.tok")?,
            pos_embed: t("embed.pos")?,
            blocks,
            final_ln_g: t("final_ln.g")?,
            final_ln_b: t("final_ln.b")?,
            head_w: t("head.w")?,
            head_b: t("head.b")?,
            lsh_rotations,
            lsh_cfg,
            quant: None,
        };
        // Honour the ambient LINTRA_WEIGHT_DTYPE so every consumer of a
        // freshly loaded model (tests, examples, benches) runs the same
        // numeric path without separate plumbing. The engine re-casts with
        // its explicit `ServeConfig::weight_dtype` on spawn.
        model.cast_weights(crate::config::resolve_weight_dtype(None));
        Ok(model)
    }

    /// (Re)build the packed weight sidecar at `dtype`. `F32` drops the
    /// sidecar and restores the bitwise-reference kernels. The f32
    /// tensors are retained untouched as the cast source, so casting is
    /// idempotent and switching dtypes never compounds rounding error.
    pub fn cast_weights(&mut self, dtype: WeightDtype) {
        if dtype == WeightDtype::F32 {
            self.quant = None;
            return;
        }
        let q = |t: &Tensor| {
            let (rows, cols) = t.dims2();
            WeightMat::quantize(&t.data, rows, cols, dtype)
        };
        self.quant = Some(QuantWeights {
            dtype,
            blocks: self
                .blocks
                .iter()
                .map(|b| QuantBlock {
                    wq: q(&b.wq),
                    wk: q(&b.wk),
                    wv: q(&b.wv),
                    wo: q(&b.wo),
                    ff_w1: q(&b.ff_w1),
                    ff_w2: q(&b.ff_w2),
                })
                .collect(),
            head_w: q(&self.head_w),
        });
    }

    /// The dtype the serving kernels currently read weights at.
    pub fn weight_dtype(&self) -> WeightDtype {
        self.quant.as_ref().map(|q| q.dtype).unwrap_or(WeightDtype::F32)
    }

    /// Bytes of projection/FF/lm-head weight traffic one decode tick
    /// streams per lane — the quantity the weight-dtype work shrinks.
    /// Counts only [`quantized_param`] tensors (embeddings are row
    /// gathers, norms/biases are O(e)).
    pub fn weight_bytes_per_token(&self) -> usize {
        match &self.quant {
            Some(qw) => {
                qw.blocks
                    .iter()
                    .map(|b| {
                        b.wq.weight_bytes()
                            + b.wk.weight_bytes()
                            + b.wv.weight_bytes()
                            + b.wo.weight_bytes()
                            + b.ff_w1.weight_bytes()
                            + b.ff_w2.weight_bytes()
                    })
                    .sum::<usize>()
                    + qw.head_w.weight_bytes()
            }
            None => {
                let elems = self
                    .blocks
                    .iter()
                    .map(|b| {
                        b.wq.numel()
                            + b.wk.numel()
                            + b.wv.numel()
                            + b.wo.numel()
                            + b.ff_w1.numel()
                            + b.ff_w2.numel()
                    })
                    .sum::<usize>()
                    + self.head_w.numel();
                elems * std::mem::size_of::<f32>()
            }
        }
    }

    /// Random init (same scales as python init_params) — for benches that
    /// measure speed rather than quality.
    pub fn init(cfg: &ModelConfig, kind: AttentionKind, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let e = cfg.d_model;
        let bundle = WeightBundle::new(random_param_tensors(cfg, &mut rng));
        let mut model = Self::from_bundle(cfg, kind, &bundle).expect("init bundle complete");
        // keep tensors in struct; bundle dropped
        let _ = e;
        model.lsh_cfg.seed = seed;
        model.lsh_rotations = make_lsh_rotations(&model.lsh_cfg, cfg.d_head());
        model
    }

    pub fn n_params(&self) -> usize {
        let mut n = self.tok_embed.numel()
            + self.pos_embed.numel()
            + self.final_ln_g.numel()
            + self.final_ln_b.numel()
            + self.head_w.numel()
            + self.head_b.numel();
        for b in &self.blocks {
            n += b.wq.numel() * 4
                + b.ln1_g.numel() * 4 // ln1 g/b + ln2 g/b
                + b.ff_w1.numel()
                + b.ff_b1.numel()
                + b.ff_w2.numel()
                + b.ff_b2.numel();
        }
        n
    }

    // -----------------------------------------------------------------------
    // full-sequence forward (teacher-forced eval; Figure 1-style workloads)
    // -----------------------------------------------------------------------

    /// Forward a token sequence -> logits [n, vocab].
    pub fn forward(&self, tokens: &[u32]) -> Tensor {
        let n = tokens.len();
        let e = self.cfg.d_model;
        assert!(n <= self.cfg.max_len, "sequence {n} > max_len {}", self.cfg.max_len);
        let mut x = Tensor::zeros(&[n, e]);
        for (i, &t) in tokens.iter().enumerate() {
            let row = x.row_mut(i);
            let te = self.tok_embed.row(t as usize);
            let pe = self.pos_embed.row(i);
            for j in 0..e {
                row[j] = te[j] + pe[j];
            }
        }
        for (li, blk) in self.blocks.iter().enumerate() {
            self.block_forward(blk, self.quant.as_ref().map(|q| &q.blocks[li]), &mut x);
        }
        // final ln + head
        let mut normed = Tensor::zeros(&[n, e]);
        for i in 0..n {
            layer_norm_into(
                normed.row_mut(i),
                x.row(i),
                &self.final_ln_g.data,
                &self.final_ln_b.data,
            );
        }
        let mut logits = mm_alloc(&normed, self.quant.as_ref().map(|q| &q.head_w), &self.head_w);
        for i in 0..n {
            for (l, b) in logits.row_mut(i).iter_mut().zip(&self.head_b.data) {
                *l += b;
            }
        }
        logits
    }

    /// Mean next-token NLL (nats) of a teacher-forced sequence.
    pub fn sequence_nll(&self, inputs: &[u32], targets: &[u32]) -> f64 {
        let logits = self.forward(inputs);
        crate::metrics::mean_nll(&logits.data, self.cfg.vocab, targets)
    }

    fn block_forward(&self, blk: &BlockWeights, qb: Option<&QuantBlock>, x: &mut Tensor) {
        let (n, e) = x.dims2();
        let h = self.cfg.n_heads;
        let dh = self.cfg.d_head();

        // ln1 -> q/k/v projections
        let mut normed = Tensor::zeros(&[n, e]);
        for i in 0..n {
            layer_norm_into(normed.row_mut(i), x.row(i), &blk.ln1_g.data, &blk.ln1_b.data);
        }
        let q = mm_alloc(&normed, qb.map(|q| &q.wq), &blk.wq);
        let k = mm_alloc(&normed, qb.map(|q| &q.wk), &blk.wk);
        let v = mm_alloc(&normed, qb.map(|q| &q.wv), &blk.wv);

        // per-head attention into `merged`
        let mut merged = Tensor::zeros(&[n, e]);
        let mut qh = vec![0.0f32; n * dh];
        let mut kh = vec![0.0f32; n * dh];
        let mut vh = vec![0.0f32; n * dh];
        let mut oh = vec![0.0f32; n * dh];
        for hd in 0..h {
            let col = hd * dh;
            for i in 0..n {
                qh[i * dh..(i + 1) * dh].copy_from_slice(&q.row(i)[col..col + dh]);
                kh[i * dh..(i + 1) * dh].copy_from_slice(&k.row(i)[col..col + dh]);
                vh[i * dh..(i + 1) * dh].copy_from_slice(&v.row(i)[col..col + dh]);
            }
            match self.kind {
                AttentionKind::Linear => {
                    if self.cfg.causal {
                        linear::forward_causal(&qh, &kh, &vh, n, dh, dh, &mut oh);
                    } else {
                        linear::forward_noncausal(&qh, &kh, &vh, n, dh, dh, &mut oh);
                    }
                }
                AttentionKind::Softmax => {
                    softmax::forward(&qh, &kh, &vh, n, dh, dh, self.cfg.causal, &mut oh);
                }
                AttentionKind::Lsh { .. } => {
                    // Reformer shares QK: hash/attend with q in the key role
                    lsh::forward(
                        &self.lsh_cfg,
                        &self.lsh_rotations,
                        &qh,
                        &qh,
                        &vh,
                        n,
                        dh,
                        dh,
                        self.cfg.causal,
                        &mut oh,
                    );
                }
            }
            for i in 0..n {
                merged.row_mut(i)[col..col + dh].copy_from_slice(&oh[i * dh..(i + 1) * dh]);
            }
        }
        let attn_out = mm_alloc(&merged, qb.map(|q| &q.wo), &blk.wo);
        x.add_assign(&attn_out);

        // ff
        for i in 0..n {
            let mut normed_row = vec![0.0f32; e];
            layer_norm_into(&mut normed_row, x.row(i), &blk.ln2_g.data, &blk.ln2_b.data);
            let ff = self.cfg.d_ff;
            let mut hrow = vec![0.0f32; ff];
            vm_w(&mut hrow, &normed_row, qb.map(|q| &q.ff_w1), &blk.ff_w1, e, ff);
            for (hv, b) in hrow.iter_mut().zip(&blk.ff_b1.data) {
                *hv = gelu(*hv + b);
            }
            let mut orow = vec![0.0f32; e];
            vm_w(&mut orow, &hrow, qb.map(|q| &q.ff_w2), &blk.ff_w2, ff, e);
            let xrow = x.row_mut(i);
            for j in 0..e {
                xrow[j] += orow[j] + blk.ff_b2.data[j];
            }
        }
    }

    // -----------------------------------------------------------------------
    // generation
    // -----------------------------------------------------------------------

    /// Create a decode session for this model's natural backend
    /// (linear -> batched RNN at B=1; softmax -> batched KV cache at
    /// B=1; lsh -> recompute, Reformer has no stateful decode). The
    /// stateful kinds route through the same batched sessions the
    /// serving engine uses, so `generate` is bit-identical to serving —
    /// which is what lets the engine tests use it as an oracle.
    pub fn session(&self) -> DecodeSession<'_> {
        let backend = match self.kind {
            AttentionKind::Linear => {
                let mut batched = self.batched_session(1);
                batched.alloc_row().expect("capacity 1");
                Backend::Linear(batched)
            }
            AttentionKind::Softmax => {
                let mut batched = self.batched_softmax_session(1);
                batched.alloc_row().expect("capacity 1");
                Backend::SoftmaxKv(batched)
            }
            AttentionKind::Lsh { .. } => Backend::Recompute,
        };
        DecodeSession::new(self, backend)
    }

    /// Decode session that reruns the full parallel [`Self::forward`]
    /// every step — O(t²)/token for softmax. This is the naive-softmax
    /// baseline of Tables 4/5 (the benches' "softmax" rows), kept
    /// distinct from the KV-cache backend [`Self::session`] now routes
    /// softmax models through.
    pub fn session_recompute(&self) -> DecodeSession<'_> {
        DecodeSession::new(self, Backend::Recompute)
    }

    /// Create a batched RNN decode session with capacity for `cap` lanes
    /// (linear models only). This is the serving engine's native backend:
    /// one `step_batch` advances every lane by one token through single
    /// `[B, ·]` GEMMs. The session's hot kernels run on the process-wide
    /// worker pool ([`crate::parallel::default_pool`]); results are
    /// bit-identical to the serial kernels under any thread count.
    pub fn batched_session(&self, cap: usize) -> BatchedDecodeSession<'_> {
        BatchedDecodeSession::new(self, cap, crate::parallel::default_pool())
    }

    /// [`Self::batched_session`] with an explicit worker pool (`None`
    /// runs the plain single-threaded kernels with zero dispatch cost).
    pub fn batched_session_with_pool(
        &self,
        cap: usize,
        pool: Option<Arc<ThreadPool>>,
    ) -> BatchedDecodeSession<'_> {
        BatchedDecodeSession::new(self, cap, pool)
    }

    /// Stateful-softmax session (supplementary C.1) — only for softmax models.
    pub fn session_kv(&self) -> DecodeSession<'_> {
        assert_eq!(self.kind, AttentionKind::Softmax);
        DecodeSession::new(self, Backend::KvCache(KvState::new(&self.cfg)))
    }

    /// Create a batched KV-cache decode session with capacity for `cap`
    /// lanes (softmax models only) — the serving engine's softmax
    /// backend, mirroring [`Self::batched_session`] lane-for-lane: one
    /// `step_batch` advances every lane by one token through single
    /// `[B, ·]` GEMMs on the process-wide worker pool; only the
    /// attention core differs (append-and-attend over a growing cache
    /// instead of the O(1) linear state update).
    pub fn batched_softmax_session(&self, cap: usize) -> BatchedSoftmaxSession<'_> {
        BatchedSoftmaxSession::new(self, cap, crate::parallel::default_pool())
    }

    /// [`Self::batched_softmax_session`] with an explicit worker pool
    /// (`None` runs the plain single-threaded kernels with zero
    /// dispatch cost).
    pub fn batched_softmax_session_with_pool(
        &self,
        cap: usize,
        pool: Option<Arc<ThreadPool>>,
    ) -> BatchedSoftmaxSession<'_> {
        BatchedSoftmaxSession::new(self, cap, pool)
    }

    /// Convenience: feed `prompt`, then sample `n_new` tokens.
    pub fn generate(&self, prompt: &[u32], n_new: usize, temperature: f32, seed: u64) -> Vec<u32> {
        let mut sess = self.session();
        let mut rng = Rng::new(seed);
        sess.generate(prompt, n_new, temperature, &mut rng)
    }
}

fn make_lsh_rotations(cfg: &lsh::LshConfig, d: usize) -> Vec<Vec<f32>> {
    lsh::make_rotations(cfg, d)
}

/// Random parameter tensors in the python naming scheme.
pub fn random_param_tensors(cfg: &ModelConfig, rng: &mut Rng) -> Vec<NamedTensor> {
    let e = cfg.d_model;
    let scale_e = 1.0 / (e as f32).sqrt();
    let mut out = vec![
        NamedTensor {
            name: "embed.tok".into(),
            tensor: Tensor::randn(&[cfg.vocab, e], 0.02, rng),
        },
        NamedTensor {
            name: "embed.pos".into(),
            tensor: Tensor::randn(&[cfg.max_len, e], 0.02, rng),
        },
    ];
    for i in 0..cfg.n_layers {
        let p = format!("layer{i}");
        let mut push = |suffix: &str, t: Tensor| {
            out.push(NamedTensor {
                name: format!("{p}.{suffix}"),
                tensor: t,
            })
        };
        push("ln1.g", Tensor::filled(&[e], 1.0));
        push("ln1.b", Tensor::zeros(&[e]));
        push("attn.wq", Tensor::randn(&[e, e], scale_e, rng));
        push("attn.wk", Tensor::randn(&[e, e], scale_e, rng));
        push("attn.wv", Tensor::randn(&[e, e], scale_e, rng));
        push("attn.wo", Tensor::randn(&[e, e], scale_e, rng));
        push("ln2.g", Tensor::filled(&[e], 1.0));
        push("ln2.b", Tensor::zeros(&[e]));
        push("ff.w1", Tensor::randn(&[e, cfg.d_ff], scale_e, rng));
        push("ff.b1", Tensor::zeros(&[cfg.d_ff]));
        push(
            "ff.w2",
            Tensor::randn(&[cfg.d_ff, e], 1.0 / (cfg.d_ff as f32).sqrt(), rng),
        );
        push("ff.b2", Tensor::zeros(&[e]));
    }
    out.push(NamedTensor {
        name: "final_ln.g".into(),
        tensor: Tensor::filled(&[e], 1.0),
    });
    out.push(NamedTensor {
        name: "final_ln.b".into(),
        tensor: Tensor::zeros(&[e]),
    });
    out.push(NamedTensor {
        name: "head.w".into(),
        tensor: Tensor::randn(&[e, cfg.vocab], scale_e, rng),
    });
    out.push(NamedTensor {
        name: "head.b".into(),
        tensor: Tensor::zeros(&[cfg.vocab]),
    });
    out
}

// ---------------------------------------------------------------------------
// decode sessions
// ---------------------------------------------------------------------------

/// How many prompt tokens one prefill pass pushes through the layers at
/// a time. Buffers are sized for this up front, so prompt ingestion runs
/// in constant memory regardless of prompt length (the SLiM trick:
/// blockwise accumulation into the cumulative state).
pub const PREFILL_CHUNK: usize = 64;

/// One decode lane's complete recurrent state, exported as a flat
/// buffer: every layer×head's (S, Z) pair (in layer-major order, the
/// exact f32 bits) plus the lane's position cursor.
///
/// Because the paper's decode state is **fixed-size** (eqs 16-20), this
/// is the *entire* attention memory of everything the lane has consumed
/// — a few hundred KB regardless of how many tokens went in, where a
/// softmax KV cache would grow with length. That is what makes prefix
/// caching nearly free: snapshot a lane after a prompt prefix, key it
/// by the tokens, and any later request sharing that prefix restores
/// the snapshot and skips the prefix's prefill entirely.
///
/// Produced by [`BatchedDecodeSession::export_lane`]; consumed by
/// [`BatchedDecodeSession::import_lane`]. Import is bit-identical to
/// having prefilled the same tokens in place: both paths land the same
/// f32 state bits, and every continuation's float-op order depends only
/// on the state and the inputs — never on how the state got there.
#[derive(Clone, Debug, PartialEq)]
pub struct LaneSnapshot {
    /// Absolute position of the next token the lane would consume
    /// (i.e. how many tokens the snapshot has absorbed).
    pub pos: usize,
    /// Concatenated per-layer×head (S, Z) blocks, in
    /// [`linear::BatchedLinearAttnState::export_row`] layout.
    data: Vec<f32>,
}

impl LaneSnapshot {
    /// Heap bytes this snapshot holds (the cache's budget currency).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

/// Batched autoregressive decode over the linear-attention RNN view.
///
/// Holds every lane's recurrent state in structure-of-arrays layout (one
/// [`linear::BatchedLinearAttnState`] per layer×head, each with `[B, dh,
/// dh]` / `[B, dh]` blocks) plus `[B, ·]` activation buffers, so one
/// [`Self::step_batch`] call advances all live lanes by one token: the
/// embedding gather, QKV/output/FF projections, and the logits head each
/// run as a single `[B, ·] × [·, ·]` GEMM instead of B GEMVs, and the
/// attention update runs as three streaming batched kernels.
///
/// Prompts enter through [`Self::prefill_row`] (one-shot) or
/// [`Self::prefill_row_partial`] (resumable): the prompt is consumed in
/// [`PREFILL_CHUNK`]-sized chunks, each chunk running the projections as
/// `[chunk, ·]` GEMMs and the causal recurrence as one cumulative-state
/// sweep per layer×head — the vocab-sized lm-head runs only for the
/// final prompt position. Time-to-first-token therefore costs
/// O(prompt_len / chunk) GEMM blocks instead of O(prompt_len) engine
/// ticks, and the ingested state is bit-identical to per-tick feeding
/// regardless of how the prompt is sliced across calls. The resumable
/// form plus prefix [`Self::step_batch`] (and [`Self::swap_rows`] for
/// lane ordering) is what lets the serving engine interleave bounded
/// prompt chunks with decode ticks.
///
/// Lanes are dense rows `0..rows`. Slot churn is [`Self::alloc_row`]
/// (append a zeroed lane) and [`Self::free_row`] (swap-remove compaction);
/// both are O(state-per-lane) — possible only because the paper's decode
/// state is a fixed-size matrix pair per lane (eqs 16-20). The same
/// property makes a lane *portable*: [`Self::export_lane`] /
/// [`Self::import_lane`] move one lane's complete state in and out as a
/// flat [`LaneSnapshot`], the substrate of the serving engine's
/// prefix-reuse state cache (restore is bit-identical to having
/// prefilled the snapshot's tokens in place).
pub struct BatchedDecodeSession<'m> {
    model: &'m TransformerLM,
    cap: usize,
    rows: usize,
    /// worker pool for the hot kernels (None = pure serial)
    pool: Option<Arc<ThreadPool>>,
    /// n_layers * n_heads batched states, lane-for-lane in step
    states: Vec<linear::BatchedLinearAttnState>,
    /// absolute position of the next token, per lane
    pos: Vec<usize>,
    // preallocated [cap, ·] activation buffers
    x: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    merged: Vec<f32>,
    out2: Vec<f32>,
    ff: Vec<f32>,
    // per-head gather buffers, [cap, d_head]
    qh: Vec<f32>,
    kh: Vec<f32>,
    vh: Vec<f32>,
    oh: Vec<f32>,
}

impl<'m> BatchedDecodeSession<'m> {
    fn new(model: &'m TransformerLM, cap: usize, pool: Option<Arc<ThreadPool>>) -> Self {
        assert_eq!(
            model.kind,
            AttentionKind::Linear,
            "batched RNN decode requires a linear-attention model"
        );
        assert!(cap >= 1);
        let cfg = &model.cfg;
        let e = cfg.d_model;
        let dh = cfg.d_head();
        // activation buffers serve both the [B, ·] decode tick and the
        // [PREFILL_CHUNK, ·] prefill pass (never concurrently), so size
        // them for whichever is wider
        let buf_rows = cap.max(PREFILL_CHUNK);
        BatchedDecodeSession {
            model,
            cap,
            rows: 0,
            pool,
            states: (0..cfg.n_layers * cfg.n_heads)
                .map(|_| linear::BatchedLinearAttnState::new(cap, dh, dh))
                .collect(),
            pos: Vec::with_capacity(cap),
            x: vec![0.0; buf_rows * e],
            normed: vec![0.0; buf_rows * e],
            q: vec![0.0; buf_rows * e],
            k: vec![0.0; buf_rows * e],
            v: vec![0.0; buf_rows * e],
            merged: vec![0.0; buf_rows * e],
            out2: vec![0.0; buf_rows * e],
            ff: vec![0.0; buf_rows * cfg.d_ff],
            qh: vec![0.0; buf_rows * dh],
            kh: vec![0.0; buf_rows * dh],
            vh: vec![0.0; buf_rows * dh],
            oh: vec![0.0; buf_rows * dh],
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Threads the session's kernels fan out over (1 = serial).
    pub fn pool_threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.threads()).unwrap_or(1)
    }

    /// Live lanes.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Absolute position of the next token lane `row` will consume.
    pub fn pos(&self, row: usize) -> usize {
        self.pos[row]
    }

    pub fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }

    pub fn max_len(&self) -> usize {
        self.model.cfg.max_len
    }

    /// Append a fresh lane (zero state, position 0); `None` at capacity.
    pub fn alloc_row(&mut self) -> Option<usize> {
        if self.rows == self.cap {
            return None;
        }
        for st in &mut self.states {
            // lintra: allow(panic) -- guarded by the rows == cap check above
            st.push_row().expect("states and session agree on capacity");
        }
        self.pos.push(0);
        self.rows += 1;
        Some(self.rows - 1)
    }

    /// Free lane `row`, compacting by moving the last lane into its place.
    /// Returns the moved lane's previous index (`None` if `row` was last).
    pub fn free_row(&mut self, row: usize) -> Option<usize> {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        let mut moved = None;
        for st in &mut self.states {
            moved = st.swap_remove_row(row);
        }
        self.pos.swap_remove(row);
        self.rows -= 1;
        moved
    }

    /// Bytes of recurrent decode state held for the live lanes.
    pub fn state_bytes(&self) -> usize {
        self.states.iter().map(|s| s.state_bytes()).sum()
    }

    /// Advance the first `tokens.len()` live lanes by one token;
    /// `tokens[r]` feeds lane r. Returns logits `[tokens.len() * vocab]`
    /// row-major.
    ///
    /// Allocating convenience form of [`Self::step_batch_into`]; the
    /// serving tick loop passes a reused buffer instead.
    pub fn step_batch(&mut self, tokens: &[u32]) -> Vec<f32> {
        // lintra: allow(alloc) -- compat wrapper; the tick loop uses step_batch_into
        let mut logits = Vec::new();
        self.step_batch_into(tokens, &mut logits);
        logits
    }

    /// Advance the first `tokens.len()` live lanes by one token;
    /// `tokens[r]` feeds lane r. Fills `logits` with `[tokens.len() *
    /// vocab]` row-major values, replacing its previous contents — the
    /// caller keeps one buffer alive across ticks and no per-tick
    /// allocation happens once its capacity has grown to fit.
    ///
    /// Callers may step a *prefix* of the live lanes (`tokens.len() <
    /// rows`): the suffix lanes are left completely untouched. The
    /// serving engine relies on this to keep lanes that are still
    /// mid-prefill out of the decode tick. Each lane's float-op order is
    /// independent of how many lanes step together, so a prefix step is
    /// bit-identical to the same lanes stepping in a narrower session.
    pub fn step_batch_into(&mut self, tokens: &[u32], logits: &mut Vec<f32>) {
        let b = tokens.len();
        assert!(b <= self.rows, "stepping {b} lanes of {} live", self.rows);
        let model = self.model;
        let cfg = &model.cfg;
        let e = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.d_head();
        logits.clear();
        if b == 0 {
            return;
        }
        // B = 1 ticks are GEMV-shaped; the pooled kernels split the
        // *output columns* across workers for that shape (each worker owns
        // a disjoint column range, so there is no reduction to merge and
        // the result is bit-identical to serial — see
        // `crate::tensor::vecmat_into_cols_pooled`). Shapes under the
        // dispatch thresholds still run serially.
        let pool = self.pool.as_deref();
        // x = tok_embed + pos_embed, gathered per lane
        for (r, &tok) in tokens.iter().enumerate() {
            assert!(
                self.pos[r] < cfg.max_len,
                "lane {r} exceeds max_len {}",
                cfg.max_len
            );
            let te = model.tok_embed.row(tok as usize);
            let pe = model.pos_embed.row(self.pos[r]);
            let xr = &mut self.x[r * e..(r + 1) * e];
            for j in 0..e {
                xr[j] = te[j] + pe[j];
            }
        }
        for (li, blk) in model.blocks.iter().enumerate() {
            let qb = model.quant.as_ref().map(|q| &q.blocks[li]);
            // ln1 -> one [B, e] x [e, e] GEMM per projection
            layer_norm_rows_pooled(
                pool,
                &mut self.normed[..b * e],
                &self.x[..b * e],
                &blk.ln1_g.data,
                &blk.ln1_b.data,
                b,
            );
            let normed = &self.normed[..b * e];
            mm_w(pool, &mut self.q[..b * e], normed, qb.map(|q| &q.wq), &blk.wq, b, e, e);
            mm_w(pool, &mut self.k[..b * e], normed, qb.map(|q| &q.wk), &blk.wk, b, e, e);
            mm_w(pool, &mut self.v[..b * e], normed, qb.map(|q| &q.wv), &blk.wv, b, e, e);
            // per head: gather columns, batched RNN update, scatter back
            for hd in 0..h {
                let col = hd * dh;
                gather_cols(&mut self.qh[..b * dh], &self.q[..b * e], b, e, col, dh);
                gather_cols(&mut self.kh[..b * dh], &self.k[..b * e], b, e, col, dh);
                gather_cols(&mut self.vh[..b * dh], &self.v[..b * e], b, e, col, dh);
                self.states[li * h + hd].step_batch_pooled(
                    pool,
                    &self.qh[..b * dh],
                    &self.kh[..b * dh],
                    &self.vh[..b * dh],
                    &mut self.oh[..b * dh],
                );
                scatter_cols(&mut self.merged[..b * e], &self.oh[..b * dh], b, e, col, dh);
            }
            mm_w(
                pool,
                &mut self.out2[..b * e],
                &self.merged[..b * e],
                qb.map(|q| &q.wo),
                &blk.wo,
                b,
                e,
                e,
            );
            for (xv, &ov) in self.x[..b * e].iter_mut().zip(&self.out2[..b * e]) {
                *xv += ov;
            }
            // ff: [B, e] x [e, d_ff] and [B, d_ff] x [d_ff, e] GEMMs
            layer_norm_rows_pooled(
                pool,
                &mut self.normed[..b * e],
                &self.x[..b * e],
                &blk.ln2_g.data,
                &blk.ln2_b.data,
                b,
            );
            let dff = cfg.d_ff;
            mm_w(
                pool,
                &mut self.ff[..b * dff],
                &self.normed[..b * e],
                qb.map(|q| &q.ff_w1),
                &blk.ff_w1,
                b,
                e,
                dff,
            );
            for r in 0..b {
                for (hv, &bv) in self.ff[r * dff..(r + 1) * dff].iter_mut().zip(&blk.ff_b1.data)
                {
                    *hv = gelu(*hv + bv);
                }
            }
            mm_w(
                pool,
                &mut self.out2[..b * e],
                &self.ff[..b * dff],
                qb.map(|q| &q.ff_w2),
                &blk.ff_w2,
                b,
                dff,
                e,
            );
            for (xv, &ov) in self.x[..b * e].iter_mut().zip(&self.out2[..b * e]) {
                *xv += ov;
            }
            add_bias_rows(&mut self.x[..b * e], &blk.ff_b2.data, b);
        }
        // final ln + one [B, e] x [e, vocab] GEMM
        layer_norm_rows_pooled(
            pool,
            &mut self.normed[..b * e],
            &self.x[..b * e],
            &model.final_ln_g.data,
            &model.final_ln_b.data,
            b,
        );
        let vocab = cfg.vocab;
        // cleared above, so resize zero-fills every element — exactly a
        // fresh `vec![0.0; b * vocab]`, and a reused buffer is
        // bit-identical to an allocating call
        logits.resize(b * vocab, 0.0);
        let normed = &self.normed[..b * e];
        mm_w(
            pool,
            &mut logits[..],
            normed,
            model.quant.as_ref().map(|q| &q.head_w),
            &model.head_w,
            b,
            e,
            vocab,
        );
        add_bias_rows(&mut logits[..], &model.head_b.data, b);
        for p in self.pos[..b].iter_mut() {
            *p += 1;
        }
    }

    /// Swap lanes `a` and `b` (every layer×head state pair plus the
    /// position cursors). O(state-per-lane), the same cost as a
    /// [`Self::free_row`] compaction move. The serving engine uses this
    /// to move a lane whose prompt just finished prefilling into the
    /// decoding prefix (see [`Self::step_batch`] on prefix stepping).
    pub fn swap_rows(&mut self, a: usize, b: usize) {
        assert!(a < self.rows && b < self.rows, "swap_rows out of {} live lanes", self.rows);
        if a == b {
            return;
        }
        for st in &mut self.states {
            st.swap_rows(a, b);
        }
        self.pos.swap(a, b);
    }

    /// Bytes of one lane's [`LaneSnapshot`] payload for this model
    /// geometry (constant — independent of how many tokens went in).
    pub fn lane_snapshot_bytes(&self) -> usize {
        self.states.len() * self.states[0].lane_len() * std::mem::size_of::<f32>()
    }

    /// Export lane `row`'s complete recurrent state — every layer×head's
    /// (S, Z) bits plus the position cursor — as a [`LaneSnapshot`]. The
    /// lane itself is untouched; the snapshot is a plain copy, so taking
    /// one costs O(state-per-lane) (the same as a [`Self::free_row`]
    /// compaction move) and nothing else.
    pub fn export_lane(&self, row: usize) -> LaneSnapshot {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        let per = self.states[0].lane_len();
        let mut data = vec![0.0f32; self.states.len() * per];
        for (i, st) in self.states.iter().enumerate() {
            st.export_row(row, &mut data[i * per..(i + 1) * per]);
        }
        LaneSnapshot {
            pos: self.pos[row],
            data,
        }
    }

    /// Overwrite lane `row`'s state and position from a snapshot taken
    /// by [`Self::export_lane`] on a session of the same model geometry.
    ///
    /// After the import the lane is **bit-identical** to having prefilled
    /// the snapshot's tokens in place: restore lands the exact f32 state
    /// bits the prefill path would have produced (same float-op order
    /// guarantee prefill already maintains), so any continuation —
    /// [`Self::prefill_row_partial`] of the remaining suffix, then
    /// decode ticks — produces the exact logits of a cold full prefill.
    /// This is what lets the serving engine skip the shared prefix of a
    /// prompt entirely.
    pub fn import_lane(&mut self, row: usize, snap: &LaneSnapshot) {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        let per = self.states[0].lane_len();
        assert_eq!(
            snap.data.len(),
            self.states.len() * per,
            "snapshot geometry does not match this model"
        );
        assert!(
            snap.pos <= self.model.cfg.max_len,
            "snapshot position {} exceeds max_len {}",
            snap.pos,
            self.model.cfg.max_len
        );
        for (i, st) in self.states.iter_mut().enumerate() {
            st.import_row(row, &snap.data[i * per..(i + 1) * per]);
        }
        self.pos[row] = snap.pos;
    }

    /// Ingest a whole `prompt` into lane `row` in [`PREFILL_CHUNK`]-sized
    /// chunks, returning the logits of the final prompt position
    /// (`[vocab]`) — what the first generated token is sampled from.
    ///
    /// Each chunk runs the QKV/output/FF projections as `[chunk, ·]`
    /// GEMMs and the attention as a cumulative-state sweep into the
    /// lane's (S, Z); intermediate positions never touch the final layer
    /// norm or the vocab-sized lm-head. The float-op order per position
    /// matches [`Self::step_batch`] exactly, so the resulting state and
    /// logits are bit-identical to feeding the prompt one tick at a time.
    ///
    /// This is the one-shot form of [`Self::prefill_row_partial`]; the
    /// resumable form lets a scheduler bound how much prompt enters the
    /// lane per engine tick.
    pub fn prefill_row(&mut self, row: usize, prompt: &[u32]) -> Vec<f32> {
        self.prefill_row_partial(row, prompt, true)
            // lintra: allow(panic) -- contract: finish = true always yields logits
            .expect("finish = true always returns logits")
    }

    /// Resumable prefill: absorb `tokens` — any slice of a prompt — into
    /// lane `row`'s cumulative state, continuing from wherever the lane's
    /// position cursor stands. Pass `finish = false` for interior slices
    /// (the final layer norm and the vocab-sized lm-head are skipped
    /// entirely and `None` is returned); pass `finish = true` with the
    /// last slice to get the final position's logits (`Some([vocab])`).
    ///
    /// The lane state after `prefill_row_partial(row, a, false)` followed
    /// by `prefill_row_partial(row, b, true)` is bit-identical to
    /// `prefill_row(row, a ++ b)` *and* to feeding every token one
    /// [`Self::step_batch`] tick at a time: each position's float-op
    /// order never depends on how the prompt was sliced. The serving
    /// engine leans on this to interleave bounded prompt chunks with
    /// decode ticks without changing a single logit.
    ///
    /// Allocating convenience form of [`Self::prefill_row_partial_into`];
    /// the serving tick loop passes a reused buffer instead.
    pub fn prefill_row_partial(
        &mut self,
        row: usize,
        tokens: &[u32],
        finish: bool,
    ) -> Option<Vec<f32>> {
        // lintra: allow(alloc) -- compat wrapper; the tick loop uses prefill_row_partial_into
        let mut out = Vec::new();
        if self.prefill_row_partial_into(row, tokens, finish, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Buffer-reusing form of [`Self::prefill_row_partial`]: on a
    /// finishing slice, fills `out` with the final position's logits
    /// (`[vocab]`, previous contents replaced) and returns `true`;
    /// interior slices leave `out` cleared and return `false`. Keeping
    /// one `out` buffer alive across chunks makes steady-state prefill
    /// allocation-free; the values written are bit-identical to the
    /// allocating form.
    pub fn prefill_row_partial_into(
        &mut self,
        row: usize,
        tokens: &[u32],
        finish: bool,
        out: &mut Vec<f32>,
    ) -> bool {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        assert!(!tokens.is_empty(), "prefill needs at least one prompt token");
        let model = self.model;
        let cfg = &model.cfg;
        let e = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.d_head();
        let dff = cfg.d_ff;
        assert!(
            self.pos[row] + tokens.len() <= cfg.max_len,
            "lane {row}: prompt of {} at position {} exceeds max_len {}",
            tokens.len(),
            self.pos[row],
            cfg.max_len
        );
        let pool = self.pool.as_deref();
        out.clear();
        let mut wrote = false;
        let mut off = 0;
        while off < tokens.len() {
            let n = (tokens.len() - off).min(PREFILL_CHUNK);
            let chunk = &tokens[off..off + n];
            let base = self.pos[row];
            // x = tok_embed + pos_embed for every chunk position
            for (i, &tok) in chunk.iter().enumerate() {
                let te = model.tok_embed.row(tok as usize);
                let pe = model.pos_embed.row(base + i);
                let xr = &mut self.x[i * e..(i + 1) * e];
                for j in 0..e {
                    xr[j] = te[j] + pe[j];
                }
            }
            for (li, blk) in model.blocks.iter().enumerate() {
                // ln1 -> one [chunk, e] x [e, e] GEMM per projection
                layer_norm_rows_pooled(
                    pool,
                    &mut self.normed[..n * e],
                    &self.x[..n * e],
                    &blk.ln1_g.data,
                    &blk.ln1_b.data,
                    n,
                );
                let qb = model.quant.as_ref().map(|q| &q.blocks[li]);
                let normed = &self.normed[..n * e];
                mm_w(pool, &mut self.q[..n * e], normed, qb.map(|q| &q.wq), &blk.wq, n, e, e);
                mm_w(pool, &mut self.k[..n * e], normed, qb.map(|q| &q.wk), &blk.wk, n, e, e);
                mm_w(pool, &mut self.v[..n * e], normed, qb.map(|q| &q.wv), &blk.wv, n, e, e);
                // per head: the chunk flows through the causal recurrence
                // of this lane only; other lanes' states are untouched
                for hd in 0..h {
                    let col = hd * dh;
                    gather_cols(&mut self.qh[..n * dh], &self.q[..n * e], n, e, col, dh);
                    gather_cols(&mut self.kh[..n * dh], &self.k[..n * e], n, e, col, dh);
                    gather_cols(&mut self.vh[..n * dh], &self.v[..n * e], n, e, col, dh);
                    self.states[li * h + hd].prefill_row(
                        row,
                        &self.qh[..n * dh],
                        &self.kh[..n * dh],
                        &self.vh[..n * dh],
                        n,
                        &mut self.oh[..n * dh],
                    );
                    scatter_cols(&mut self.merged[..n * e], &self.oh[..n * dh], n, e, col, dh);
                }
                let merged = &self.merged[..n * e];
                mm_w(pool, &mut self.out2[..n * e], merged, qb.map(|q| &q.wo), &blk.wo, n, e, e);
                for (xv, &ov) in self.x[..n * e].iter_mut().zip(&self.out2[..n * e]) {
                    *xv += ov;
                }
                // ff: [chunk, e] x [e, d_ff] and [chunk, d_ff] x [d_ff, e]
                layer_norm_rows_pooled(
                    pool,
                    &mut self.normed[..n * e],
                    &self.x[..n * e],
                    &blk.ln2_g.data,
                    &blk.ln2_b.data,
                    n,
                );
                mm_w(
                    pool,
                    &mut self.ff[..n * dff],
                    &self.normed[..n * e],
                    qb.map(|q| &q.ff_w1),
                    &blk.ff_w1,
                    n,
                    e,
                    dff,
                );
                for r in 0..n {
                    let frow = &mut self.ff[r * dff..(r + 1) * dff];
                    for (hv, &bv) in frow.iter_mut().zip(&blk.ff_b1.data) {
                        *hv = gelu(*hv + bv);
                    }
                }
                mm_w(
                    pool,
                    &mut self.out2[..n * e],
                    &self.ff[..n * dff],
                    qb.map(|q| &q.ff_w2),
                    &blk.ff_w2,
                    n,
                    dff,
                    e,
                );
                for (xv, &ov) in self.x[..n * e].iter_mut().zip(&self.out2[..n * e]) {
                    *xv += ov;
                }
                add_bias_rows(&mut self.x[..n * e], &blk.ff_b2.data, n);
            }
            self.pos[row] += n;
            off += n;
            if finish && off == tokens.len() {
                // only the last prompt position pays for the final layer
                // norm and the [e, vocab] lm-head
                let last = n - 1;
                layer_norm_into(
                    &mut self.normed[..e],
                    &self.x[last * e..(last + 1) * e],
                    &model.final_ln_g.data,
                    &model.final_ln_b.data,
                );
                // cleared on entry, so resize zero-fills — exactly a
                // fresh `vec![0.0; vocab]` for the reused buffer too
                out.resize(cfg.vocab, 0.0);
                vm_w_pooled(
                    pool,
                    &mut out[..],
                    &self.normed[..e],
                    model.quant.as_ref().map(|q| &q.head_w),
                    &model.head_w,
                    e,
                    cfg.vocab,
                );
                for (l, bv) in out.iter_mut().zip(&model.head_b.data) {
                    *l += bv;
                }
                wrote = true;
            }
        }
        wrote
    }
}

/// Per-layer, per-head KV caches.
#[derive(Clone, Debug)]
pub struct KvState {
    caches: Vec<stateful_softmax::KvCache>,
}

impl KvState {
    fn new(cfg: &ModelConfig) -> Self {
        let dh = cfg.d_head();
        KvState {
            caches: (0..cfg.n_layers * cfg.n_heads)
                .map(|_| stateful_softmax::KvCache::new(dh, dh, cfg.max_len))
                .collect(),
        }
    }

    pub fn state_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.state_bytes()).sum()
    }
}

enum Backend<'m> {
    /// O(1)/token — the paper's contribution, as the B=1 case of the
    /// batched RNN decode path (one code path for serving and sessions).
    Linear(BatchedDecodeSession<'m>),
    /// O(t)/token — stateful softmax as the B=1 case of the batched
    /// KV-cache serving path (same machinery the engine decodes with).
    SoftmaxKv(BatchedSoftmaxSession<'m>),
    /// O(t)/token — stateful softmax (supplementary C.1), serial
    /// per-row projections; the scalar reference the batched KV path is
    /// tested against.
    KvCache(KvState),
    /// O(t²)/token — rerun the full forward each step (vanilla softmax /
    /// lsh decode; Reformer has no stateful decode).
    Recompute,
}

/// A generation session over a model.
pub struct DecodeSession<'m> {
    model: &'m TransformerLM,
    backend: Backend<'m>,
    /// Tokens consumed so far (needed by the recompute backend and for
    /// position indexing everywhere).
    pub history: Vec<u32>,
    // preallocated per-step buffers
    xbuf: Vec<f32>,
    normed: Vec<f32>,
    qrow: Vec<f32>,
    krow: Vec<f32>,
    vrow: Vec<f32>,
    orow: Vec<f32>,
    ffrow: Vec<f32>,
    out2: Vec<f32>,
}

impl<'m> DecodeSession<'m> {
    fn new(model: &'m TransformerLM, backend: Backend<'m>) -> Self {
        let e = model.cfg.d_model;
        DecodeSession {
            model,
            backend,
            history: Vec::new(),
            xbuf: vec![0.0; e],
            normed: vec![0.0; e],
            qrow: vec![0.0; e],
            krow: vec![0.0; e],
            vrow: vec![0.0; e],
            orow: vec![0.0; e],
            ffrow: vec![0.0; model.cfg.d_ff],
            out2: vec![0.0; e],
        }
    }

    /// Bytes of decode state held right now (Table 4's memory story).
    pub fn state_bytes(&self) -> usize {
        match &self.backend {
            Backend::Linear(s) => s.state_bytes(),
            Backend::SoftmaxKv(s) => s.state_bytes(),
            Backend::KvCache(c) => c.state_bytes(),
            Backend::Recompute => self.history.len() * 4,
        }
    }

    /// Feed one token; returns logits for the *next* position.
    pub fn step(&mut self, token: u32) -> Vec<f32> {
        let pos = self.history.len();
        assert!(
            pos < self.model.cfg.max_len,
            "sequence exceeds max_len {}",
            self.model.cfg.max_len
        );
        self.history.push(token);
        match &mut self.backend {
            Backend::Recompute => {
                let logits = self.model.forward(&self.history);
                let (n, v) = logits.dims2();
                logits.data[(n - 1) * v..].to_vec()
            }
            Backend::Linear(batched) => batched.step_batch(&[token]),
            Backend::SoftmaxKv(batched) => batched.step_batch(&[token]),
            Backend::KvCache(_) => self.step_incremental(token, pos),
        }
    }

    fn step_incremental(&mut self, token: u32, pos: usize) -> Vec<f32> {
        let cfg = &self.model.cfg;
        let e = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.d_head();
        // x = tok_embed + pos_embed
        let te = self.model.tok_embed.row(token as usize);
        let pe = self.model.pos_embed.row(pos);
        for j in 0..e {
            self.xbuf[j] = te[j] + pe[j];
        }
        for (li, blk) in self.model.blocks.iter().enumerate() {
            let qb = self.model.quant.as_ref().map(|q| &q.blocks[li]);
            layer_norm_into(&mut self.normed, &self.xbuf, &blk.ln1_g.data, &blk.ln1_b.data);
            vm_w(&mut self.qrow, &self.normed, qb.map(|q| &q.wq), &blk.wq, e, e);
            vm_w(&mut self.krow, &self.normed, qb.map(|q| &q.wk), &blk.wk, e, e);
            vm_w(&mut self.vrow, &self.normed, qb.map(|q| &q.wv), &blk.wv, e, e);
            for hd in 0..h {
                let col = hd * dh;
                let q = &self.qrow[col..col + dh];
                let k = &self.krow[col..col + dh];
                let v = &self.vrow[col..col + dh];
                let o = &mut self.orow[col..col + dh];
                match &mut self.backend {
                    Backend::KvCache(st) => st.caches[li * h + hd].step(q, k, v, o),
                    // linear and batched-KV decode go through their
                    // batched sessions' step_batch
                    Backend::Linear(_) | Backend::SoftmaxKv(_) | Backend::Recompute => {
                        unreachable!()
                    }
                }
            }
            vm_w(&mut self.out2, &self.orow, qb.map(|q| &q.wo), &blk.wo, e, e);
            for j in 0..e {
                self.xbuf[j] += self.out2[j];
            }
            // ff
            layer_norm_into(&mut self.normed, &self.xbuf, &blk.ln2_g.data, &blk.ln2_b.data);
            vm_w(&mut self.ffrow, &self.normed, qb.map(|q| &q.ff_w1), &blk.ff_w1, e, cfg.d_ff);
            for (hv, b) in self.ffrow.iter_mut().zip(&blk.ff_b1.data) {
                *hv = gelu(*hv + b);
            }
            vm_w(&mut self.out2, &self.ffrow, qb.map(|q| &q.ff_w2), &blk.ff_w2, cfg.d_ff, e);
            for j in 0..e {
                self.xbuf[j] += self.out2[j] + blk.ff_b2.data[j];
            }
        }
        layer_norm_into(
            &mut self.normed,
            &self.xbuf,
            &self.model.final_ln_g.data,
            &self.model.final_ln_b.data,
        );
        let vsize = cfg.vocab;
        let mut logits = vec![0.0f32; vsize];
        vm_w(
            &mut logits,
            &self.normed,
            self.model.quant.as_ref().map(|q| &q.head_w),
            &self.model.head_w,
            e,
            vsize,
        );
        for (l, b) in logits.iter_mut().zip(&self.model.head_b.data) {
            *l += b;
        }
        logits
    }

    /// Feed a prompt and sample `n_new` continuation tokens.
    pub fn generate(
        &mut self,
        prompt: &[u32],
        n_new: usize,
        temperature: f32,
        rng: &mut Rng,
    ) -> Vec<u32> {
        assert!(!prompt.is_empty(), "prompt must contain at least one token");
        let mut logits = Vec::new();
        for &t in prompt {
            logits = self.step(t);
        }
        let mut out = Vec::with_capacity(n_new);
        let max_len = self.model.cfg.max_len;
        for _ in 0..n_new {
            if self.history.len() >= max_len {
                break; // no position left for another token
            }
            let next = crate::sampling::sample_logits(&logits, temperature, rng);
            out.push(next);
            if self.history.len() + 1 >= max_len {
                break;
            }
            logits = self.step(next);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            vocab: 11,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            max_len: 32,
            d_ff: 64,
            chunk: 16,
            causal: true,
            lsh_rounds: 1,
            lsh_buckets: 8,
            lsh_chunk: 8,
        }
    }

    fn tokens(n: usize, vocab: usize, seed: u64) -> Vec<u32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(vocab as u64) as u32).collect()
    }

    #[test]
    fn forward_shapes_all_kinds() {
        let cfg = tiny_cfg();
        for kind in [
            AttentionKind::Linear,
            AttentionKind::Softmax,
            AttentionKind::Lsh { rounds: 2 },
        ] {
            let m = TransformerLM::init(&cfg, kind, 0);
            let t = tokens(16, cfg.vocab, 1);
            let logits = m.forward(&t);
            assert_eq!(logits.shape, vec![16, 11]);
            assert!(logits.data.iter().all(|x| x.is_finite()), "{kind:?}");
        }
    }

    #[test]
    fn linear_rnn_decode_matches_forward() {
        // "Transformers are RNNs" at the full-model level, native path
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 2);
        let t = tokens(20, cfg.vocab, 3);
        let full = m.forward(&t);
        let mut sess = m.session();
        for (i, &tok) in t.iter().enumerate() {
            let logits = sess.step(tok);
            for (a, b) in logits.iter().zip(full.row(i)) {
                assert!((a - b).abs() < 2e-3, "divergence at position {i}");
            }
        }
    }

    #[test]
    fn batched_decode_matches_forward_per_lane() {
        // three lanes with different token streams, one step_batch per tick
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 21);
        let streams: Vec<Vec<u32>> =
            (0..3).map(|s| tokens(12, cfg.vocab, 100 + s as u64)).collect();
        let fulls: Vec<Tensor> = streams.iter().map(|t| m.forward(t)).collect();
        let mut sess = m.batched_session(3);
        for _ in 0..3 {
            sess.alloc_row().unwrap();
        }
        for i in 0..12 {
            let tick: Vec<u32> = streams.iter().map(|t| t[i]).collect();
            let logits = sess.step_batch(&tick);
            for (lane, full) in fulls.iter().enumerate() {
                for (a, b) in logits[lane * cfg.vocab..(lane + 1) * cfg.vocab]
                    .iter()
                    .zip(full.row(i))
                {
                    assert!((a - b).abs() < 2e-3, "lane {lane} diverged at position {i}");
                }
            }
        }
    }

    #[test]
    fn batched_decode_survives_slot_churn() {
        // lane joins late, another finishes early and is compacted away
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 22);
        let s0 = tokens(10, cfg.vocab, 200);
        let s1 = tokens(4, cfg.vocab, 201);
        let s2 = tokens(6, cfg.vocab, 202);
        let f0 = m.forward(&s0);
        let f2 = m.forward(&s2);
        let mut sess = m.batched_session(3);
        sess.alloc_row().unwrap(); // lane 0 <- s0
        sess.alloc_row().unwrap(); // lane 1 <- s1
        // ticks 0..4: both s0 and s1 active
        for i in 0..4 {
            let logits = sess.step_batch(&[s0[i], s1[i]]);
            for (a, b) in logits[..cfg.vocab].iter().zip(f0.row(i)) {
                assert!((a - b).abs() < 2e-3, "s0 diverged at {i}");
            }
        }
        // s1 finishes: free lane 1 (it was last, nothing moves)
        assert_eq!(sess.free_row(1), None);
        // s2 joins at tick 4 in a fresh lane
        assert_eq!(sess.alloc_row(), Some(1));
        for i in 0..6 {
            let logits = sess.step_batch(&[s0[4 + i], s2[i]]);
            for (a, b) in logits[..cfg.vocab].iter().zip(f0.row(4 + i)) {
                assert!((a - b).abs() < 2e-3, "s0 diverged at {} after churn", 4 + i);
            }
            for (a, b) in logits[cfg.vocab..].iter().zip(f2.row(i)) {
                assert!((a - b).abs() < 2e-3, "late-joining s2 diverged at {i}");
            }
        }
        // s0 finishes first now: freeing lane 0 moves lane 1 (s2) into row 0
        assert_eq!(sess.free_row(0), Some(1));
        assert_eq!(sess.rows(), 1);
        assert_eq!(sess.pos(0), 6, "moved lane kept its position");
    }

    #[test]
    fn prefill_row_is_bitwise_token_by_token_across_chunks() {
        // a prompt longer than PREFILL_CHUNK (to cross chunk boundaries)
        // must produce the exact logits and greedy continuation of
        // feeding the same tokens one tick at a time
        let cfg = ModelConfig {
            max_len: 192,
            ..tiny_cfg()
        };
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 30);
        let prompt = tokens(PREFILL_CHUNK * 2 + 2, cfg.vocab, 31);
        let mut stepped = m.batched_session(1);
        stepped.alloc_row().unwrap();
        let mut step_logits = Vec::new();
        for &t in &prompt {
            step_logits = stepped.step_batch(&[t]);
        }
        let mut prefilled = m.batched_session(1);
        prefilled.alloc_row().unwrap();
        let pre_logits = prefilled.prefill_row(0, &prompt);
        assert_eq!(pre_logits, step_logits, "prefill logits must be bit-identical");
        assert_eq!(prefilled.pos(0), stepped.pos(0));
        // greedy continuations stay in lockstep
        let mut a = crate::sampling::argmax(&pre_logits);
        let mut b = crate::sampling::argmax(&step_logits);
        for i in 0..8 {
            assert_eq!(a, b, "greedy continuation diverged at step {i}");
            let la = prefilled.step_batch(&[a]);
            let lb = stepped.step_batch(&[b]);
            assert_eq!(la, lb);
            a = crate::sampling::argmax(&la);
            b = crate::sampling::argmax(&lb);
        }
    }

    #[test]
    fn partial_prefill_is_bitwise_one_shot_regardless_of_slicing() {
        // the same prompt sliced three different ways — one-shot, aligned
        // 64-token chunks, ragged slices that straddle chunk boundaries —
        // must land on identical logits and identical greedy continuations
        let cfg = ModelConfig {
            max_len: 256,
            ..tiny_cfg()
        };
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 40);
        let prompt = tokens(PREFILL_CHUNK * 2 + 17, cfg.vocab, 41);
        let mut one_shot = m.batched_session(1);
        one_shot.alloc_row().unwrap();
        let expect = one_shot.prefill_row(0, &prompt);

        for splits in [
            vec![PREFILL_CHUNK, PREFILL_CHUNK, 17], // the engine's schedule
            vec![5, PREFILL_CHUNK, PREFILL_CHUNK + 12], // ragged, straddling
            vec![1, prompt.len() - 1],
        ] {
            assert_eq!(splits.iter().sum::<usize>(), prompt.len());
            let mut sess = m.batched_session(1);
            sess.alloc_row().unwrap();
            let mut off = 0;
            let mut logits = None;
            for (i, &n) in splits.iter().enumerate() {
                let last = i == splits.len() - 1;
                let got = sess.prefill_row_partial(0, &prompt[off..off + n], last);
                assert_eq!(got.is_some(), last, "logits only on the finishing slice");
                logits = got;
                off += n;
            }
            assert_eq!(
                logits.as_deref(),
                Some(&expect[..]),
                "slicing {splits:?} changed the prefill logits"
            );
            assert_eq!(sess.pos(0), one_shot.pos(0));
            // greedy continuation stays in lockstep too
            let mut a = crate::sampling::argmax(&expect);
            let mut b = a;
            for _ in 0..4 {
                let la = one_shot.step_batch(&[a]);
                let lb = sess.step_batch(&[b]);
                assert_eq!(la, lb, "continuation diverged after sliced prefill");
                a = crate::sampling::argmax(&la);
                b = crate::sampling::argmax(&lb);
            }
            // reset the one-shot session for the next slicing
            one_shot.free_row(0);
            one_shot.alloc_row().unwrap();
            one_shot.prefill_row(0, &prompt);
        }
    }

    #[test]
    fn export_import_lane_is_bitwise_equivalent_to_prefilling_in_place() {
        // prefill a shared prefix, snapshot it, restore into a fresh
        // session, finish with the suffix: logits, positions, and the
        // greedy continuation must be bit-identical to one cold prefill
        // of prefix ++ suffix
        let cfg = ModelConfig {
            max_len: 192,
            ..tiny_cfg()
        };
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 60);
        let prefix = tokens(PREFILL_CHUNK * 2, cfg.vocab, 61);
        let suffix = tokens(23, cfg.vocab, 62);
        let full: Vec<u32> = prefix.iter().chain(&suffix).copied().collect();

        let mut cold = m.batched_session(1);
        cold.alloc_row().unwrap();
        let cold_logits = cold.prefill_row(0, &full);

        // donor session ingests only the prefix and exports its lane
        let mut donor = m.batched_session(2);
        donor.alloc_row().unwrap();
        donor.alloc_row().unwrap();
        assert!(donor.prefill_row_partial(1, &prefix, false).is_none());
        let snap = donor.export_lane(1);
        assert_eq!(snap.pos, prefix.len());
        assert_eq!(snap.bytes(), donor.lane_snapshot_bytes());

        // warm session: restore the snapshot, ingest only the suffix
        let mut warm = m.batched_session(1);
        warm.alloc_row().unwrap();
        // dirty the lane first: import must fully overwrite
        warm.prefill_row_partial(0, &tokens(5, cfg.vocab, 63), false);
        warm.import_lane(0, &snap);
        assert_eq!(warm.pos(0), prefix.len());
        let warm_logits = warm
            .prefill_row_partial(0, &suffix, true)
            .expect("finishing slice returns logits");
        assert_eq!(
            warm_logits, cold_logits,
            "restored-prefix prefill must be bit-identical to a cold full prefill"
        );
        assert_eq!(warm.pos(0), cold.pos(0));
        // greedy continuations stay in bitwise lockstep
        let mut a = crate::sampling::argmax(&cold_logits);
        let mut b = a;
        for i in 0..6 {
            let la = cold.step_batch(&[a]);
            let lb = warm.step_batch(&[b]);
            assert_eq!(la, lb, "continuation diverged at step {i} after restore");
            a = crate::sampling::argmax(&la);
            b = crate::sampling::argmax(&lb);
        }
        // the donor lane is untouched by the export
        let snap2 = donor.export_lane(1);
        assert_eq!(snap, snap2, "export must not mutate the source lane");
    }

    #[test]
    #[should_panic(expected = "snapshot geometry")]
    fn import_lane_rejects_wrong_geometry() {
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 64);
        let wide = TransformerLM::init(
            &ModelConfig {
                d_model: 64,
                ..tiny_cfg()
            },
            AttentionKind::Linear,
            65,
        );
        let mut a = m.batched_session(1);
        a.alloc_row().unwrap();
        let snap = a.export_lane(0);
        let mut b = wide.batched_session(1);
        b.alloc_row().unwrap();
        b.import_lane(0, &snap);
    }

    #[test]
    fn prefix_step_with_swap_matches_dedicated_sessions() {
        // lane 1 prefills over two partial calls while lane 0 keeps
        // decoding via prefix steps; after swap_rows moves lane 1 into
        // the prefix, both match single-lane references bitwise
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 50);
        let s0 = tokens(4, cfg.vocab, 51);
        let s1 = tokens(9, cfg.vocab, 52);
        let mut sess = m.batched_session(2);
        sess.alloc_row().unwrap();
        let mut ref0 = m.batched_session(1);
        ref0.alloc_row().unwrap();
        let mut ref1 = m.batched_session(1);
        ref1.alloc_row().unwrap();
        // lane 0 ingests its prompt and decodes two tokens
        let mut l0 = sess.prefill_row(0, &s0);
        assert_eq!(l0, ref0.prefill_row(0, &s0));
        // lane 1 joins and prefills incrementally while lane 0 prefix-steps
        sess.alloc_row().unwrap();
        assert!(sess.prefill_row_partial(1, &s1[..5], false).is_none());
        let mut t0 = crate::sampling::argmax(&l0);
        l0 = sess.step_batch(&[t0]); // prefix step: lane 1 untouched
        assert_eq!(l0, ref0.step_batch(&[t0]));
        let l1 = sess.prefill_row_partial(1, &s1[5..], true).expect("finishing slice");
        let mut expect1 = Vec::new();
        for &t in &s1 {
            expect1 = ref1.step_batch(&[t]);
        }
        assert_eq!(l1, expect1, "interleaved partial prefill diverged");
        // move the freshly prefilled lane into the decode prefix: the
        // engine swaps it with the first prefilling lane (here: itself),
        // but exercise a real swap by putting it at row 0 instead
        sess.swap_rows(0, 1);
        let mut t1 = crate::sampling::argmax(&l1);
        t0 = crate::sampling::argmax(&l0);
        for _ in 0..5 {
            let both = sess.step_batch(&[t1, t0]); // row 0 = stream 1 now
            let a = ref1.step_batch(&[t1]);
            let b = ref0.step_batch(&[t0]);
            assert_eq!(&both[..cfg.vocab], &a[..], "swapped-in lane diverged");
            assert_eq!(&both[cfg.vocab..], &b[..], "swapped-out lane diverged");
            t1 = crate::sampling::argmax(&a);
            t0 = crate::sampling::argmax(&b);
        }
    }

    #[test]
    fn prefill_row_joins_mid_batch_without_disturbing_neighbours() {
        // lane 0 is mid-decode when lane 1 is admitted by prefill; both
        // must match independent single-lane references bit-for-bit
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 32);
        let s0 = tokens(12, cfg.vocab, 33);
        let s1 = tokens(7, cfg.vocab, 34);
        let mut sess = m.batched_session(2);
        sess.alloc_row().unwrap();
        let mut ref0 = m.batched_session(1);
        ref0.alloc_row().unwrap();
        let mut ref1 = m.batched_session(1);
        ref1.alloc_row().unwrap();
        // lane 0 consumes 6 tokens alone
        for &t in &s0[..6] {
            let a = sess.step_batch(&[t]);
            let b = ref0.step_batch(&[t]);
            assert_eq!(a, b);
        }
        // lane 1 joins via prefill
        sess.alloc_row().unwrap();
        let got = sess.prefill_row(1, &s1);
        let mut expect = Vec::new();
        for &t in &s1 {
            expect = ref1.step_batch(&[t]);
        }
        assert_eq!(got, expect, "prefill in an occupied batch diverged");
        // both lanes keep decoding in lockstep with their references
        for i in 0..6 {
            let tick = [s0[6 + i], crate::sampling::argmax(&expect)];
            let both = sess.step_batch(&tick);
            let a = ref0.step_batch(&[tick[0]]);
            let b = ref1.step_batch(&[tick[1]]);
            assert_eq!(&both[..cfg.vocab], &a[..], "lane 0 disturbed by prefill");
            assert_eq!(&both[cfg.vocab..], &b[..], "prefilled lane diverged in decode");
            expect = b;
        }
    }

    #[test]
    fn single_slot_session_is_thin_wrapper_over_batched() {
        // DecodeSession (linear) and a 1-lane batched session must agree bitwise
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 23);
        let t = tokens(10, cfg.vocab, 300);
        let mut single = m.session();
        let mut batched = m.batched_session(1);
        batched.alloc_row().unwrap();
        for &tok in &t {
            let a = single.step(tok);
            let b = batched.step_batch(&[tok]);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn kv_decode_matches_softmax_forward() {
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Softmax, 4);
        let t = tokens(18, cfg.vocab, 5);
        let full = m.forward(&t);
        let mut sess = m.session_kv();
        for (i, &tok) in t.iter().enumerate() {
            let logits = sess.step(tok);
            for (a, b) in logits.iter().zip(full.row(i)) {
                assert!((a - b).abs() < 2e-3, "divergence at position {i}");
            }
        }
    }

    #[test]
    fn recompute_decode_matches_forward() {
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Softmax, 6);
        let t = tokens(10, cfg.vocab, 7);
        let full = m.forward(&t);
        let mut sess = m.session_recompute();
        for (i, &tok) in t.iter().enumerate() {
            let logits = sess.step(tok);
            for (a, b) in logits.iter().zip(full.row(i)) {
                assert!((a - b).abs() < 1e-4, "divergence at position {i}");
            }
        }
    }

    #[test]
    fn softmax_session_is_thin_wrapper_over_batched_kv() {
        // DecodeSession (softmax) and a 1-lane batched KV session must
        // agree bitwise — session()/generate() is the engine tests'
        // oracle for the softmax backend, so it must route through the
        // same batched machinery the engine serves with
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Softmax, 23);
        let t = tokens(10, cfg.vocab, 300);
        let mut single = m.session();
        let mut batched = m.batched_softmax_session(1);
        batched.alloc_row().unwrap();
        for &tok in &t {
            let a = single.step(tok);
            let b = batched.step_batch(&[tok]);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn linear_state_constant_kv_state_grows() {
        let cfg = tiny_cfg();
        let lin = TransformerLM::init(&cfg, AttentionKind::Linear, 8);
        let sm = TransformerLM::init(&cfg, AttentionKind::Softmax, 8);
        let mut s1 = lin.session();
        let mut s2 = sm.session_kv();
        let t = tokens(16, cfg.vocab, 9);
        s1.step(t[0]);
        s2.step(t[0]);
        let lin0 = s1.state_bytes();
        let kv0 = s2.state_bytes();
        for &tok in &t[1..] {
            s1.step(tok);
            s2.step(tok);
        }
        assert_eq!(s1.state_bytes(), lin0, "linear state must stay constant");
        assert!(s2.state_bytes() > kv0, "kv state must grow");
    }

    #[test]
    fn generation_stays_in_vocab_and_respects_max_len() {
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 10);
        let out = m.generate(&[1, 2, 3], 64, 1.0, 11);
        assert!(out.len() <= cfg.max_len - 3);
        assert!(out.iter().all(|&t| (t as usize) < cfg.vocab));
    }

    #[test]
    fn greedy_generation_is_deterministic() {
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 12);
        let a = m.generate(&[1, 2], 10, 0.0, 1);
        let b = m.generate(&[1, 2], 10, 0.0, 2); // different seed, greedy
        assert_eq!(a, b);
    }

    #[test]
    fn bundle_roundtrip_preserves_forward() {
        let cfg = tiny_cfg();
        let m = TransformerLM::init(&cfg, AttentionKind::Linear, 13);
        let mut rng = Rng::new(13);
        let tensors = random_param_tensors(&cfg, &mut rng);
        let bundle = WeightBundle::new(tensors);
        let dir = std::env::temp_dir().join(format!("nn_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ltw");
        bundle.save(&path).unwrap();
        let loaded = WeightBundle::load(&path).unwrap();
        let m2 = TransformerLM::from_bundle(&cfg, AttentionKind::Linear, &loaded).unwrap();
        let t = tokens(8, cfg.vocab, 14);
        // same weights => identical logits (m uses an independent init
        // stream, so compare m2 against a third model from same bundle)
        let m3 = TransformerLM::from_bundle(&cfg, AttentionKind::Linear, &loaded).unwrap();
        assert_eq!(m2.forward(&t), m3.forward(&t));
        let _ = m;
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_param_is_error() {
        let cfg = tiny_cfg();
        let bundle = WeightBundle::new(vec![]);
        assert!(TransformerLM::from_bundle(&cfg, AttentionKind::Linear, &bundle).is_err());
    }

    #[test]
    fn quantized_param_selects_gemv_shaped_weights() {
        for name in [
            "layer0.attn.wq",
            "layer7.attn.wk",
            "layer0.attn.wv",
            "layer12.attn.wo",
            "layer3.ff.w1",
            "layer3.ff.w2",
            "head.w",
        ] {
            assert!(quantized_param(name), "{name} should quantize");
        }
        for name in [
            "embed.tok",
            "embed.pos",
            "layer0.ln1.g",
            "layer0.ln1.b",
            "layer0.ln2.g",
            "layer3.ff.b1",
            "layer3.ff.b2",
            "final_ln.g",
            "final_ln.b",
            "head.b",
        ] {
            assert!(!quantized_param(name), "{name} should stay f32");
        }
    }

    #[test]
    fn cast_weights_builds_and_clears_the_sidecar() {
        let cfg = tiny_cfg();
        let mut m = TransformerLM::init(&cfg, AttentionKind::Linear, 3);
        // normalize away any ambient LINTRA_WEIGHT_DTYPE first
        m.cast_weights(WeightDtype::F32);
        assert_eq!(m.weight_dtype(), WeightDtype::F32);
        let f32_bytes = m.weight_bytes_per_token();
        m.cast_weights(WeightDtype::F16);
        assert_eq!(m.weight_dtype(), WeightDtype::F16);
        assert_eq!(m.weight_bytes_per_token() * 2, f32_bytes);
        // re-casting from the retained f32 source is idempotent
        let once = m.clone();
        m.cast_weights(WeightDtype::F16);
        let t = tokens(8, cfg.vocab, 1);
        assert_eq!(m.forward(&t).data, once.forward(&t).data);
        // back to f32 restores the bitwise-reference path
        m.cast_weights(WeightDtype::F32);
        assert_eq!(m.weight_dtype(), WeightDtype::F32);
        assert_eq!(m.weight_bytes_per_token(), f32_bytes);
    }

    #[test]
    fn f16_cast_keeps_forward_logits_within_contract() {
        let cfg = tiny_cfg();
        let mut m = TransformerLM::init(&cfg, AttentionKind::Linear, 5);
        m.cast_weights(WeightDtype::F32);
        let t = tokens(12, cfg.vocab, 2);
        let reference = m.forward(&t);
        m.cast_weights(WeightDtype::F16);
        let quantized = m.forward(&t);
        for (i, (g, w)) in quantized.data.iter().zip(&reference.data).enumerate() {
            crate::propcheck::assert_close_ulp(
                *g,
                *w,
                0,
                5e-2,
                5e-2,
                &format!("f16 forward logit {i}"),
            );
        }
    }
}
