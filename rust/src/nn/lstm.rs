//! Bi-LSTM baseline for the speech experiment (§4.3, Table 3).
//!
//! Matches `python/compile/models_speech.py::lstm_forward`: per layer one
//! forward and one backward LSTM whose outputs are concatenated; a linear
//! head produces log-softmax phoneme posteriors. Weights come from the
//! `speech_bilstm_*.ltw` bundles (gate order i, f, g, o as in the jax code).

use crate::tensor::{vecmat_into, Tensor};
use crate::weights::WeightBundle;

/// One direction's weights.
#[derive(Clone, Debug)]
struct LstmDir {
    wx: Tensor, // [d_in, 4h]
    wh: Tensor, // [h, 4h]
    b: Tensor,  // [4h]
}

/// The Bi-LSTM CTC encoder.
#[derive(Clone, Debug)]
pub struct BiLstm {
    pub n_mels: usize,
    pub hidden: usize,
    pub n_layers: usize,
    pub vocab: usize,
    layers: Vec<(LstmDir, LstmDir)>,
    head_w: Tensor,
    head_b: Tensor,
}

impl BiLstm {
    pub fn from_bundle(
        n_mels: usize,
        hidden: usize,
        n_layers: usize,
        vocab: usize,
        bundle: &WeightBundle,
    ) -> anyhow::Result<Self> {
        let t = |name: &str| -> anyhow::Result<Tensor> {
            bundle
                .get(name)
                .cloned()
                .ok_or_else(|| anyhow::anyhow!("bundle missing {name:?}"))
        };
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let dir = |d: &str| -> anyhow::Result<LstmDir> {
                Ok(LstmDir {
                    wx: t(&format!("lstm{i}.{d}.wx"))?,
                    wh: t(&format!("lstm{i}.{d}.wh"))?,
                    b: t(&format!("lstm{i}.{d}.b"))?,
                })
            };
            layers.push((dir("fwd")?, dir("bwd")?));
        }
        Ok(BiLstm {
            n_mels,
            hidden,
            n_layers,
            vocab,
            layers,
            head_w: t("head.w")?,
            head_b: t("head.b")?,
        })
    }

    /// Random init at the python scales (speed benches).
    pub fn init(n_mels: usize, hidden: usize, n_layers: usize, vocab: usize, seed: u64) -> Self {
        use crate::weights::NamedTensor;
        let mut rng = crate::rng::Rng::new(seed);
        let mut tensors = Vec::new();
        for i in 0..n_layers {
            let d_in = if i == 0 { n_mels } else { 2 * hidden };
            for d in ["fwd", "bwd"] {
                tensors.push(NamedTensor {
                    name: format!("lstm{i}.{d}.wx"),
                    tensor: Tensor::randn(
                        &[d_in, 4 * hidden],
                        1.0 / (d_in as f32).sqrt(),
                        &mut rng,
                    ),
                });
                tensors.push(NamedTensor {
                    name: format!("lstm{i}.{d}.wh"),
                    tensor: Tensor::randn(
                        &[hidden, 4 * hidden],
                        1.0 / (hidden as f32).sqrt(),
                        &mut rng,
                    ),
                });
                let mut b = Tensor::zeros(&[4 * hidden]);
                for j in hidden..2 * hidden {
                    b.data[j] = 1.0; // forget-gate bias
                }
                tensors.push(NamedTensor {
                    name: format!("lstm{i}.{d}.b"),
                    tensor: b,
                });
            }
        }
        tensors.push(NamedTensor {
            name: "head.w".into(),
            tensor: Tensor::randn(
                &[2 * hidden, vocab],
                1.0 / ((2 * hidden) as f32).sqrt(),
                &mut rng,
            ),
        });
        tensors.push(NamedTensor {
            name: "head.b".into(),
            tensor: Tensor::zeros(&[vocab]),
        });
        Self::from_bundle(n_mels, hidden, n_layers, vocab, &WeightBundle::new(tensors)).unwrap()
    }

    fn scan_dir(&self, dir: &LstmDir, x: &Tensor, reverse: bool) -> Tensor {
        let (t_len, d_in) = x.dims2();
        let h = self.hidden;
        let mut out = Tensor::zeros(&[t_len, h]);
        let mut hs = vec![0.0f32; h];
        let mut cs = vec![0.0f32; h];
        let mut gates = vec![0.0f32; 4 * h];
        let mut gates_h = vec![0.0f32; 4 * h];
        let steps: Vec<usize> = if reverse {
            (0..t_len).rev().collect()
        } else {
            (0..t_len).collect()
        };
        for t in steps {
            vecmat_into(&mut gates, x.row(t), &dir.wx.data, d_in, 4 * h);
            vecmat_into(&mut gates_h, &hs, &dir.wh.data, h, 4 * h);
            for j in 0..4 * h {
                gates[j] += gates_h[j] + dir.b.data[j];
            }
            for j in 0..h {
                let i_g = sigmoid(gates[j]);
                let f_g = sigmoid(gates[h + j]);
                let g_g = gates[2 * h + j].tanh();
                let o_g = sigmoid(gates[3 * h + j]);
                cs[j] = f_g * cs[j] + i_g * g_g;
                hs[j] = o_g * cs[j].tanh();
            }
            out.row_mut(t).copy_from_slice(&hs);
        }
        out
    }

    /// feats [t, n_mels] -> log posteriors [t, vocab].
    pub fn forward(&self, feats: &Tensor) -> Tensor {
        let (t_len, _) = feats.dims2();
        let mut x = feats.clone();
        for (fwd, bwd) in &self.layers {
            let f = self.scan_dir(fwd, &x, false);
            let b = self.scan_dir(bwd, &x, true);
            let mut cat = Tensor::zeros(&[t_len, 2 * self.hidden]);
            for t in 0..t_len {
                cat.row_mut(t)[..self.hidden].copy_from_slice(f.row(t));
                cat.row_mut(t)[self.hidden..].copy_from_slice(b.row(t));
            }
            x = cat;
        }
        let mut logits = crate::tensor::matmul(&x, &self.head_w);
        for t in 0..t_len {
            let row = logits.row_mut(t);
            for (l, b) in row.iter_mut().zip(&self.head_b.data) {
                *l += b;
            }
            // log softmax
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
            for l in row.iter_mut() {
                *l -= lse;
            }
        }
        logits
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn forward_shape_and_normalization() {
        let m = BiLstm::init(13, 16, 2, 9, 0);
        let mut rng = Rng::new(1);
        let feats = Tensor::randn(&[20, 13], 1.0, &mut rng);
        let logp = m.forward(&feats);
        assert_eq!(logp.shape, vec![20, 9]);
        for t in 0..20 {
            let s: f32 = logp.row(t).iter().map(|&l| l.exp()).sum();
            assert!((s - 1.0).abs() < 1e-4, "row {t} sums to {s}");
        }
    }

    #[test]
    fn uses_future_context() {
        let m = BiLstm::init(8, 8, 1, 5, 2);
        let mut rng = Rng::new(3);
        let feats = Tensor::randn(&[10, 8], 1.0, &mut rng);
        let a = m.forward(&feats);
        let mut feats2 = feats.clone();
        for x in feats2.row_mut(9) {
            *x += 5.0;
        }
        let b = m.forward(&feats2);
        let diff: f32 = a.row(0).iter().zip(b.row(0)).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-6, "first frame must see the perturbed last frame");
    }

    #[test]
    fn deterministic() {
        let m = BiLstm::init(8, 8, 2, 5, 4);
        let mut rng = Rng::new(5);
        let feats = Tensor::randn(&[12, 8], 1.0, &mut rng);
        assert_eq!(m.forward(&feats), m.forward(&feats));
    }
}
