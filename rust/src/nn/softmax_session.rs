//! Batched stateful-softmax decode: the KV-cache serving backend.
//!
//! [`BatchedSoftmaxSession`] is the softmax twin of
//! [`super::BatchedDecodeSession`]: the same `[B, ·]` activation
//! buffers, the same quant-aware pooled GEMMs for the QKV/output/FF
//! projections and the lm-head, the same dense-lane churn discipline
//! (alloc / swap-remove / prefix stepping / chunked resumable prefill /
//! snapshot export-import). The *only* divergence is the attention
//! core: where the linear session updates a fixed-size (S, Z) pair per
//! layer×head (eqs 16-20), this session appends one (k, v) row per
//! token to a [`softmax::BatchedKvCache`] and attends over the whole
//! cache — O(t·d) per token at position t, with state that grows with
//! the sequence.
//!
//! Serving both formulations behind the same
//! [`crate::coordinator::engine::DecodeBackend`] trait is what makes
//! the paper's Tables 4/5 contrast a measured serving scenario instead
//! of a claim: one tick loop, one batcher, one admission path — the
//! backends differ only in the per-token attention cost and in how
//! their lane snapshots scale (O(1) bytes for linear, O(N) here).

use std::sync::Arc;

use crate::attention::{softmax, AttentionKind};
use crate::parallel::ThreadPool;
use crate::tensor::{
    add_bias_rows, gather_cols, gelu, layer_norm_into, layer_norm_rows_pooled, scatter_cols,
};

use super::{mm_w, vm_w_pooled, LaneSnapshot, TransformerLM, PREFILL_CHUNK};

/// Batched autoregressive decode over per-lane growing KV caches.
///
/// Holds every lane's cache in structure-of-arrays layout (one
/// [`softmax::BatchedKvCache`] per layer×head, each lane's rows
/// reserved at `max_len` tokens up front so serving-tick appends never
/// allocate) plus `[B, ·]` activation buffers, so one
/// [`Self::step_batch`] call advances all live lanes by one token
/// through single `[B, ·]` GEMMs — identical projection machinery to
/// the linear session; only the attention core differs.
///
/// Prompts enter through [`Self::prefill_row`] (one-shot) or
/// [`Self::prefill_row_partial`] (resumable), consumed in
/// [`PREFILL_CHUNK`]-sized chunks with the vocab-sized lm-head run only
/// for the final prompt position. The per-token float-op order of the
/// KV attention core IS the step path, so prefilled state and logits
/// are bit-identical to per-tick feeding regardless of chunking.
///
/// A lane's snapshot ([`Self::export_lane`] / [`Self::import_lane`]) is
/// its appended K/V rows plus the position cursor — unlike the linear
/// backend's constant-size snapshot it grows with the prefix length,
/// and [`LaneSnapshot::bytes`] reports that honestly so the state
/// cache's LRU budget stays meaningful.
pub struct BatchedSoftmaxSession<'m> {
    model: &'m TransformerLM,
    cap: usize,
    rows: usize,
    /// worker pool for the projection GEMMs (None = pure serial); the
    /// attention core itself is serial per lane — O(t·d) next to the
    /// `[B, ·]` GEMMs, and trivially thread-count-invariant
    pool: Option<Arc<ThreadPool>>,
    /// n_layers * n_heads batched caches, lane-for-lane in step
    states: Vec<softmax::BatchedKvCache>,
    /// absolute position of the next token, per lane
    pos: Vec<usize>,
    // preallocated [cap, ·] activation buffers
    x: Vec<f32>,
    normed: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    merged: Vec<f32>,
    out2: Vec<f32>,
    ff: Vec<f32>,
    // per-head gather buffers, [cap, d_head]
    qh: Vec<f32>,
    kh: Vec<f32>,
    vh: Vec<f32>,
    oh: Vec<f32>,
}

impl<'m> BatchedSoftmaxSession<'m> {
    pub(super) fn new(model: &'m TransformerLM, cap: usize, pool: Option<Arc<ThreadPool>>) -> Self {
        assert_eq!(
            model.kind,
            AttentionKind::Softmax,
            "batched KV-cache decode requires a softmax-attention model"
        );
        assert!(cap >= 1);
        let cfg = &model.cfg;
        let e = cfg.d_model;
        let dh = cfg.d_head();
        // activation buffers serve both the [B, ·] decode tick and the
        // [PREFILL_CHUNK, ·] prefill pass (never concurrently), so size
        // them for whichever is wider
        let buf_rows = cap.max(PREFILL_CHUNK);
        BatchedSoftmaxSession {
            model,
            cap,
            rows: 0,
            pool,
            states: (0..cfg.n_layers * cfg.n_heads)
                .map(|_| softmax::BatchedKvCache::new(cap, dh, dh, cfg.max_len))
                .collect(),
            pos: Vec::with_capacity(cap),
            x: vec![0.0; buf_rows * e],
            normed: vec![0.0; buf_rows * e],
            q: vec![0.0; buf_rows * e],
            k: vec![0.0; buf_rows * e],
            v: vec![0.0; buf_rows * e],
            merged: vec![0.0; buf_rows * e],
            out2: vec![0.0; buf_rows * e],
            ff: vec![0.0; buf_rows * cfg.d_ff],
            qh: vec![0.0; buf_rows * dh],
            kh: vec![0.0; buf_rows * dh],
            vh: vec![0.0; buf_rows * dh],
            oh: vec![0.0; buf_rows * dh],
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Threads the session's GEMM kernels fan out over (1 = serial).
    pub fn pool_threads(&self) -> usize {
        self.pool.as_ref().map(|p| p.threads()).unwrap_or(1)
    }

    /// Live lanes.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Absolute position of the next token lane `row` will consume.
    pub fn pos(&self, row: usize) -> usize {
        self.pos[row]
    }

    pub fn vocab(&self) -> usize {
        self.model.cfg.vocab
    }

    pub fn max_len(&self) -> usize {
        self.model.cfg.max_len
    }

    /// Append a fresh lane (empty cache, position 0); `None` at capacity.
    pub fn alloc_row(&mut self) -> Option<usize> {
        if self.rows == self.cap {
            return None;
        }
        for st in &mut self.states {
            // lintra: allow(panic) -- guarded by the rows == cap check above
            st.push_row().expect("states and session agree on capacity");
        }
        self.pos.push(0);
        self.rows += 1;
        Some(self.rows - 1)
    }

    /// Free lane `row`, compacting by moving the last lane into its place.
    /// Returns the moved lane's previous index (`None` if `row` was last).
    pub fn free_row(&mut self, row: usize) -> Option<usize> {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        let mut moved = None;
        for st in &mut self.states {
            moved = st.swap_remove_row(row);
        }
        self.pos.swap_remove(row);
        self.rows -= 1;
        moved
    }

    /// Bytes of KV-cache state held for the live lanes *at their current
    /// lengths* — grows with every decoded token (Table 4's contrast
    /// with the constant linear state).
    pub fn state_bytes(&self) -> usize {
        self.states.iter().map(|s| s.state_bytes()).sum()
    }

    /// Advance the first `tokens.len()` live lanes by one token;
    /// `tokens[r]` feeds lane r. Returns logits `[tokens.len() * vocab]`
    /// row-major.
    ///
    /// Allocating convenience form of [`Self::step_batch_into`]; the
    /// serving tick loop passes a reused buffer instead.
    pub fn step_batch(&mut self, tokens: &[u32]) -> Vec<f32> {
        // lintra: allow(alloc) -- compat wrapper; the tick loop uses step_batch_into
        let mut logits = Vec::new();
        self.step_batch_into(tokens, &mut logits);
        logits
    }

    /// Advance the first `tokens.len()` live lanes by one token;
    /// `tokens[r]` feeds lane r. Fills `logits` with `[tokens.len() *
    /// vocab]` row-major values, replacing its previous contents.
    ///
    /// Callers may step a *prefix* of the live lanes (`tokens.len() <
    /// rows`): the suffix lanes are left completely untouched, and each
    /// lane's float-op order is independent of how many lanes step
    /// together — the same prefix-step contract the linear session
    /// keeps, which the serving engine relies on for mid-prefill lanes.
    pub fn step_batch_into(&mut self, tokens: &[u32], logits: &mut Vec<f32>) {
        let b = tokens.len();
        assert!(b <= self.rows, "stepping {b} lanes of {} live", self.rows);
        let model = self.model;
        let cfg = &model.cfg;
        let e = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.d_head();
        logits.clear();
        if b == 0 {
            return;
        }
        let pool = self.pool.as_deref();
        // x = tok_embed + pos_embed, gathered per lane
        for (r, &tok) in tokens.iter().enumerate() {
            assert!(
                self.pos[r] < cfg.max_len,
                "lane {r} exceeds max_len {}",
                cfg.max_len
            );
            let te = model.tok_embed.row(tok as usize);
            let pe = model.pos_embed.row(self.pos[r]);
            let xr = &mut self.x[r * e..(r + 1) * e];
            for j in 0..e {
                xr[j] = te[j] + pe[j];
            }
        }
        for (li, blk) in model.blocks.iter().enumerate() {
            let qb = model.quant.as_ref().map(|q| &q.blocks[li]);
            // ln1 -> one [B, e] x [e, e] GEMM per projection
            layer_norm_rows_pooled(
                pool,
                &mut self.normed[..b * e],
                &self.x[..b * e],
                &blk.ln1_g.data,
                &blk.ln1_b.data,
                b,
            );
            let normed = &self.normed[..b * e];
            mm_w(pool, &mut self.q[..b * e], normed, qb.map(|q| &q.wq), &blk.wq, b, e, e);
            mm_w(pool, &mut self.k[..b * e], normed, qb.map(|q| &q.wk), &blk.wk, b, e, e);
            mm_w(pool, &mut self.v[..b * e], normed, qb.map(|q| &q.wv), &blk.wv, b, e, e);
            // per head: gather columns, append-and-attend, scatter back
            for hd in 0..h {
                let col = hd * dh;
                gather_cols(&mut self.qh[..b * dh], &self.q[..b * e], b, e, col, dh);
                gather_cols(&mut self.kh[..b * dh], &self.k[..b * e], b, e, col, dh);
                gather_cols(&mut self.vh[..b * dh], &self.v[..b * e], b, e, col, dh);
                self.states[li * h + hd].step_batch(
                    &self.qh[..b * dh],
                    &self.kh[..b * dh],
                    &self.vh[..b * dh],
                    &mut self.oh[..b * dh],
                );
                scatter_cols(&mut self.merged[..b * e], &self.oh[..b * dh], b, e, col, dh);
            }
            mm_w(
                pool,
                &mut self.out2[..b * e],
                &self.merged[..b * e],
                qb.map(|q| &q.wo),
                &blk.wo,
                b,
                e,
                e,
            );
            for (xv, &ov) in self.x[..b * e].iter_mut().zip(&self.out2[..b * e]) {
                *xv += ov;
            }
            // ff: [B, e] x [e, d_ff] and [B, d_ff] x [d_ff, e] GEMMs
            layer_norm_rows_pooled(
                pool,
                &mut self.normed[..b * e],
                &self.x[..b * e],
                &blk.ln2_g.data,
                &blk.ln2_b.data,
                b,
            );
            let dff = cfg.d_ff;
            mm_w(
                pool,
                &mut self.ff[..b * dff],
                &self.normed[..b * e],
                qb.map(|q| &q.ff_w1),
                &blk.ff_w1,
                b,
                e,
                dff,
            );
            for r in 0..b {
                for (hv, &bv) in self.ff[r * dff..(r + 1) * dff].iter_mut().zip(&blk.ff_b1.data)
                {
                    *hv = gelu(*hv + bv);
                }
            }
            mm_w(
                pool,
                &mut self.out2[..b * e],
                &self.ff[..b * dff],
                qb.map(|q| &q.ff_w2),
                &blk.ff_w2,
                b,
                dff,
                e,
            );
            for (xv, &ov) in self.x[..b * e].iter_mut().zip(&self.out2[..b * e]) {
                *xv += ov;
            }
            add_bias_rows(&mut self.x[..b * e], &blk.ff_b2.data, b);
        }
        // final ln + one [B, e] x [e, vocab] GEMM
        layer_norm_rows_pooled(
            pool,
            &mut self.normed[..b * e],
            &self.x[..b * e],
            &model.final_ln_g.data,
            &model.final_ln_b.data,
            b,
        );
        let vocab = cfg.vocab;
        // cleared above, so resize zero-fills every element — exactly a
        // fresh `vec![0.0; b * vocab]`, and a reused buffer is
        // bit-identical to an allocating call
        logits.resize(b * vocab, 0.0);
        let normed = &self.normed[..b * e];
        mm_w(
            pool,
            &mut logits[..],
            normed,
            model.quant.as_ref().map(|q| &q.head_w),
            &model.head_w,
            b,
            e,
            vocab,
        );
        add_bias_rows(&mut logits[..], &model.head_b.data, b);
        for p in self.pos[..b].iter_mut() {
            *p += 1;
        }
    }

    /// Swap lanes `a` and `b` (every layer×head cache plus the position
    /// cursors). O(cached-tokens-per-lane), the same order as a
    /// [`Self::free_row`] compaction move. The serving engine uses this
    /// to move a lane whose prompt just finished prefilling into the
    /// decoding prefix.
    pub fn swap_rows(&mut self, a: usize, b: usize) {
        assert!(a < self.rows && b < self.rows, "swap_rows out of {} live lanes", self.rows);
        if a == b {
            return;
        }
        for st in &mut self.states {
            st.swap_rows(a, b);
        }
        self.pos.swap(a, b);
    }

    /// Bytes of lane `row`'s [`LaneSnapshot`] payload — proportional to
    /// the tokens the lane has consumed, unlike the linear backend's
    /// constant-size snapshot.
    pub fn lane_snapshot_bytes(&self, row: usize) -> usize {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        self.states.len() * self.states[0].snapshot_len(row) * std::mem::size_of::<f32>()
    }

    /// Export lane `row`'s complete decode state — every layer×head's
    /// cached K/V rows plus the position cursor — as a [`LaneSnapshot`].
    /// The lane itself is untouched. The payload is O(pos) per
    /// layer×head; [`LaneSnapshot::bytes`] therefore reports the true
    /// growing cost, which is what keeps the state cache's LRU budget
    /// honest when this backend deposits into it.
    pub fn export_lane(&self, row: usize) -> LaneSnapshot {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        let per = self.states[0].snapshot_len(row);
        debug_assert_eq!(
            per,
            self.pos[row] * 2 * self.model.cfg.d_head(),
            "cache length and position cursor must agree"
        );
        // lintra: allow(alloc) -- snapshots are admission/deposit-path, not
        // per-tick, and each needs an owned buffer to hand to the cache
        let mut data = vec![0.0f32; self.states.len() * per];
        for (i, st) in self.states.iter().enumerate() {
            st.export_row(row, &mut data[i * per..(i + 1) * per]);
        }
        LaneSnapshot {
            pos: self.pos[row],
            data,
        }
    }

    /// Overwrite lane `row`'s caches and position from a snapshot taken
    /// by [`Self::export_lane`] on a session of the same model geometry.
    ///
    /// After the import the lane is **bit-identical** to having
    /// prefilled the snapshot's tokens in place: the cached K/V rows are
    /// the exact f32 bits the prefill path appended, and every
    /// continuation's float-op order depends only on the cached rows and
    /// the inputs — never on how the rows got there.
    pub fn import_lane(&mut self, row: usize, snap: &LaneSnapshot) {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        let dh = self.model.cfg.d_head();
        let per = snap.pos * 2 * dh;
        assert_eq!(
            snap.data.len(),
            self.states.len() * per,
            "snapshot geometry does not match this model"
        );
        assert!(
            snap.pos <= self.model.cfg.max_len,
            "snapshot position {} exceeds max_len {}",
            snap.pos,
            self.model.cfg.max_len
        );
        for (i, st) in self.states.iter_mut().enumerate() {
            st.import_row(row, snap.pos, &snap.data[i * per..(i + 1) * per]);
        }
        self.pos[row] = snap.pos;
    }

    /// Ingest a whole `prompt` into lane `row` in [`PREFILL_CHUNK`]-sized
    /// chunks, returning the logits of the final prompt position
    /// (`[vocab]`). The chunk projections run as `[chunk, ·]` GEMMs; the
    /// attention appends the chunk's K/V rows and attends causally over
    /// the growing cache; intermediate positions never touch the final
    /// layer norm or the vocab-sized lm-head. Bit-identical to feeding
    /// the prompt one tick at a time.
    pub fn prefill_row(&mut self, row: usize, prompt: &[u32]) -> Vec<f32> {
        self.prefill_row_partial(row, prompt, true)
            // lintra: allow(panic) -- contract: finish = true always yields logits
            .expect("finish = true always returns logits")
    }

    /// Resumable prefill: absorb `tokens` — any slice of a prompt — into
    /// lane `row`'s caches, continuing from wherever the lane's position
    /// cursor stands. Pass `finish = false` for interior slices (`None`
    /// returned); pass `finish = true` with the last slice to get the
    /// final position's logits (`Some([vocab])`). Slicing never changes
    /// a logit, exactly as for the linear session.
    ///
    /// Allocating convenience form of [`Self::prefill_row_partial_into`];
    /// the serving tick loop passes a reused buffer instead.
    pub fn prefill_row_partial(
        &mut self,
        row: usize,
        tokens: &[u32],
        finish: bool,
    ) -> Option<Vec<f32>> {
        // lintra: allow(alloc) -- compat wrapper; the tick loop uses prefill_row_partial_into
        let mut out = Vec::new();
        if self.prefill_row_partial_into(row, tokens, finish, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Buffer-reusing form of [`Self::prefill_row_partial`]: on a
    /// finishing slice, fills `out` with the final position's logits
    /// (`[vocab]`, previous contents replaced) and returns `true`;
    /// interior slices leave `out` cleared and return `false`.
    pub fn prefill_row_partial_into(
        &mut self,
        row: usize,
        tokens: &[u32],
        finish: bool,
        out: &mut Vec<f32>,
    ) -> bool {
        assert!(row < self.rows, "lane {row} out of {} live lanes", self.rows);
        assert!(!tokens.is_empty(), "prefill needs at least one prompt token");
        let model = self.model;
        let cfg = &model.cfg;
        let e = cfg.d_model;
        let h = cfg.n_heads;
        let dh = cfg.d_head();
        let dff = cfg.d_ff;
        assert!(
            self.pos[row] + tokens.len() <= cfg.max_len,
            "lane {row}: prompt of {} at position {} exceeds max_len {}",
            tokens.len(),
            self.pos[row],
            cfg.max_len
        );
        let pool = self.pool.as_deref();
        out.clear();
        let mut wrote = false;
        let mut off = 0;
        while off < tokens.len() {
            let n = (tokens.len() - off).min(PREFILL_CHUNK);
            let chunk = &tokens[off..off + n];
            let base = self.pos[row];
            // x = tok_embed + pos_embed for every chunk position
            for (i, &tok) in chunk.iter().enumerate() {
                let te = model.tok_embed.row(tok as usize);
                let pe = model.pos_embed.row(base + i);
                let xr = &mut self.x[i * e..(i + 1) * e];
                for j in 0..e {
                    xr[j] = te[j] + pe[j];
                }
            }
            for (li, blk) in model.blocks.iter().enumerate() {
                // ln1 -> one [chunk, e] x [e, e] GEMM per projection
                layer_norm_rows_pooled(
                    pool,
                    &mut self.normed[..n * e],
                    &self.x[..n * e],
                    &blk.ln1_g.data,
                    &blk.ln1_b.data,
                    n,
                );
                let qb = model.quant.as_ref().map(|q| &q.blocks[li]);
                let normed = &self.normed[..n * e];
                mm_w(pool, &mut self.q[..n * e], normed, qb.map(|q| &q.wq), &blk.wq, n, e, e);
                mm_w(pool, &mut self.k[..n * e], normed, qb.map(|q| &q.wk), &blk.wk, n, e, e);
                mm_w(pool, &mut self.v[..n * e], normed, qb.map(|q| &q.wv), &blk.wv, n, e, e);
                // per head: the chunk's rows append to this lane's cache
                // only; other lanes' caches are untouched
                for hd in 0..h {
                    let col = hd * dh;
                    gather_cols(&mut self.qh[..n * dh], &self.q[..n * e], n, e, col, dh);
                    gather_cols(&mut self.kh[..n * dh], &self.k[..n * e], n, e, col, dh);
                    gather_cols(&mut self.vh[..n * dh], &self.v[..n * e], n, e, col, dh);
                    self.states[li * h + hd].prefill_row(
                        row,
                        &self.qh[..n * dh],
                        &self.kh[..n * dh],
                        &self.vh[..n * dh],
                        n,
                        &mut self.oh[..n * dh],
                    );
                    scatter_cols(&mut self.merged[..n * e], &self.oh[..n * dh], n, e, col, dh);
                }
                let merged = &self.merged[..n * e];
                mm_w(pool, &mut self.out2[..n * e], merged, qb.map(|q| &q.wo), &blk.wo, n, e, e);
                for (xv, &ov) in self.x[..n * e].iter_mut().zip(&self.out2[..n * e]) {
                    *xv += ov;
                }
                // ff: [chunk, e] x [e, d_ff] and [chunk, d_ff] x [d_ff, e]
                layer_norm_rows_pooled(
                    pool,
                    &mut self.normed[..n * e],
                    &self.x[..n * e],
                    &blk.ln2_g.data,
                    &blk.ln2_b.data,
                    n,
                );
                mm_w(
                    pool,
                    &mut self.ff[..n * dff],
                    &self.normed[..n * e],
                    qb.map(|q| &q.ff_w1),
                    &blk.ff_w1,
                    n,
                    e,
                    dff,
                );
                for r in 0..n {
                    let frow = &mut self.ff[r * dff..(r + 1) * dff];
                    for (hv, &bv) in frow.iter_mut().zip(&blk.ff_b1.data) {
                        *hv = gelu(*hv + bv);
                    }
                }
                mm_w(
                    pool,
                    &mut self.out2[..n * e],
                    &self.ff[..n * dff],
                    qb.map(|q| &q.ff_w2),
                    &blk.ff_w2,
                    n,
                    dff,
                    e,
                );
                for (xv, &ov) in self.x[..n * e].iter_mut().zip(&self.out2[..n * e]) {
                    *xv += ov;
                }
                add_bias_rows(&mut self.x[..n * e], &blk.ff_b2.data, n);
            }
            self.pos[row] += n;
            off += n;
            if finish && off == tokens.len() {
                // only the last prompt position pays for the final layer
                // norm and the [e, vocab] lm-head
                let last = n - 1;
                layer_norm_into(
                    &mut self.normed[..e],
                    &self.x[last * e..(last + 1) * e],
                    &model.final_ln_g.data,
                    &model.final_ln_b.data,
                );
                // cleared on entry, so resize zero-fills — exactly a
                // fresh `vec![0.0; vocab]` for the reused buffer too
                out.resize(cfg.vocab, 0.0);
                vm_w_pooled(
                    pool,
                    &mut out[..],
                    &self.normed[..e],
                    model.quant.as_ref().map(|q| &q.head_w),
                    &model.head_w,
                    e,
                    cfg.vocab,
                );
                for (l, bv) in out.iter_mut().zip(&model.head_b.data) {
                    *l += bv;
                }
                wrote = true;
            }
        }
        wrote
    }
}

#[cfg(test)]
mod tests {
    use crate::attention::AttentionKind;
    use crate::config::ModelConfig;
    use crate::nn::TransformerLM;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            vocab: 11,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            d_ff: 64,
            max_len: 48,
            ..ModelConfig::small_copy()
        }
    }

    fn tokens(n: usize, vocab: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::rng::Rng::new(seed);
        (0..n).map(|_| (rng.next_u64() as usize % vocab) as u32).collect()
    }

    #[test]
    fn batched_softmax_matches_forward_per_lane() {
        // every lane's step-by-step logits vs the full parallel forward
        // of that lane's sequence (tolerance: different projection
        // paths — GEMM rows vs allocating matmuls — not bitwise)
        let cfg = tiny_cfg();
        let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 3);
        let streams: Vec<Vec<u32>> =
            (0..3).map(|s| tokens(10, cfg.vocab, 100 + s)).collect();
        let mut sess = model.batched_softmax_session_with_pool(streams.len(), None);
        for _ in 0..streams.len() {
            sess.alloc_row().expect("capacity");
        }
        let vocab = cfg.vocab;
        for t in 0..10 {
            let step_tokens: Vec<u32> = streams.iter().map(|s| s[t]).collect();
            let logits = sess.step_batch(&step_tokens);
            for (r, stream) in streams.iter().enumerate() {
                let full = model.forward(&stream[..t + 1]);
                let (nrows, v) = full.dims2();
                assert_eq!(v, vocab);
                let want = &full.data[(nrows - 1) * v..];
                let got = &logits[r * vocab..(r + 1) * vocab];
                for (i, (w, g)) in want.iter().zip(got).enumerate() {
                    assert!(
                        (w - g).abs() < 2e-3,
                        "lane {r} pos {t} logit {i}: forward {w} vs kv {g}"
                    );
                }
            }
        }
    }

    #[test]
    fn partial_prefill_is_bitwise_one_shot_regardless_of_slicing() {
        let mut cfg = tiny_cfg();
        cfg.max_len = 200;
        let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 5);
        let prompt = tokens(130, cfg.vocab, 7);

        let mut oneshot = model.batched_softmax_session_with_pool(1, None);
        oneshot.alloc_row().expect("capacity");
        let want = oneshot.prefill_row(0, &prompt);

        for splits in [
            vec![130usize],
            vec![64, 66],
            vec![1, 64, 65],
            vec![13, 51, 29, 37],
        ] {
            let mut sess = model.batched_softmax_session_with_pool(1, None);
            sess.alloc_row().expect("capacity");
            let mut off = 0;
            let mut got = None;
            for (i, &len) in splits.iter().enumerate() {
                let finish = i == splits.len() - 1;
                let res = sess.prefill_row_partial(0, &prompt[off..off + len], finish);
                off += len;
                if finish {
                    got = res;
                }
            }
            assert_eq!(off, prompt.len());
            let got = got.expect("finishing slice returns logits");
            assert_eq!(
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "splits {splits:?} changed the finishing logits"
            );
        }
    }

    #[test]
    fn prefill_is_bitwise_step_by_step() {
        let cfg = tiny_cfg();
        let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 9);
        let prompt = tokens(20, cfg.vocab, 21);

        let mut stepped = model.batched_softmax_session_with_pool(1, None);
        stepped.alloc_row().expect("capacity");
        let mut step_logits = Vec::new();
        for &t in &prompt {
            step_logits = stepped.step_batch(&[t]);
        }

        let mut prefilled = model.batched_softmax_session_with_pool(1, None);
        prefilled.alloc_row().expect("capacity");
        let pre_logits = prefilled.prefill_row(0, &prompt);

        assert_eq!(
            step_logits.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            pre_logits.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
        assert_eq!(stepped.pos(0), prefilled.pos(0));
        assert_eq!(stepped.state_bytes(), prefilled.state_bytes());
    }

    #[test]
    fn lane_churn_preserves_survivor_streams() {
        // mirror of the linear session's slot-churn spec: free a lane
        // mid-stream, let the survivor get compacted into its slot, and
        // check its continuation is bitwise the uninterrupted run
        let cfg = tiny_cfg();
        let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 11);
        let a = tokens(16, cfg.vocab, 1);
        let b = tokens(16, cfg.vocab, 2);

        // uninterrupted reference for stream b
        let mut solo = model.batched_softmax_session_with_pool(1, None);
        solo.alloc_row().expect("capacity");
        let mut want = Vec::new();
        for &t in &b {
            want = solo.step_batch(&[t]);
        }

        let mut sess = model.batched_softmax_session_with_pool(2, None);
        sess.alloc_row().expect("capacity");
        sess.alloc_row().expect("capacity");
        // advance both lanes half-way
        for i in 0..8 {
            let _ = sess.step_batch(&[a[i], b[i]]);
        }
        // retire lane 0; lane 1 (stream b) compacts into slot 0
        assert_eq!(sess.free_row(0), Some(1));
        let mut got = Vec::new();
        for i in 8..16 {
            got = sess.step_batch(&[b[i]]);
        }
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "compacted lane diverged from its uninterrupted run"
        );
    }

    #[test]
    fn export_import_lane_resumes_bitwise() {
        let cfg = tiny_cfg();
        let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 13);
        let prompt = tokens(24, cfg.vocab, 3);
        let cut = 16;

        let mut full = model.batched_softmax_session_with_pool(1, None);
        full.alloc_row().expect("capacity");
        let want = full.prefill_row(0, &prompt);

        let mut donor = model.batched_softmax_session_with_pool(1, None);
        donor.alloc_row().expect("capacity");
        donor.prefill_row_partial(0, &prompt[..cut], false);
        let snap = donor.export_lane(0);
        assert_eq!(snap.pos, cut);
        assert_eq!(
            snap.bytes(),
            donor.lane_snapshot_bytes(0),
            "snapshot bytes must match the session's accounting"
        );
        // O(N) snapshot: bytes grow with the prefix, unlike linear
        assert_eq!(
            snap.bytes(),
            cfg.n_layers * cfg.n_heads * cut * 2 * cfg.d_head() * 4
        );

        let mut resumed = model.batched_softmax_session_with_pool(1, None);
        resumed.alloc_row().expect("capacity");
        resumed.import_lane(0, &snap);
        let got = resumed
            .prefill_row_partial(0, &prompt[cut..], true)
            .expect("finishing slice returns logits");
        assert_eq!(
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    #[should_panic(expected = "snapshot geometry does not match this model")]
    fn import_lane_rejects_mismatched_geometry() {
        let cfg = tiny_cfg();
        let model = TransformerLM::init(&cfg, AttentionKind::Softmax, 15);
        let mut donor = model.batched_softmax_session_with_pool(1, None);
        donor.alloc_row().expect("capacity");
        donor.prefill_row_partial(0, &tokens(8, cfg.vocab, 4), false);
        let snap = donor.export_lane(0);

        let mut other_cfg = tiny_cfg();
        other_cfg.n_layers = 1;
        let other = TransformerLM::init(&other_cfg, AttentionKind::Softmax, 15);
        let mut sess = other.batched_softmax_session_with_pool(1, None);
        sess.alloc_row().expect("capacity");
        sess.import_lane(0, &snap);
    }
}
