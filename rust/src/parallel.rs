//! Persistent worker pool for the hot decode/prefill kernels.
//!
//! The paper's recurrence makes decode compute-bound on a handful of
//! `[B, ·]` GEMMs per tick; this module supplies the threads that keep
//! every core busy during those GEMMs without changing a single float.
//!
//! Design constraints, in order:
//!
//! 1. **Bit-identical results.** The pool never splits a reduction: work
//!    is partitioned over *output rows/lanes only* (see
//!    [`ThreadPool::for_row_blocks`]), so each row is produced by exactly
//!    the serial kernel's float-op order and `parallel == serial` holds
//!    bitwise. The parity suites assert this directly.
//! 2. **Spawn once.** Threads are created at pool construction and live
//!    until drop — a decode tick dispatches ~dozens of kernels, and
//!    per-kernel thread spawning would dwarf the work.
//! 3. **Low dispatch latency, no idle burn.** Workers spin briefly on an
//!    atomic epoch (microseconds) before parking on a condvar, so
//!    back-to-back kernels within one tick stay hot while an idle engine
//!    costs no CPU.
//!
//! Thread-count resolution (see [`resolve_threads`]): an explicit count
//! wins, `0` means "auto" — the `LINTRA_NUM_THREADS` environment variable
//! if set, else one thread per available core. The process-wide
//! [`default_pool`] backs sessions that don't pick a pool themselves; CI
//! runs the test suite both with `LINTRA_NUM_THREADS=1` (pure serial
//! paths) and unset (pooled paths).
//!
//! # Dispatch thresholds — when work does *not* fan out
//!
//! Because the unit of partition is an output row, a job with a single
//! output row is a GEMV in disguise and cannot be split *by rows*. It
//! **can** be split by output **columns** without touching any
//! reduction: each worker owns a disjoint contiguous column range of
//! the one output row and computes those dot products exactly as the
//! serial kernel would, so rule 1 still holds bitwise at any thread
//! count. [`crate::tensor::vecmat_into_cols_pooled`] (and its
//! packed-weight siblings) implement exactly that — it is how B = 1
//! decode ticks, the weight-bandwidth-bound serving shape, scale with
//! cores. Two layers of defense keep *unprofitable* shapes off the
//! pool:
//!
//! * **Row-partitioned kernels require `rows >= 2`** (guards in every
//!   row-blocked `*_pooled` kernel in `crate::tensor`); single-row
//!   inputs route to the column-split GEMV path instead.
//! * **Tiny kernels stay serial**: below
//!   [`crate::tunables::PAR_MIN_WORK`] (~16k mul-adds for GEMM shapes),
//!   [`crate::tunables::PAR_MIN_ROW_ELEMS`] (row-wise kernels), or
//!   [`crate::tunables::PAR_MIN_GEMV_COLS`] output columns (the
//!   column-split GEMV), one dispatch (microseconds) would rival the
//!   work itself. Every such threshold lives in [`crate::tunables`],
//!   next to the SIMD and GEMM-packing minimums of the other dispatch
//!   axes.
//!
//! # Example
//!
//! ```
//! use linear_transformer::parallel::ThreadPool;
//!
//! let pool = ThreadPool::new(4);
//! // fill a [8, 3] block in parallel; rows are never split, so each
//! // row's values match what a serial loop would produce exactly
//! let mut out = vec![0.0f32; 8 * 3];
//! pool.for_row_blocks(8, 3, &mut out, |row0, block| {
//!     for (i, row) in block.chunks_mut(3).enumerate() {
//!         row.fill((row0 + i) as f32);
//!     }
//! });
//! assert_eq!(out[7 * 3], 7.0);
//! ```

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::time::Duration;

/// Acquire a mutex, taking the data even when a peer thread panicked
/// while holding it (std's poisoning). Every serving-path lock goes
/// through here — the rule-`lock` invariant (`lintra analyze`) — so one
/// panicked connection thread can never cascade into the engine via a
/// poisoned `.lock().unwrap()`. Sound for the crate's lock contents
/// (plain counters and job slots): they are valid at every await-free
/// point a panic can interrupt, so observing a "torn" update is not
/// possible beyond what the panicking thread had already committed.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Spin iterations before a waiter parks on its condvar. At ~1 ns per
/// iteration this bridges the gap between consecutive kernels of one
/// decode tick; an idle engine parks its workers within microseconds.
const SPIN_BEFORE_PARK: usize = 8 * 1024;

/// Lifetime-erased pointer to the dispatcher's job closure.
///
/// Only ever dereferenced by pool workers *while the dispatcher blocks
/// inside [`ThreadPool::broadcast`]*, which does not return until every
/// worker has finished the job — so the pointee outlives every call.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (concurrent shared calls are allowed) and
// `broadcast` keeps it alive until all workers are done with it.
unsafe impl Send for JobPtr {}

/// The current job, present only while a broadcast is in flight.
struct JobSlot {
    f: Option<JobPtr>,
}

struct Shared {
    /// Published under this lock *before* `epoch` is bumped.
    job: Mutex<JobSlot>,
    /// Workers park here when the spin budget runs out.
    start: Condvar,
    /// Bumped once per broadcast (Release after the job is published).
    epoch: AtomicU64,
    /// Workers that have not yet finished the current job.
    remaining: AtomicUsize,
    /// Set by a worker whose job closure panicked; re-raised by the
    /// dispatcher so pooled kernels keep serial panic semantics.
    panicked: AtomicBool,
    shutdown: AtomicBool,
    /// Dispatcher parks here waiting for `remaining` to hit zero.
    done: Mutex<()>,
    done_cv: Condvar,
}

/// A spawn-once pool of `threads - 1` workers plus the dispatching
/// thread itself (the dispatcher always runs worker index 0, so a pool
/// of N threads uses exactly N cores during a job).
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes broadcasts so the pool can be shared across engines.
    dispatch: Mutex<()>,
    threads: usize,
}

impl ThreadPool {
    /// Build a pool that uses `threads` cores per job (clamped to >= 1;
    /// a 1-thread pool runs every job inline on the dispatcher).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            job: Mutex::new(JobSlot { f: None }),
            start: Condvar::new(),
            epoch: AtomicU64::new(0),
            remaining: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            done: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(threads.saturating_sub(1));
        for i in 1..threads {
            let sh = shared.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("lintra-pool-{i}"))
                    .spawn(move || worker_loop(sh, i))
                    // lintra: allow(panic) -- pool construction happens once
                    // before serving starts; if the OS cannot spawn threads
                    // here, failing fast beats serving with a broken pool
                    .expect("spawn pool worker"),
            );
        }
        ThreadPool {
            shared,
            handles,
            dispatch: Mutex::new(()),
            threads,
        }
    }

    /// Cores this pool uses per job (including the dispatching thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(worker_index)` once per pool thread (indices `0..threads`),
    /// returning only after every call has completed. `f` may borrow
    /// stack data: the borrow is safe because this call blocks until all
    /// workers are done with it. Panics in any `f` call are re-raised
    /// here (after all workers finished), matching serial semantics.
    ///
    /// Do not call `broadcast` from inside a job closure — the dispatch
    /// lock is not reentrant.
    pub fn broadcast(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.threads == 1 {
            f(0);
            return;
        }
        let _dispatch = lock_unpoisoned(&self.dispatch);
        // SAFETY: the erased borrow is only reachable through `JobPtr`
        // while this function blocks (see `wait_done` below), so the
        // closure strictly outlives every worker's use of it.
        let erased: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        {
            let mut slot = lock_unpoisoned(&self.shared.job);
            slot.f = Some(JobPtr(erased as *const (dyn Fn(usize) + Sync)));
            self.shared.remaining.store(self.threads - 1, Ordering::Release);
            self.shared.epoch.fetch_add(1, Ordering::Release);
            self.shared.start.notify_all();
        }
        // the dispatcher is worker 0; catch a local panic so we still
        // wait for the workers (they borrow f's captures) before unwinding
        let local = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(0)));
        self.wait_done();
        lock_unpoisoned(&self.shared.job).f = None;
        if self.shared.panicked.swap(false, Ordering::AcqRel) {
            // lintra: allow(panic) -- deliberate re-raise: pooled kernels
            // must keep serial panic semantics, so a worker's panic
            // surfaces on the dispatching thread once all workers are done
            panic!("pool worker panicked during a broadcast job");
        }
        if let Err(p) = local {
            std::panic::resume_unwind(p);
        }
    }

    /// Partition `out` (a `[rows, width]` row-major block) into one
    /// contiguous row range per pool thread and run
    /// `f(first_row, block)` on each range concurrently.
    ///
    /// Rows are never split, so a kernel that computes each output row
    /// exactly like its serial counterpart stays bit-identical under any
    /// thread count — the partition only decides ownership.
    pub fn for_row_blocks<F>(&self, rows: usize, width: usize, out: &mut [f32], f: F)
    where
        F: Fn(usize, &mut [f32]) + Sync,
    {
        assert_eq!(out.len(), rows * width, "for_row_blocks: out is not [rows, width]");
        if rows == 0 {
            return;
        }
        let parts = self.threads.min(rows);
        if parts <= 1 {
            f(0, out);
            return;
        }
        // split at row boundaries into one cell per participating worker
        let mut cells: Vec<Mutex<Option<(usize, &mut [f32])>>> = Vec::with_capacity(parts);
        let mut rest = out;
        for i in 0..parts {
            let lo = i * rows / parts;
            let hi = (i + 1) * rows / parts;
            let (blk, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * width);
            cells.push(Mutex::new(Some((lo, blk))));
            rest = tail;
        }
        self.broadcast(&|wi| {
            if let Some(cell) = cells.get(wi) {
                let taken = lock_unpoisoned(cell).take();
                if let Some((row0, blk)) = taken {
                    f(row0, blk);
                }
            }
        });
    }

    /// Block until every worker has finished the current job: spin
    /// briefly (workers usually finish within microseconds of the
    /// dispatcher's own share), then park on the done condvar.
    fn wait_done(&self) {
        let sh = &self.shared;
        let mut spins = 0usize;
        while sh.remaining.load(Ordering::Acquire) != 0 {
            spins += 1;
            if spins < SPIN_BEFORE_PARK {
                std::hint::spin_loop();
            } else {
                let guard = lock_unpoisoned(&sh.done);
                if sh.remaining.load(Ordering::Acquire) == 0 {
                    break;
                }
                // timed wait: belt-and-suspenders against a lost notify
                let (_guard, _timeout) = sh
                    .done_cv
                    .wait_timeout(guard, Duration::from_millis(1))
                    .unwrap_or_else(|p| p.into_inner());
            }
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let _slot = lock_unpoisoned(&self.shared.job);
            self.shared.shutdown.store(true, Ordering::Release);
            self.shared.start.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, index: usize) {
    let mut seen = 0u64;
    loop {
        // 1. wait for a fresh epoch: bounded spin, then park
        let mut spins = 0usize;
        loop {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            let e = shared.epoch.load(Ordering::Acquire);
            if e != seen {
                seen = e;
                break;
            }
            spins += 1;
            if spins < SPIN_BEFORE_PARK {
                std::hint::spin_loop();
            } else {
                // recheck under the job lock: the dispatcher bumps the
                // epoch while holding it, so no wakeup can be lost
                let guard = lock_unpoisoned(&shared.job);
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                if shared.epoch.load(Ordering::Acquire) == seen {
                    let _g = shared.start.wait(guard).unwrap_or_else(|p| p.into_inner());
                }
                spins = 0;
            }
        }
        // 2. run the job for this worker's index
        let job = lock_unpoisoned(&shared.job).f;
        if let Some(JobPtr(ptr)) = job {
            let call = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                // SAFETY: see JobPtr — the dispatcher blocks until
                // `remaining` hits zero, keeping the closure alive.
                (unsafe { &*ptr })(index)
            }));
            if call.is_err() {
                shared.panicked.store(true, Ordering::Release);
            }
        }
        // 3. report completion; the last finisher wakes the dispatcher
        if shared.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = lock_unpoisoned(&shared.done);
            shared.done_cv.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// thread-count resolution + the process-wide default pool
// ---------------------------------------------------------------------------

/// Resolve a thread-count request: `n >= 1` is explicit, `0` means auto
/// (`LINTRA_NUM_THREADS` if set to a positive integer, else one thread
/// per available core). Every path is clamped to
/// [`crate::config::MAX_NUM_THREADS`] so an absurd request degrades to a
/// large pool instead of panicking thread creation mid-serve.
pub fn resolve_threads(requested: usize) -> usize {
    let cap = crate::config::MAX_NUM_THREADS;
    if requested >= 1 {
        return requested.min(cap);
    }
    if let Ok(v) = std::env::var("LINTRA_NUM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n.min(cap);
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cap)
}

static DEFAULT_POOL: OnceLock<Option<Arc<ThreadPool>>> = OnceLock::new();

/// The process-wide pool, sized by [`resolve_threads`]`(0)` on first
/// use. `None` when the resolved count is 1 — callers then run the
/// plain serial kernels with zero dispatch overhead.
pub fn default_pool() -> Option<Arc<ThreadPool>> {
    DEFAULT_POOL
        .get_or_init(|| {
            let n = resolve_threads(0);
            if n <= 1 {
                None
            } else {
                Some(Arc::new(ThreadPool::new(n)))
            }
        })
        .clone()
}

/// Pool for an explicit request: `0` shares [`default_pool`], `1` is
/// pure serial (no pool at all), `n > 1` builds a dedicated pool
/// (clamped like every [`resolve_threads`] path).
pub fn pool_for(requested: usize) -> Option<Arc<ThreadPool>> {
    match requested {
        0 => default_pool(),
        1 => None,
        n => Some(Arc::new(ThreadPool::new(resolve_threads(n)))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_runs_every_worker_index_exactly_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..50 {
            pool.broadcast(&|wi| {
                hits[wi].fetch_add(1, Ordering::Relaxed);
            });
        }
        for (wi, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 50, "worker {wi} ran a wrong number of jobs");
        }
    }

    #[test]
    fn for_row_blocks_covers_every_row_exactly_once() {
        let pool = ThreadPool::new(3);
        for rows in [1usize, 2, 3, 7, 64] {
            let width = 5;
            let mut out = vec![-1.0f32; rows * width];
            pool.for_row_blocks(rows, width, &mut out, |row0, blk| {
                let nrows = blk.len() / width;
                for r in 0..nrows {
                    for c in 0..width {
                        assert_eq!(blk[r * width + c], -1.0, "row visited twice");
                        blk[r * width + c] = (row0 + r) as f32;
                    }
                }
            });
            for r in 0..rows {
                for c in 0..width {
                    assert_eq!(out[r * width + c], r as f32, "row {r} missing or misrouted");
                }
            }
        }
    }

    #[test]
    fn drop_joins_all_workers_without_leaking() {
        let pool = ThreadPool::new(4);
        pool.broadcast(&|_| {});
        let shared = pool.shared.clone();
        drop(pool);
        // drop joined every worker thread, so ours is the only Arc left
        assert_eq!(Arc::strong_count(&shared), 1, "a pool worker outlived drop");
    }

    #[test]
    fn one_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut out = vec![0.0f32; 8];
        pool.for_row_blocks(4, 2, &mut out, |row0, blk| {
            for v in blk.iter_mut() {
                *v = row0 as f32 + 1.0;
            }
        });
        assert!(out.iter().all(|&v| v == 1.0), "inline path must see row0 == 0");
    }

    #[test]
    fn worker_panic_propagates_and_pool_stays_usable() {
        let pool = ThreadPool::new(3);
        let hit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.broadcast(&|wi| {
                if wi == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(hit.is_err(), "a worker panic must surface on the dispatcher");
        // the pool must still dispatch correctly afterwards
        let count = AtomicUsize::new(0);
        pool.broadcast(&|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn concurrent_dispatchers_are_serialized_safely() {
        let pool = ThreadPool::new(4);
        std::thread::scope(|s| {
            for t in 0..3 {
                let pool = &pool;
                s.spawn(move || {
                    let width = 8;
                    let rows = 16;
                    for round in 0..25 {
                        let mut out = vec![0.0f32; rows * width];
                        pool.for_row_blocks(rows, width, &mut out, |row0, blk| {
                            let nrows = blk.len() / width;
                            for r in 0..nrows {
                                for c in 0..width {
                                    blk[r * width + c] = (t * 1000 + round + row0 + r) as f32;
                                }
                            }
                        });
                        for r in 0..rows {
                            for c in 0..width {
                                assert_eq!(out[r * width + c], (t * 1000 + round + r) as f32);
                            }
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn resolve_threads_explicit_wins() {
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(1), 1);
        assert!(resolve_threads(0) >= 1, "auto must resolve to at least one thread");
    }
}
