//! Dynamic batching policy — pure logic, no I/O, fully propcheckable.
//!
//! Requests queue until either (a) `max_batch` are waiting or (b) the
//! oldest has waited `max_wait`; then a batch is released. The policy is
//! driven by an injected clock so tests control time. Released requests
//! are *admitted*, not necessarily fully ingested: on prefill-capable
//! backends the engine streams each admitted prompt into its lane over
//! subsequent ticks (`prefill_chunks_per_tick` chunks at a time), so a
//! released batch of long prompts does not stall the decode loop.
//!
//! # Example
//!
//! ```
//! use std::time::{Duration, Instant};
//! use linear_transformer::coordinator::batcher::Batcher;
//! use linear_transformer::coordinator::request::GenerateRequest;
//!
//! let mut b = Batcher::new(4, Duration::from_millis(10));
//! let t0 = Instant::now();
//! let req = GenerateRequest { id: 1, prompt: vec![3], max_new: 4, temperature: 0.0, top_k: 0 };
//! b.push(req, t0);
//! assert!(!b.ready(t0)); // underfull and before the deadline
//! let later = t0 + Duration::from_millis(10);
//! assert_eq!(b.poll(later, usize::MAX).len(), 1); // deadline releases it
//! ```

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::coordinator::request::GenerateRequest;

/// A queued request with its arrival time.
#[derive(Debug, Clone)]
struct Pending {
    req: GenerateRequest,
    arrived: Instant,
}

/// The batching policy.
#[derive(Debug)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
    queue: VecDeque<Pending>,
    /// total requests ever enqueued / released (conservation invariant)
    pub enqueued: u64,
    pub released: u64,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Batcher {
            max_batch,
            max_wait,
            queue: VecDeque::new(),
            enqueued: 0,
            released: 0,
        }
    }

    pub fn push(&mut self, req: GenerateRequest, now: Instant) {
        self.queue.push_back(Pending { req, arrived: now });
        self.enqueued += 1;
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Would `poll` release a batch at `now`?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.max_batch {
            return true;
        }
        now.duration_since(self.queue[0].arrived) >= self.max_wait
    }

    /// If the deadline has not fired, when will it?
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.front().map(|p| p.arrived + self.max_wait)
    }

    /// Release up to `capacity.min(max_batch)` requests if ready.
    /// FIFO order is preserved (no starvation).
    pub fn poll(&mut self, now: Instant, capacity: usize) -> Vec<GenerateRequest> {
        if capacity == 0 || !self.ready(now) {
            return Vec::new();
        }
        let n = self.queue.len().min(self.max_batch).min(capacity);
        let out: Vec<GenerateRequest> = self.queue.drain(..n).map(|p| p.req).collect();
        self.released += out.len() as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> GenerateRequest {
        GenerateRequest {
            id,
            prompt: vec![1],
            max_new: 4,
            temperature: 0.0,
            top_k: 0,
        }
    }

    #[test]
    fn releases_on_full_batch_immediately() {
        let mut b = Batcher::new(3, Duration::from_secs(10));
        let t0 = Instant::now();
        for i in 0..3 {
            b.push(req(i), t0);
        }
        assert!(b.ready(t0));
        let batch = b.poll(t0, usize::MAX);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch[0].id, 0); // FIFO
    }

    #[test]
    fn waits_for_deadline_when_underfull() {
        let mut b = Batcher::new(8, Duration::from_millis(100));
        let t0 = Instant::now();
        b.push(req(1), t0);
        assert!(!b.ready(t0));
        assert!(b.poll(t0, usize::MAX).is_empty());
        let later = t0 + Duration::from_millis(150);
        assert!(b.ready(later));
        assert_eq!(b.poll(later, usize::MAX).len(), 1);
    }

    #[test]
    fn respects_capacity() {
        let mut b = Batcher::new(4, Duration::from_millis(0));
        let t0 = Instant::now();
        for i in 0..4 {
            b.push(req(i), t0);
        }
        let batch = b.poll(t0, 2);
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn conservation_and_fifo_property() {
        let cases = crate::propcheck::default_cases();
        crate::propcheck::check("batcher-conservation-fifo", cases, |g| {
            let max_batch = g.usize_in(1, 8);
            let max_wait = Duration::from_millis(g.usize_in(0, 50) as u64);
            let mut b = Batcher::new(max_batch, max_wait);
            let t0 = Instant::now();
            let mut next_id = 0u64;
            let mut released_ids = Vec::new();
            let mut now = t0;
            for _ in 0..g.usize_in(1, 40) {
                // random interleaving of pushes, time advances, polls
                match g.usize_in(0, 2) {
                    0 => {
                        b.push(req(next_id), now);
                        next_id += 1;
                    }
                    1 => now += Duration::from_millis(g.usize_in(0, 30) as u64),
                    _ => {
                        let cap = g.usize_in(0, 10);
                        let batch = b.poll(now, cap);
                        if batch.len() > max_batch.min(cap) {
                            return Err(format!(
                                "batch of {} exceeds max_batch {} / cap {}",
                                batch.len(),
                                max_batch,
                                cap
                            ));
                        }
                        released_ids.extend(batch.iter().map(|r| r.id));
                    }
                }
            }
            // drain completely
            now += max_wait + Duration::from_millis(1);
            loop {
                let batch = b.poll(now, usize::MAX);
                if batch.is_empty() {
                    break;
                }
                released_ids.extend(batch.iter().map(|r| r.id));
            }
            // conservation: everything enqueued is eventually released once
            if released_ids.len() as u64 != b.enqueued {
                return Err(format!(
                    "released {} of {} enqueued",
                    released_ids.len(),
                    b.enqueued
                ));
            }
            if b.enqueued != b.released {
                return Err("counter mismatch".into());
            }
            // FIFO: ids must come out strictly increasing
            for w in released_ids.windows(2) {
                if w[0] >= w[1] {
                    return Err(format!("out of order: {} then {}", w[0], w[1]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_starvation_property() {
        // any request is released within max_wait once polls happen
        crate::propcheck::check("batcher-no-starvation", 40, |g| {
            let max_wait = Duration::from_millis(20);
            let mut b = Batcher::new(16, max_wait);
            let t0 = Instant::now();
            b.push(req(0), t0);
            // adversarial: keep polling *before* the deadline with tiny caps
            let mut now = t0;
            for _ in 0..g.usize_in(0, 5) {
                now += Duration::from_millis(3);
                let _ = b.poll(now, 1);
            }
            // after the deadline the request must come out
            now = t0 + max_wait;
            let batch = b.poll(now, 1);
            if batch.len() != 1 {
                return Err("request starved past its deadline".into());
            }
            Ok(())
        });
    }
}
