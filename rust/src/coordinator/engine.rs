//! The serving engine: worker thread + continuous batching decode loop.
//!
//! Two interchangeable engines implement the same submit/response API:
//!
//! * [`NativeEngine`] — decodes with the pure-rust [`crate::nn`] model.
//!   One `DecodeSession` per slot; a tick advances every active slot by
//!   one token. Because linear attention's decode state is O(1) per slot,
//!   admission never requires eviction or cache planning.
//! * [`PjrtEngine`] — decodes with a batched `*_decode_linear_b<B>` AOT
//!   artifact through the PJRT runtime. All slots advance in one XLA
//!   execution per tick; per-slot positions ride in the `in:pos` vector
//!   (this is why the artifact takes pos as [B]).
//!
//! PJRT handles are not `Send`, so the PJRT engine constructs its
//! `Runtime` *inside* the worker thread; only plain data crosses.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::attention::AttentionKind;
use crate::config::{ModelConfig, ServeConfig};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::request::{GenerateRequest, GenerateResponse};
use crate::coordinator::sessions::{SlotInfo, SlotTable};
use crate::metrics::LatencyRecorder;
use crate::nn::TransformerLM;
use crate::rng::Rng;
use crate::runtime::{Runtime, Value};
use crate::sampling::sample_logits;

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub requests: u64,
    pub completed: u64,
    pub tokens_generated: u64,
    pub ticks: u64,
    pub batch_occupancy_sum: u64,
    pub latency: LatencyRecorder,
}

impl EngineStats {
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.ticks == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.ticks as f64
        }
    }
}

enum Msg {
    Request(GenerateRequest, Sender<GenerateResponse>),
    Shutdown,
}

/// Handle for submitting work to a running engine.
pub struct EngineHandle {
    tx: Sender<Msg>,
    stats: Arc<Mutex<EngineStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl EngineHandle {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: GenerateRequest) -> Receiver<GenerateResponse> {
        let (tx, rx) = channel();
        self.tx
            .send(Msg::Request(req, tx))
            .expect("engine worker gone");
        rx
    }

    /// Submit and wait.
    pub fn generate_blocking(&self, req: GenerateRequest) -> GenerateResponse {
        self.submit(req).recv().expect("engine dropped response")
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.lock().unwrap().clone()
    }

    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// native engine
// ---------------------------------------------------------------------------

/// Serving engine over the pure-rust model.
pub struct NativeEngine;

impl NativeEngine {
    /// Spawn the worker; the model moves into the thread.
    pub fn spawn(model: TransformerLM, cfg: ServeConfig) -> anyhow::Result<EngineHandle> {
        cfg.validate()?;
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(Mutex::new(EngineStats::default()));
        let stats_w = stats.clone();
        let worker = std::thread::Builder::new()
            .name("lintra-native-engine".into())
            .spawn(move || native_worker(model, cfg, rx, stats_w))?;
        Ok(EngineHandle {
            tx,
            stats,
            worker: Some(worker),
        })
    }
}

fn native_worker(
    model: TransformerLM,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<EngineStats>>,
) {
    assert_eq!(
        model.kind,
        AttentionKind::Linear,
        "the native engine decodes with the linear-RNN backend"
    );
    let mut batcher = Batcher::new(cfg.max_batch, Duration::from_micros(cfg.max_wait_us));
    let mut slots = SlotTable::new(cfg.max_batch);
    let mut sessions: Vec<Option<crate::nn::DecodeSession>> =
        (0..cfg.max_batch).map(|_| None).collect();
    let mut responders: std::collections::HashMap<u64, Sender<GenerateResponse>> =
        std::collections::HashMap::new();
    let mut rng = Rng::new(cfg.seed);
    let mut shutdown = false;

    while !shutdown || slots.active() > 0 || batcher.pending() > 0 {
        // 1. ingest requests (block only when totally idle)
        let idle = slots.active() == 0 && batcher.pending() == 0;
        loop {
            let msg = if idle && !shutdown {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            } else {
                match rx.try_recv() {
                    Ok(m) => Some(m),
                    Err(_) => None,
                }
            };
            match msg {
                Some(Msg::Request(req, resp_tx)) => {
                    responders.insert(req.id, resp_tx);
                    stats.lock().unwrap().requests += 1;
                    batcher.push(req, Instant::now());
                    continue; // drain any further queued messages
                }
                Some(Msg::Shutdown) => {
                    shutdown = true;
                    continue;
                }
                None => break,
            }
        }

        // 2. admit from the batcher into free slots
        let now = Instant::now();
        let capacity = cfg.max_batch - slots.active();
        for req in batcher.poll(now, capacity) {
            let prompt = req.prompt.clone();
            let idx = slots
                .alloc(SlotInfo {
                    request_id: req.id,
                    started: now,
                    prompt_left: prompt,
                    generated: Vec::new(),
                    max_new: req.max_new,
                    temperature: req.temperature,
                    pos: 0,
                })
                .expect("capacity checked");
            sessions[idx] = Some(model.session());
        }

        if slots.active() == 0 {
            continue;
        }

        // 3. one decode tick: advance every active slot by one token
        let active = slots.active_indices();
        {
            let mut st = stats.lock().unwrap();
            st.ticks += 1;
            st.batch_occupancy_sum += active.len() as u64;
        }
        let mut finished: Vec<usize> = Vec::new();
        for idx in active {
            let info = slots.get_mut(idx).unwrap();
            let sess = sessions[idx].as_mut().unwrap();
            let token = if !info.prompt_left.is_empty() {
                info.prompt_left.remove(0)
            } else {
                *info.generated.last().unwrap()
            };
            let logits = sess.step(token);
            info.pos += 1;
            if info.prompt_left.is_empty() {
                let next = sample_logits(&logits, info.temperature, &mut rng);
                info.generated.push(next);
                stats.lock().unwrap().tokens_generated += 1;
                let at_len_cap = info.pos + 1 >= model.cfg.max_len;
                if info.generated.len() >= info.max_new || at_len_cap {
                    finished.push(idx);
                }
            }
        }

        // 4. complete finished slots
        for idx in finished {
            let info = slots.release(idx).unwrap();
            sessions[idx] = None;
            let latency = info.started.elapsed();
            {
                let mut st = stats.lock().unwrap();
                st.completed += 1;
                st.latency.record(latency);
            }
            if let Some(tx) = responders.remove(&info.request_id) {
                let _ = tx.send(GenerateResponse {
                    id: info.request_id,
                    tokens: info.generated,
                    latency_us: latency.as_micros() as u64,
                    error: None,
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT engine
// ---------------------------------------------------------------------------

/// Serving engine over a batched AOT decode artifact.
pub struct PjrtEngine;

/// Parameters identifying the artifact the PJRT engine decodes with.
#[derive(Clone, Debug)]
pub struct PjrtEngineSpec {
    pub artifacts_dir: String,
    /// e.g. "mnist" — uses `<task>_decode_linear_b<max_batch>`
    pub task: String,
    pub model_cfg: ModelConfig,
}

impl PjrtEngine {
    pub fn spawn(spec: PjrtEngineSpec, cfg: ServeConfig) -> anyhow::Result<EngineHandle> {
        cfg.validate()?;
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let stats = Arc::new(Mutex::new(EngineStats::default()));
        let stats_w = stats.clone();
        let worker = std::thread::Builder::new()
            .name("lintra-pjrt-engine".into())
            .spawn(move || pjrt_worker(spec, cfg, rx, stats_w, ready_tx))?;
        // surface artifact-loading errors synchronously
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("pjrt worker died during startup"))??;
        Ok(EngineHandle {
            tx,
            stats,
            worker: Some(worker),
        })
    }
}

fn pjrt_worker(
    spec: PjrtEngineSpec,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<EngineStats>>,
    ready: Sender<anyhow::Result<()>>,
) {
    // Build everything PJRT inside the worker (handles are not Send).
    let setup = (|| -> anyhow::Result<_> {
        let mut rt = Runtime::open(&spec.artifacts_dir)?;
        let art_name = format!("{}_decode_linear_b{}", spec.task, cfg.max_batch);
        let artifact = rt.load(&art_name)?;
        let model_key = format!("{}_linear", spec.task);
        let weights = rt.load_weights(&model_key)?;
        let model_spec = rt
            .bundle
            .model(&model_key)
            .ok_or_else(|| anyhow::anyhow!("model {model_key} missing"))?
            .clone();
        // params in manifest order
        let params: Vec<Value> = model_spec
            .params
            .iter()
            .map(|n| Value::from_tensor(weights.req(n)))
            .collect();
        Ok((artifact, params))
    })();
    let (artifact, params) = match setup {
        Ok(x) => {
            let _ = ready.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    let mcfg = &spec.model_cfg;
    let b = cfg.max_batch;
    let (l, h, dh) = (mcfg.n_layers, mcfg.n_heads, mcfg.d_head());
    let s_shape = vec![l, b, h, dh, dh];
    let z_shape = vec![l, b, h, dh];
    let mut s = vec![0.0f32; l * b * h * dh * dh];
    let mut z = vec![0.0f32; l * b * h * dh];
    let mut token = vec![0i32; b];
    let mut pos = vec![0i32; b];

    let mut batcher = Batcher::new(b, Duration::from_micros(cfg.max_wait_us));
    let mut slots = SlotTable::new(b);
    let mut responders: std::collections::HashMap<u64, Sender<GenerateResponse>> =
        std::collections::HashMap::new();
    let mut rng = Rng::new(cfg.seed);
    let mut shutdown = false;

    // zero one slot's stripes in (s, z)
    let clear_slot = |s: &mut [f32], z: &mut [f32], slot: usize| {
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * b + slot) * h + hi) * dh * dh;
                s[base..base + dh * dh].fill(0.0);
                let zbase = ((li * b + slot) * h + hi) * dh;
                z[zbase..zbase + dh].fill(0.0);
            }
        }
    };

    while !shutdown || slots.active() > 0 || batcher.pending() > 0 {
        let idle = slots.active() == 0 && batcher.pending() == 0;
        loop {
            let msg = if idle && !shutdown {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                }
            } else {
                rx.try_recv().ok()
            };
            match msg {
                Some(Msg::Request(req, resp_tx)) => {
                    responders.insert(req.id, resp_tx);
                    stats.lock().unwrap().requests += 1;
                    batcher.push(req, Instant::now());
                    continue;
                }
                Some(Msg::Shutdown) => {
                    shutdown = true;
                    continue;
                }
                None => break,
            }
        }

        let now = Instant::now();
        let capacity = b - slots.active();
        for req in batcher.poll(now, capacity) {
            let idx = slots
                .alloc(SlotInfo {
                    request_id: req.id,
                    started: now,
                    prompt_left: req.prompt.clone(),
                    generated: Vec::new(),
                    max_new: req.max_new,
                    temperature: req.temperature,
                    pos: 0,
                })
                .expect("capacity checked");
            clear_slot(&mut s, &mut z, idx);
            pos[idx] = 0;
        }

        if slots.active() == 0 {
            continue;
        }

        // build the tick inputs: per-slot next token
        let active = slots.active_indices();
        for &idx in &active {
            let info = slots.get_mut(idx).unwrap();
            token[idx] = if !info.prompt_left.is_empty() {
                info.prompt_left.remove(0) as i32
            } else {
                *info.generated.last().unwrap() as i32
            };
            pos[idx] = info.pos as i32;
        }
        {
            let mut st = stats.lock().unwrap();
            st.ticks += 1;
            st.batch_occupancy_sum += active.len() as u64;
        }

        // assemble artifact inputs: params..., token, pos, s, z
        let mut inputs = params.clone();
        inputs.push(Value::I32(vec![b], token.clone()));
        inputs.push(Value::I32(vec![b], pos.clone()));
        inputs.push(Value::F32(s_shape.clone(), s.clone()));
        inputs.push(Value::F32(z_shape.clone(), z.clone()));
        let outputs = match artifact.run(&inputs) {
            Ok(o) => o,
            Err(e) => {
                // fail all active requests
                for idx in active {
                    if let Some(info) = slots.release(idx) {
                        if let Some(tx) = responders.remove(&info.request_id) {
                            let _ = tx.send(GenerateResponse {
                                id: info.request_id,
                                tokens: info.generated,
                                latency_us: 0,
                                error: Some(format!("decode failed: {e}")),
                            });
                        }
                    }
                }
                continue;
            }
        };
        let logits = outputs[0].as_f32().unwrap();
        let vocab = mcfg.vocab;
        s.copy_from_slice(outputs[1].as_f32().unwrap());
        z.copy_from_slice(outputs[2].as_f32().unwrap());

        let mut finished: Vec<usize> = Vec::new();
        for &idx in &active {
            let info = slots.get_mut(idx).unwrap();
            info.pos += 1;
            if info.prompt_left.is_empty() {
                let row = &logits[idx * vocab..(idx + 1) * vocab];
                let next = sample_logits(row, info.temperature, &mut rng);
                info.generated.push(next);
                stats.lock().unwrap().tokens_generated += 1;
                if info.generated.len() >= info.max_new || info.pos + 1 >= mcfg.max_len {
                    finished.push(idx);
                }
            }
        }
        for idx in finished {
            let info = slots.release(idx).unwrap();
            let latency = info.started.elapsed();
            {
                let mut st = stats.lock().unwrap();
                st.completed += 1;
                st.latency.record(latency);
            }
            if let Some(tx) = responders.remove(&info.request_id) {
                let _ = tx.send(GenerateResponse {
                    id: info.request_id,
                    tokens: info.generated,
                    latency_us: latency.as_micros() as u64,
                    error: None,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny_model() -> TransformerLM {
        let cfg = ModelConfig {
            vocab: 11,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            max_len: 64,
            d_ff: 64,
            chunk: 16,
            causal: true,
            lsh_rounds: 1,
            lsh_buckets: 8,
            lsh_chunk: 8,
        };
        TransformerLM::init(&cfg, AttentionKind::Linear, 0)
    }

    #[test]
    fn serves_single_request() {
        let handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 1,
            prompt: vec![1, 2, 3],
            max_new: 5,
            temperature: 0.0,
        });
        assert_eq!(resp.id, 1);
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.error.is_none());
        let st = handle.stats();
        assert_eq!(st.completed, 1);
        assert_eq!(st.tokens_generated, 5);
        handle.shutdown();
    }

    #[test]
    fn serves_concurrent_requests_batched() {
        let handle = NativeEngine::spawn(
            tiny_model(),
            ServeConfig {
                max_batch: 4,
                max_wait_us: 2000,
                ..Default::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                handle.submit(GenerateRequest {
                    id: i,
                    prompt: vec![1, (i % 10) as u32],
                    max_new: 6,
                    temperature: 0.0,
                })
            })
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens.len(), 6);
            ids.push(resp.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
        let st = handle.stats();
        assert_eq!(st.completed, 8);
        // batching actually happened: mean occupancy > 1
        assert!(
            st.mean_batch_occupancy() > 1.0,
            "occupancy {}",
            st.mean_batch_occupancy()
        );
        handle.shutdown();
    }

    #[test]
    fn deterministic_greedy_responses_match_direct_generation() {
        let model = tiny_model();
        let direct = model.generate(&[1, 2, 3], 5, 0.0, 0);
        let handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 9,
            prompt: vec![1, 2, 3],
            max_new: 5,
            temperature: 0.0,
        });
        assert_eq!(resp.tokens, direct);
        handle.shutdown();
    }

    #[test]
    fn respects_max_len() {
        let model = tiny_model();
        let max_len = model.cfg.max_len;
        let handle = NativeEngine::spawn(model, ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 2,
            prompt: vec![1; 10],
            max_new: 10_000,
            temperature: 0.0,
        });
        assert!(resp.tokens.len() <= max_len - 10);
        handle.shutdown();
    }
}
