//! The serving engine: worker thread + continuous batching decode loop.
//!
//! One generic tick loop (`run_engine`) drives any [`DecodeBackend`]:
//! a backend owns a set of dense decode *lanes* (0..lanes), each holding
//! one request's fixed-size RNN state (S, Z — eqs 16-20), and advances
//! every lane by one token per [`DecodeBackend::step_batch`] call. Because
//! the paper's decode state is O(1) per lane, admission is "append a
//! zeroed row" and eviction is "swap-remove compaction" — no paged KV
//! cache, no prefix planning, and the whole batch stays contiguous so the
//! per-tick work is a handful of `[B, ·]` GEMMs.
//!
//! Prompt ingestion is an *incremental prefill* phase when the backend
//! supports it ([`DecodeBackend::prefill_partial`]): the linear-attention
//! recurrence makes prefill a cumulative-state scan, so a prompt can be
//! paused and resumed at any chunk boundary. The engine exploits that by
//! treating prefill as a first-class, resumable scheduler state: an
//! admitted slot occupies a lane in the *prefill suffix* of the lane
//! array and absorbs at most `prefill_chunks_per_tick` fixed-size chunks
//! per tick, interleaved with the decode tick of the resident lanes (the
//! *decode prefix*, the only lanes [`DecodeBackend::step_batch`] sees).
//! The vocab-sized lm-head runs only for the final prompt position; when
//! it lands, the first token is sampled right there and the lane is
//! swapped into the decode prefix ([`DecodeBackend::swap_lanes`]) — or
//! retired on the spot for `max_new == 1` / max_len-filling prompts. A
//! long prompt therefore costs O(prompt_len / chunk) GEMM blocks spread
//! across ticks: time-to-first-token no longer scales with the engine
//! tick rate, *and* resident decode lanes keep producing one token per
//! tick at a flat cadence while it streams in (the
//! [`crate::metrics::TickLatencySplit`] in [`EngineStats`] measures
//! exactly this). Every schedule produces bit-identical logits — chunked,
//! one-shot, and per-tick ingestion share the same per-position float-op
//! order — so greedy (temperature 0) outputs never depend on the
//! schedule. (With temperature > 0 the worker's sampling RNG draws in
//! schedule order, so sampled streams vary with scheduling, as they
//! always have with batch composition.) Backends without the path (PJRT
//! today) fall back to the per-tick cursor walk.
//!
//! Because that recurrent state is *fixed-size*, a lane is also
//! snapshottable: [`DecodeBackend::snapshot_lane`] /
//! [`DecodeBackend::restore_lane`] move one lane's complete state in
//! and out as a [`crate::nn::LaneSnapshot`]. With `--state-cache-mb`
//! (or `LINTRA_STATE_CACHE_MB`) the engine keeps a **prefix-reuse state
//! cache** ([`crate::coordinator::state_cache::StateCache`]) on top of
//! those hooks: as a prompt streams in, the lane is snapshotted at
//! prefill-chunk boundaries whose prefix has been *seen before*
//! (second-chance admission — a first-ever prefix only registers its
//! running hash, so one-off prompts never pay the snapshot copy),
//! keyed by the exact token prefix; at admission the cache is
//! consulted and the longest cached prefix of the new prompt is
//! restored into the fresh lane, so only the non-shared suffix is
//! prefilled. Restore is a memcpy and
//! bit-identical to having prefilled the prefix in place, so a cache
//! hit can never change a logit — it only deletes ingestion work
//! (`EngineStats::prompt_tokens_skipped` counts how much). Two knobs
//! bound admission work per tick: `prefill_chunks_per_tick` (per slot)
//! and `prefill_chunk_budget` (global across all admitting slots).
//!
//! Two backends implement the trait:
//!
//! * the **native** backend — [`crate::nn::BatchedDecodeSession`], the
//!   pure-rust structure-of-arrays decode path. All slots advance through
//!   single batched GEMMs per projection instead of per-slot GEMV loops.
//! * `PjrtBackend` — a batched `*_decode_linear_b<B>` AOT artifact
//!   through the PJRT runtime. All slots advance in one XLA execution per
//!   tick; per-slot positions ride in the `in:pos` vector. The host-side
//!   (s, z) blocks are compacted with the same lane discipline.
//!
//! PJRT handles are not `Send`, so the PJRT engine constructs its
//! `Runtime` *inside* the worker thread; only plain data crosses.
//!
//! # Example
//!
//! ```no_run
//! use linear_transformer::attention::AttentionKind;
//! use linear_transformer::config::{ModelConfig, ServeConfig};
//! use linear_transformer::coordinator::engine::NativeEngine;
//! use linear_transformer::coordinator::request::GenerateRequest;
//! use linear_transformer::nn::TransformerLM;
//!
//! let model = TransformerLM::init(&ModelConfig::small_copy(), AttentionKind::Linear, 0);
//! let mut engine = NativeEngine::spawn(model, ServeConfig::default()).unwrap();
//! let resp = engine.generate_blocking(GenerateRequest {
//!     id: 1,
//!     prompt: vec![12, 3, 4],
//!     max_new: 16,
//!     temperature: 0.0,
//!     top_k: 0,
//! });
//! assert!(resp.error.is_none());
//! engine.shutdown();
//! ```

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::attention::AttentionKind;
use crate::config::{resolve_state_cache_mb, ModelConfig, ServeConfig};
use crate::coordinator::batcher::Batcher;
use crate::coordinator::request::{GenerateRequest, GenerateResponse};
use crate::coordinator::sessions::{SlotInfo, SlotPhase, SlotTable};
use crate::coordinator::state_cache::StateCache;
use crate::metrics::{LatencyRecorder, StateCacheCounters, TickLatencySplit};
use crate::nn::{BatchedDecodeSession, BatchedSoftmaxSession, LaneSnapshot, TransformerLM};
use crate::parallel::lock_unpoisoned;
use crate::propcheck::engine_invariants;
use crate::rng::Rng;
use crate::runtime::{Runtime, Value};
use crate::sampling::sample_logits_topk;

/// Aggregate serving statistics.
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub requests: u64,
    pub completed: u64,
    pub tokens_generated: u64,
    pub ticks: u64,
    /// Ticks that ingested at least one prompt chunk (a subset of
    /// `ticks`; the rest were pure decode ticks).
    pub prefill_ticks: u64,
    /// Prompt tokens absorbed through the incremental prefill path.
    pub prompt_tokens_ingested: u64,
    /// Prompt tokens *not* prefilled because a cached prefix snapshot
    /// was restored instead (the prefix-reuse cache's win; disjoint
    /// from `prompt_tokens_ingested`).
    pub prompt_tokens_skipped: u64,
    /// Prefix-reuse state-cache consultations and evictions (all zero
    /// when the cache is off).
    pub state_cache: StateCacheCounters,
    pub batch_occupancy_sum: u64,
    /// End-to-end request latency (admission to completion).
    pub latency: LatencyRecorder,
    /// Per-tick wall time, split into prefill-carrying vs pure-decode
    /// ticks — the evidence that resident decode latency stays flat
    /// while long prompts admit.
    pub tick_latency: TickLatencySplit,
}

impl EngineStats {
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.ticks == 0 {
            0.0
        } else {
            self.batch_occupancy_sum as f64 / self.ticks as f64
        }
    }
}

enum Msg {
    Request(GenerateRequest, Sender<GenerateResponse>),
    Shutdown,
}

/// Handle for submitting work to a running engine.
pub struct EngineHandle {
    tx: Sender<Msg>,
    stats: Arc<Mutex<EngineStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl EngineHandle {
    /// Submit a request; returns a receiver for the response.
    ///
    /// Never panics: if the worker has shut down or died, the receiver
    /// yields an error [`GenerateResponse`] instead — a TCP connection
    /// thread calling this must not take the whole server down with it.
    pub fn submit(&self, req: GenerateRequest) -> Receiver<GenerateResponse> {
        let (tx, rx) = channel();
        if let Err(std::sync::mpsc::SendError(msg)) = self.tx.send(Msg::Request(req, tx)) {
            // the worker's receiver is gone; recover the responder from
            // the bounced message and answer with an error
            if let Msg::Request(req, tx) = msg {
                let _ = tx.send(engine_gone_response(req.id));
            }
        }
        rx
    }

    /// Submit and wait. Like [`Self::submit`], resolves to an error
    /// response (not a panic) if the worker is gone.
    pub fn generate_blocking(&self, req: GenerateRequest) -> GenerateResponse {
        let id = req.id;
        self.submit(req)
            .recv()
            .unwrap_or_else(|_| engine_gone_response(id))
    }

    pub fn stats(&self) -> EngineStats {
        // unpoisoned: stats are plain counters, and a panicked reader
        // elsewhere must not wedge every future stats() call
        lock_unpoisoned(&self.stats).clone()
    }

    /// Stop the worker and wait for it to drain. Idempotent; the handle
    /// stays usable afterwards (submissions resolve to error responses).
    pub fn shutdown(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

/// The response a request resolves to when the engine worker is gone.
pub(crate) fn engine_gone_response(id: u64) -> GenerateResponse {
    GenerateResponse {
        id,
        tokens: Vec::new(),
        latency_us: 0,
        truncated: false,
        error: Some("engine unavailable: worker has shut down".to_string()),
    }
}

impl Drop for EngineHandle {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// the decode-backend abstraction
// ---------------------------------------------------------------------------

/// A batched decoder the engine ticks: a set of dense lanes (0..lanes),
/// each one request's O(1) recurrent decode state, advanced one token per
/// call. Implementations keep lanes contiguous; the engine mirrors the
/// lane order in its own slot map and relies on swap-remove semantics.
///
/// A backend may additionally offer a *resumable prefill* path
/// ([`Self::prefill_partial`]): prompt slices absorbed into one lane's
/// cumulative state across multiple calls, so a prompt costs
/// O(prompt_len / chunk) GEMM blocks — scheduled a bounded amount per
/// tick — instead of occupying a decode lane for `prompt_len` ticks of
/// the shared loop. Prefill-capable backends must also support *prefix
/// stepping* ([`Self::step_batch`] with fewer tokens than lanes) and
/// lane swaps ([`Self::swap_lanes`]): the engine keeps actively decoding
/// lanes as a contiguous prefix `0..n_dec` and mid-prefill lanes as the
/// suffix `n_dec..lanes`, so one `step_batch` call advances exactly the
/// resident lanes while prompts stream into the suffix.
pub trait DecodeBackend {
    /// Vocabulary size of the logits rows.
    fn vocab(&self) -> usize;

    /// Maximum sequence position a lane may reach.
    fn max_len(&self) -> usize;

    /// Number of live lanes.
    fn lanes(&self) -> usize;

    /// Append a fresh lane with zeroed state at position 0.
    fn alloc_lane(&mut self) -> anyhow::Result<usize>;

    /// Free `lane`, compacting by moving the last lane into its place.
    /// Returns the moved lane's previous index (`None` if `lane` was last).
    fn free_lane(&mut self, lane: usize) -> Option<usize>;

    /// Advance the first `tokens.len()` lanes by one token (`tokens[r]`
    /// feeds lane r), leaving lanes `tokens.len()..lanes()` untouched —
    /// the engine parks mid-prefill lanes there. Fills `logits` with
    /// `[tokens.len() * vocab]` row-major values, replacing its previous
    /// contents — the engine keeps one buffer alive across ticks so the
    /// steady-state decode loop allocates nothing. Backends reporting
    /// [`Self::supports_prefill`] `== false` never see a partial width
    /// and may require `tokens.len() == lanes()`.
    fn step_batch(&mut self, tokens: &[u32], logits: &mut Vec<f32>) -> anyhow::Result<()>;

    /// True if [`Self::prefill_partial`] ingests prompts chunk by chunk.
    fn supports_prefill(&self) -> bool {
        false
    }

    /// The backend's natural prefill granularity in tokens: the engine
    /// slices prompts into chunks of this size, and
    /// `prefill_chunks_per_tick` is counted in these units. Only
    /// meaningful when [`Self::supports_prefill`] reports true; a
    /// backend built around a different quantum (e.g. an AOT artifact
    /// compiled for a fixed slice length) overrides this.
    fn prefill_chunk(&self) -> usize {
        crate::nn::PREFILL_CHUNK
    }

    /// Resumable prefill hook: absorb `chunk` — the next slice of a
    /// prompt — into lane `lane`'s state, continuing from the lane's
    /// current position. `finish` marks the slice carrying the final
    /// prompt token; only that call produces logits — it fills `logits`
    /// with `[vocab]` values (previous contents replaced; what the first
    /// generated token is sampled from) and returns `Ok(true)`. Interior
    /// slices skip the vocab-sized lm-head entirely, leave `logits`
    /// cleared, and return `Ok(false)`. The engine keeps one `logits`
    /// buffer alive across chunks, so steady-state prefill allocates
    /// nothing. Slicing must not change results: any chunking of a
    /// prompt, including one-shot, must produce bit-identical state and
    /// logits. Only invoked when [`Self::supports_prefill`] reports
    /// true; the default is a hard error so backends without the path
    /// fall back to per-tick prompt feeding in the engine.
    fn prefill_partial(
        &mut self,
        lane: usize,
        chunk: &[u32],
        finish: bool,
        logits: &mut Vec<f32>,
    ) -> anyhow::Result<bool> {
        let _ = (lane, chunk, finish, logits);
        anyhow::bail!("this backend has no prefill path")
    }

    /// Swap lanes `a` and `b` (state and position) in place. The engine
    /// only calls this on prefill-capable backends, to move a lane whose
    /// prompt just finished into the decode prefix (and to keep the
    /// prefix contiguous when a resident lane retires); the default
    /// therefore panics — implement it whenever
    /// [`Self::supports_prefill`] reports true.
    fn swap_lanes(&mut self, a: usize, b: usize) {
        let _ = (a, b);
        // lintra: allow(panic) -- contract default: never reached when supports_prefill is false
        unreachable!("swap_lanes is only invoked on prefill-capable backends")
    }

    /// True if [`Self::snapshot_lane`] / [`Self::restore_lane`] are
    /// implemented. Together with [`Self::supports_prefill`] this is
    /// the prerequisite for the engine's prefix-reuse state cache.
    fn supports_snapshot(&self) -> bool {
        false
    }

    /// Export `lane`'s complete recurrent state (every layer×head (S, Z)
    /// pair plus the position cursor) as a [`LaneSnapshot`]. The lane is
    /// untouched; the snapshot is an exact-bits copy, O(state-per-lane).
    /// `None` when the backend has no snapshot path.
    fn snapshot_lane(&self, lane: usize) -> Option<LaneSnapshot> {
        let _ = lane;
        None
    }

    /// Overwrite `lane`'s state and position from a snapshot previously
    /// produced by [`Self::snapshot_lane`] **on this backend** (the
    /// engine never crosses backends or model geometries). After the
    /// restore the lane must be bit-identical to having prefilled the
    /// snapshot's tokens in place, so any continuation produces the
    /// exact logits of a cold full prefill — the invariant the
    /// prefix-reuse cache's correctness rests on.
    fn restore_lane(&mut self, lane: usize, snap: &LaneSnapshot) -> anyhow::Result<()> {
        let _ = (lane, snap);
        anyhow::bail!("this backend has no snapshot path")
    }
}

impl DecodeBackend for BatchedDecodeSession<'_> {
    fn vocab(&self) -> usize {
        BatchedDecodeSession::vocab(self)
    }

    fn max_len(&self) -> usize {
        BatchedDecodeSession::max_len(self)
    }

    fn lanes(&self) -> usize {
        self.rows()
    }

    fn alloc_lane(&mut self) -> anyhow::Result<usize> {
        self.alloc_row()
            .ok_or_else(|| anyhow::anyhow!("native decode capacity exhausted"))
    }

    fn free_lane(&mut self, lane: usize) -> Option<usize> {
        self.free_row(lane)
    }

    fn step_batch(&mut self, tokens: &[u32], logits: &mut Vec<f32>) -> anyhow::Result<()> {
        BatchedDecodeSession::step_batch_into(self, tokens, logits);
        Ok(())
    }

    fn supports_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(&self) -> usize {
        crate::nn::PREFILL_CHUNK
    }

    fn prefill_partial(
        &mut self,
        lane: usize,
        chunk: &[u32],
        finish: bool,
        logits: &mut Vec<f32>,
    ) -> anyhow::Result<bool> {
        Ok(self.prefill_row_partial_into(lane, chunk, finish, logits))
    }

    fn swap_lanes(&mut self, a: usize, b: usize) {
        self.swap_rows(a, b)
    }

    fn supports_snapshot(&self) -> bool {
        true
    }

    fn snapshot_lane(&self, lane: usize) -> Option<LaneSnapshot> {
        Some(self.export_lane(lane))
    }

    fn restore_lane(&mut self, lane: usize, snap: &LaneSnapshot) -> anyhow::Result<()> {
        // import_lane asserts geometry; the engine only restores
        // snapshots this very session exported, so the contract holds
        self.import_lane(lane, snap);
        Ok(())
    }
}

impl DecodeBackend for BatchedSoftmaxSession<'_> {
    fn vocab(&self) -> usize {
        BatchedSoftmaxSession::vocab(self)
    }

    fn max_len(&self) -> usize {
        BatchedSoftmaxSession::max_len(self)
    }

    fn lanes(&self) -> usize {
        self.rows()
    }

    fn alloc_lane(&mut self) -> anyhow::Result<usize> {
        self.alloc_row()
            .ok_or_else(|| anyhow::anyhow!("native decode capacity exhausted"))
    }

    fn free_lane(&mut self, lane: usize) -> Option<usize> {
        self.free_row(lane)
    }

    fn step_batch(&mut self, tokens: &[u32], logits: &mut Vec<f32>) -> anyhow::Result<()> {
        BatchedSoftmaxSession::step_batch_into(self, tokens, logits);
        Ok(())
    }

    fn supports_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(&self) -> usize {
        crate::nn::PREFILL_CHUNK
    }

    fn prefill_partial(
        &mut self,
        lane: usize,
        chunk: &[u32],
        finish: bool,
        logits: &mut Vec<f32>,
    ) -> anyhow::Result<bool> {
        Ok(self.prefill_row_partial_into(lane, chunk, finish, logits))
    }

    fn swap_lanes(&mut self, a: usize, b: usize) {
        self.swap_rows(a, b)
    }

    fn supports_snapshot(&self) -> bool {
        true
    }

    fn snapshot_lane(&self, lane: usize) -> Option<LaneSnapshot> {
        // O(cached tokens) payload, unlike the linear backend's O(1):
        // LaneSnapshot::bytes reports the true size, so the state
        // cache's LRU budget evicts honestly under the bigger entries
        Some(self.export_lane(lane))
    }

    fn restore_lane(&mut self, lane: usize, snap: &LaneSnapshot) -> anyhow::Result<()> {
        // import_lane asserts geometry; the engine only restores
        // snapshots this very session exported, so the contract holds
        self.import_lane(lane, snap);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// the shared tick loop
// ---------------------------------------------------------------------------

/// Reply to a request with a failure, if its responder is still waiting.
/// Takes `impl Into<String>` so the static rejection messages on the
/// tick path stay `&'static str` at the call sites — the conversion
/// happens here, only when a responder is actually waiting (failure
/// paths are cold; the hot tick never reaches this).
fn send_failure(
    responders: &mut std::collections::HashMap<u64, Sender<GenerateResponse>>,
    id: u64,
    tokens: Vec<u32>,
    msg: impl Into<String>,
) {
    if let Some(tx) = responders.remove(&id) {
        let _ = tx.send(GenerateResponse {
            id,
            tokens,
            latency_us: 0,
            truncated: false,
            error: Some(msg.into()),
        });
    }
}

/// Drive a backend until shutdown: ingest, admit into lanes, stream
/// queued prompts into the prefill suffix a bounded number of chunks per
/// tick, tick the decode prefix by one token, retire finished slots with
/// swap-remove compaction.
fn run_engine<B: DecodeBackend>(
    backend: &mut B,
    cfg: &ServeConfig,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<EngineStats>>,
) {
    let max_batch = cfg.max_batch;
    let mut batcher = Batcher::new(max_batch, Duration::from_micros(cfg.max_wait_us));
    let mut slots = SlotTable::new(max_batch);
    // lane -> slot index, mirrored against the backend's lane order.
    // Lanes 0..n_dec are decoding (stepped together each tick); lanes
    // n_dec..len are mid-prefill (advanced chunkwise, excluded from the
    // decode step and from sampling). On backends without a prefill path
    // the suffix is always empty (n_dec == lane_slots.len()).
    let mut lane_slots: Vec<usize> = Vec::with_capacity(max_batch); // lintra: allow(alloc) -- one-time setup before the tick loop
    let mut n_dec: usize = 0;
    let mut responders: std::collections::HashMap<u64, Sender<GenerateResponse>> =
        std::collections::HashMap::new(); // lintra: allow(alloc) -- one-time setup before the tick loop
    let mut rng = Rng::new(cfg.seed);
    let mut shutdown = false;
    let mut tokens: Vec<u32> = Vec::with_capacity(max_batch); // lintra: allow(alloc) -- one-time setup before the tick loop
    // Per-tick scratch, hoisted out of the loop: the steady-state tick
    // reuses these buffers instead of reallocating them every iteration
    // (the `alloc` analysis rule gates regressions here). Logits buffers
    // are filled by clear-then-resize, so reuse is bit-identical to a
    // fresh allocation.
    let mut retired: Vec<(SlotInfo, Duration)> = Vec::new(); // lintra: allow(alloc) -- hoisted scratch, allocated once
    let mut finished_lanes: Vec<usize> = Vec::new(); // lintra: allow(alloc) -- hoisted scratch, allocated once
    let mut decode_logits: Vec<f32> = Vec::new(); // lintra: allow(alloc) -- hoisted scratch, allocated once
    let mut prefill_logits: Vec<f32> = Vec::new(); // lintra: allow(alloc) -- hoisted scratch, allocated once
    let vocab = backend.vocab();
    let max_len = backend.max_len();
    let prefill_chunk = backend.prefill_chunk().max(1);
    // prefix-reuse state cache: explicit --state-cache-mb wins, else the
    // LINTRA_STATE_CACHE_MB env var, else off. Needs both the resumable
    // prefill path (to resume from a restored cursor) and the snapshot
    // hooks.
    let cache_mb = resolve_state_cache_mb(cfg.state_cache_mb);
    let mut state_cache: Option<StateCache> =
        if cache_mb > 0 && backend.supports_prefill() && backend.supports_snapshot() {
            // saturating: a 32-bit usize cannot wrap a large MiB count
            // to a zero-byte (silently inert) budget
            Some(StateCache::new(cache_mb.saturating_mul(1 << 20), prefill_chunk))
        } else {
            if cache_mb > 0 {
                // requested but unusable (e.g. the PJRT backend has no
                // snapshot/prefill path yet): say so instead of letting
                // the operator believe prefix caching is active
                eprintln!(
                    "[engine] state cache ({cache_mb} MiB) requested but this backend has \
                     no snapshot/prefill path; prefix caching disabled"
                );
            }
            None
        };

    while !shutdown || slots.active() > 0 || batcher.pending() > 0 {
        // 1. ingest requests. Block whenever there is nothing to tick:
        // totally idle, or every pending request is waiting out the
        // batcher deadline (this loop used to busy-spin on try_recv at
        // 100% CPU until max_wait elapsed in that second case).
        let mut block_for: Option<Duration> = None;
        if !shutdown && slots.active() == 0 {
            let now = Instant::now();
            block_for = if batcher.pending() == 0 {
                Some(Duration::from_millis(50))
            } else if batcher.ready(now) {
                // a batch is already releasable (full, or past its
                // deadline): admit it now, don't sleep on it
                None
            } else {
                // sleep until the batch deadline (or a new request)
                batcher
                    .next_deadline()
                    .map(|d| d.saturating_duration_since(now))
                    .filter(|d| !d.is_zero())
            };
        }
        loop {
            // the timed wait applies to the first receive only; further
            // queued messages drain without blocking
            let msg = match block_for.take() {
                Some(timeout) => match rx.recv_timeout(timeout) {
                    Ok(m) => Some(m),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => {
                        shutdown = true;
                        None
                    }
                },
                None => rx.try_recv().ok(),
            };
            match msg {
                Some(Msg::Request(req, resp_tx)) => {
                    responders.insert(req.id, resp_tx);
                    lock_unpoisoned(&stats).requests += 1;
                    batcher.push(req, Instant::now());
                    continue; // drain any further queued messages
                }
                Some(Msg::Shutdown) => {
                    shutdown = true;
                    continue;
                }
                None => break,
            }
        }

        // 2. admit from the batcher into fresh backend lanes; prompts are
        // prefilled in one call when the backend has the path. During
        // shutdown the deadline is moot (no more requests can join the
        // batch), so poll as if it had already fired.
        let now = Instant::now();
        let poll_now = if shutdown { now + batcher.max_wait } else { now };
        let capacity = max_batch - slots.active();
        let mut cache_hits = 0u64;
        let mut cache_misses = 0u64;
        let mut cache_evictions = 0u64;
        let mut tokens_skipped = 0u64;
        for req in batcher.poll(poll_now, capacity) {
            // reject prompts the decode loop cannot survive — empty (no
            // token to feed on the first tick) or longer than the position
            // embedding — so one bad request cannot take down the worker
            if req.prompt.is_empty() {
                send_failure(&mut responders, req.id, Vec::new(), "prompt must not be empty");
                continue;
            }
            if req.prompt.len() > max_len {
                send_failure(
                    &mut responders,
                    req.id,
                    Vec::new(),
                    format!("prompt length {} exceeds max_len {max_len}", req.prompt.len()),
                );
                continue;
            }
            if !req.temperature.is_finite() || req.temperature < 0.0 {
                // NaN/inf/negative temperatures have no sensible
                // distribution; reject instead of silently degrading to
                // greedy inside the sampler
                send_failure(
                    &mut responders,
                    req.id,
                    Vec::new(),
                    format!("temperature must be finite and >= 0, got {}", req.temperature),
                );
                continue;
            }
            if req.max_new == 0 {
                // zero tokens requested: complete immediately, without
                // burning a lane or sampling a token the client refused
                lock_unpoisoned(&stats).completed += 1;
                if let Some(tx) = responders.remove(&req.id) {
                    let _ = tx.send(GenerateResponse {
                        id: req.id,
                        tokens: Vec::new(),
                        latency_us: 0,
                        truncated: false,
                        error: None,
                    });
                }
                continue;
            }
            let req_id = req.id;
            let Some(idx) = slots.alloc(SlotInfo::new(
                req_id,
                now,
                req.prompt,
                req.max_new,
                req.temperature,
                req.top_k,
            )) else {
                // capacity was checked above, so this branch means the
                // slot table and the batcher disagree; fail the request
                // rather than the whole worker
                send_failure(&mut responders, req_id, Vec::new(), "admission failed: no free slot");
                continue;
            };
            let lane = match backend.alloc_lane() {
                Ok(lane) => lane,
                Err(e) => {
                    // lane allocation failed: fail this request, keep serving
                    let generated = slots.release(idx).map(|i| i.generated).unwrap_or_default();
                    send_failure(
                        &mut responders,
                        req_id,
                        generated,
                        format!("admission failed: {e}"),
                    );
                    continue;
                }
            };
            debug_assert_eq!(lane, lane_slots.len(), "lanes must stay dense");
            if backend.supports_prefill() {
                // resumable prefill: the slot joins the prefill suffix
                // and its first chunks flow in this very tick (step 3)
                let Some(info) = slots.get_mut(idx) else {
                    // unreachable in practice (idx was allocated just
                    // above); degrade to a failed request, not a panic
                    backend.free_lane(lane);
                    let msg = "admission failed: slot table lost the new slot";
                    send_failure(&mut responders, req_id, Vec::new(), msg);
                    continue;
                };
                info.start_prefill();
                // prefix reuse: restore the longest cached prefix of
                // this prompt into the fresh lane and advance the slot's
                // cursor past it — those tokens are never prefilled.
                // Restore lands the exact state bits prefill would have,
                // so a hit cannot change a single logit.
                if let Some(cache) = state_cache.as_mut() {
                    match cache.lookup(&info.prompt) {
                        Some((skip, snap)) => match backend.restore_lane(lane, &snap) {
                            Ok(()) => {
                                info.advance_prefill(skip);
                                cache_hits += 1;
                                tokens_skipped += skip as u64;
                            }
                            Err(_) => {
                                // the lane is still freshly zeroed:
                                // fall back to a cold prefill
                                cache_misses += 1;
                            }
                        },
                        None => cache_misses += 1,
                    }
                }
                lane_slots.push(idx);
            } else {
                // per-tick prompt feeding: the slot's cursor walks the
                // prompt through the shared decode loop, so it joins the
                // decode prefix directly (no suffix exists here)
                debug_assert_eq!(n_dec, lane_slots.len(), "suffix must stay empty");
                lane_slots.push(idx);
                n_dec += 1;
            }
        }

        if lane_slots.is_empty() {
            continue;
        }
        let tick_started = Instant::now();
        let occupancy = lane_slots.len() as u64;
        let mut tick_tokens = 0u64;
        let mut tick_chunks = 0u64;
        let mut tick_prompt_tokens = 0u64;
        debug_assert!(retired.is_empty(), "retired slots are drained every tick");

        // 3. prefill phase: every mid-prefill lane ingests at most
        // `prefill_chunks_per_tick` chunks, and the tick as a whole at
        // most `prefill_chunk_budget` chunks (0 = unlimited) across all
        // admitting slots — K simultaneous admissions can then add at
        // most one budget's worth of latency, not K chunks. A lane whose
        // final prompt position lands samples its first token from the
        // returned logits and either retires on the spot or swaps into
        // the decode prefix; everyone else (including lanes the global
        // budget starved this tick, earliest-admitted lanes first)
        // resumes next tick. This bounds admission-time work per tick,
        // which is what keeps resident decode lanes producing one token
        // per tick while long prompts stream in.
        let mut chunk_budget = if cfg.prefill_chunk_budget == 0 {
            u64::MAX
        } else {
            cfg.prefill_chunk_budget as u64
        };
        let mut lane = n_dec;
        'suffix: while lane < lane_slots.len() {
            let slot = lane_slots[lane];
            let mut have_logits = false;
            for _ in 0..cfg.prefill_chunks_per_tick {
                if chunk_budget == 0 {
                    break; // global budget exhausted: resume next tick
                }
                let Some(info) = slots.get_mut(slot) else {
                    // lane/slot maps diverged (bookkeeping corruption):
                    // compact the orphaned lane out and keep serving. The
                    // moved-in lane is re-examined at this same index.
                    debug_assert!(false, "suffix lane {lane} maps to a dead slot {slot}");
                    backend.free_lane(lane);
                    lane_slots.swap_remove(lane);
                    continue 'suffix;
                };
                debug_assert_eq!(info.phase, SlotPhase::Prefilling);
                let take = info.prefill_remaining().min(prefill_chunk);
                let finish = take == info.prefill_remaining();
                // lintra: allow(panic) -- take <= prefill_remaining, so cursor + take <= len
                let chunk = &info.prompt[info.cursor..info.cursor + take];
                match backend.prefill_partial(lane, chunk, finish, &mut prefill_logits) {
                    Ok(got) => {
                        info.advance_prefill(take);
                        chunk_budget -= 1;
                        tick_chunks += 1;
                        tick_prompt_tokens += take as u64;
                        // deposit a prefix snapshot when the cursor lands
                        // on a chunk boundary (interior chunks always do;
                        // a ragged finishing slice does not) AND this
                        // prefix has been sighted before — second-chance
                        // admission, so one-off prompts never pay the
                        // snapshot copy or churn the LRU budget. The key
                        // is the slot's running prefix hash, extended
                        // chunk by chunk in advance_prefill, so no rehash
                        // from position 0 happens here.
                        if let Some(cache) = state_cache.as_mut() {
                            if info.cursor % prefill_chunk == 0 {
                                let h = info.prefix_hash;
                                // lintra: allow(panic) -- cursor <= prompt.len() by contract
                                let prefix = &info.prompt[..info.cursor];
                                if cache.note_and_should_deposit(h)
                                    && !cache.contains_hashed(h, prefix)
                                {
                                    if let Some(snap) = backend.snapshot_lane(lane) {
                                        cache_evictions +=
                                            cache.insert_hashed(h, prefix, snap) as u64;
                                    }
                                }
                            }
                        }
                        if finish {
                            if !got {
                                // backend contract breach (a finishing
                                // chunk must return logits): treat it
                                // like a prefill failure, not a panic
                                backend.free_lane(lane);
                                lane_slots.swap_remove(lane);
                                if let Some(info) = slots.release(slot) {
                                    send_failure(
                                        &mut responders,
                                        info.request_id,
                                        info.generated,
                                        "prefill failed: finishing chunk returned no logits",
                                    );
                                }
                                continue 'suffix;
                            }
                            have_logits = true;
                            break;
                        }
                    }
                    Err(e) => {
                        // the lane is dead: compact it out of the suffix.
                        // The moved-in lane (previously last, also a
                        // suffix lane) is re-examined at this same index.
                        backend.free_lane(lane);
                        lane_slots.swap_remove(lane);
                        if let Some(info) = slots.release(slot) {
                            send_failure(
                                &mut responders,
                                info.request_id,
                                info.generated,
                                format!("prefill failed: {e}"),
                            );
                        }
                        continue 'suffix;
                    }
                }
            }
            if !have_logits {
                // chunk budget exhausted mid-prompt: resume next tick
                lane += 1;
                continue;
            }
            // final prompt position landed: sample the first token
            let Some(info) = slots.get_mut(slot) else {
                debug_assert!(false, "finishing lane {lane} maps to a dead slot {slot}");
                backend.free_lane(lane);
                lane_slots.swap_remove(lane);
                continue 'suffix;
            };
            let next = sample_logits_topk(&prefill_logits, info.temperature, info.top_k, &mut rng);
            info.generated.push(next);
            tick_tokens += 1;
            if info.generated.len() >= info.max_new || info.pos + 1 >= max_len {
                // single-token request (or a prompt that already fills
                // max_len): retire straight from prefill, never touching
                // a decode tick; the moved-in suffix lane (if any) is
                // re-examined at this index
                backend.free_lane(lane);
                lane_slots.swap_remove(lane);
                if let Some(info) = slots.release(slot) {
                    let latency = info.started.elapsed();
                    retired.push((info, latency)); // lintra: allow(alloc) -- reuses hoisted capacity, drained every tick
                }
                continue;
            }
            // transition Prefilling -> Decoding: swap into the decode
            // prefix. Position n_dec holds either this lane itself or a
            // suffix lane already advanced this tick, so no lane is
            // skipped or advanced twice.
            backend.swap_lanes(lane, n_dec);
            lane_slots.swap(lane, n_dec);
            n_dec += 1;
            lane += 1;
        }

        // the tick's scheduling invariants (lane/slot agreement, the
        // decode-prefix/prefill-suffix phase discipline, state-cache
        // byte accounting) — debug builds only, compiled out in release
        engine_invariants::check_tick(&engine_invariants::TickView {
            backend_lanes: backend.lanes(),
            n_dec,
            lane_slots: &lane_slots,
            slots: &slots,
            cache: state_cache.as_ref(),
        });

        // 4. one decode tick over the prefix: every decoding lane
        // advances by one token, together; suffix lanes are untouched
        let mut did_decode = false;
        if n_dec > 0 {
            tokens.clear();
            for &slot in lane_slots.iter().take(n_dec) {
                // lintra: allow(panic) -- the lane map mirrors the slot table by construction
                tokens.push(slots.get(slot).expect("lane maps to live slot").next_token());
            }
            match backend.step_batch(&tokens, &mut decode_logits) {
                Ok(()) => did_decode = true,
                Err(e) => {
                    // fail all active requests (mid-prefill ones too),
                    // clear every lane
                    for &slot in &lane_slots {
                        if let Some(info) = slots.release(slot) {
                            send_failure(
                                &mut responders,
                                info.request_id,
                                info.generated,
                                format!("decode failed: {e}"),
                            );
                        }
                    }
                    while backend.lanes() > 0 {
                        backend.free_lane(backend.lanes() - 1);
                    }
                    lane_slots.clear();
                    n_dec = 0;
                }
            }
        }

        if did_decode {
            // 5. consume logits: advance cursors, sample past the prompt.
            // Stats accumulate tick-locally — the lock is taken once per
            // tick (step 7), not once per generated token.
            finished_lanes.clear();
            debug_assert_eq!(
                decode_logits.len(),
                n_dec * vocab,
                "one logits row per decoding lane"
            );
            let rows = decode_logits.chunks_exact(vocab);
            for (lane, (&slot, row)) in lane_slots.iter().take(n_dec).zip(rows).enumerate() {
                let Some(info) = slots.get_mut(slot) else {
                    debug_assert!(false, "decode lane {lane} maps to a dead slot {slot}");
                    continue;
                };
                if !info.prompt_done() {
                    info.cursor += 1;
                }
                info.pos += 1;
                if info.prompt_done() {
                    let next = sample_logits_topk(row, info.temperature, info.top_k, &mut rng);
                    info.generated.push(next);
                    tick_tokens += 1;
                    if info.generated.len() >= info.max_new || info.pos + 1 >= max_len {
                        finished_lanes.push(lane); // lintra: allow(alloc) -- reuses hoisted capacity, drained every tick
                    }
                }
            }

            // 6. retire finished slots; descending lane order keeps the
            // bookkeeping valid (each removal only disturbs higher
            // lanes). With no prefill suffix this is plain swap-remove
            // compaction; with mid-prefill lanes parked behind the
            // prefix, the retiring lane is first swapped to the end of
            // the decode prefix so that the backend's swap-remove (which
            // moves the overall-last lane — a mid-prefill one) lands the
            // moved lane exactly on the new prefix/suffix boundary.
            finished_lanes.sort_unstable_by_key(|&lane| std::cmp::Reverse(lane));
            for lane in finished_lanes.drain(..) {
                let slot = lane_slots[lane];
                if n_dec == lane_slots.len() {
                    backend.free_lane(lane);
                    lane_slots.swap_remove(lane);
                } else {
                    let last_dec = n_dec - 1;
                    if lane != last_dec {
                        backend.swap_lanes(lane, last_dec);
                        lane_slots.swap(lane, last_dec);
                    }
                    backend.free_lane(last_dec);
                    lane_slots.swap_remove(last_dec);
                }
                n_dec -= 1;
                if let Some(info) = slots.release(slot) {
                    let latency = info.started.elapsed();
                    retired.push((info, latency)); // lintra: allow(alloc) -- reuses hoisted capacity, drained every tick
                }
            }
        }

        // 7. flush this tick's stats under a single lock acquisition,
        // *then* answer clients — a client holding its response must
        // already see its completion reflected in the stats
        let tick_dur = tick_started.elapsed();
        {
            let mut st = lock_unpoisoned(&stats);
            st.ticks += 1;
            st.batch_occupancy_sum += occupancy;
            st.tokens_generated += tick_tokens;
            st.prompt_tokens_ingested += tick_prompt_tokens;
            st.prompt_tokens_skipped += tokens_skipped;
            st.state_cache.hits += cache_hits;
            st.state_cache.misses += cache_misses;
            st.state_cache.evictions += cache_evictions;
            st.completed += retired.len() as u64;
            if tick_chunks > 0 {
                st.prefill_ticks += 1;
                st.tick_latency.prefill.record(tick_dur);
            } else {
                st.tick_latency.decode.record(tick_dur);
            }
            for (_, d) in &retired {
                st.latency.record(*d);
            }
        }
        for (info, latency) in retired.drain(..) {
            let truncated = info.generated.len() < info.max_new;
            if let Some(tx) = responders.remove(&info.request_id) {
                let _ = tx.send(GenerateResponse {
                    id: info.request_id,
                    tokens: info.generated,
                    latency_us: latency.as_micros() as u64,
                    truncated,
                    error: None,
                });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// native engine
// ---------------------------------------------------------------------------

/// Serving engine over the pure-rust batched decode path.
pub struct NativeEngine;

impl NativeEngine {
    /// Spawn the worker; the model moves into the thread.
    pub fn spawn(model: TransformerLM, cfg: ServeConfig) -> anyhow::Result<EngineHandle> {
        cfg.validate()?;
        if matches!(model.kind, AttentionKind::Lsh { .. }) {
            // Reformer has no stateful decode (hashing needs the whole
            // prefix — paper §C.1): there is nothing to run a tick loop on
            anyhow::bail!("the native engine serves linear or softmax models, not LSH");
        }
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(Mutex::new(EngineStats::default()));
        let stats_w = stats.clone();
        let worker = std::thread::Builder::new()
            .name("lintra-native-engine".into())
            .spawn(move || {
                // Weight storage dtype: explicit ServeConfig wins, else
                // LINTRA_WEIGHT_DTYPE, else f32. Casting is idempotent
                // (always from the retained f32 tensors), so re-casting a
                // model the loader already quantized is harmless.
                let mut model = model;
                model.cast_weights(crate::config::resolve_weight_dtype(cfg.weight_dtype));
                // GEMM worker pool: cfg.num_threads (0 = auto). Pooled
                // kernels are bit-identical to serial, so thread count
                // never changes what a request gets back.
                let pool = crate::parallel::pool_for(cfg.num_threads);
                // The serving backend follows the model's attention kind
                // (the --attention-backend flag / LINTRA_ATTENTION_BACKEND
                // resolve at model construction, not here): linear decodes
                // through the batched RNN state, softmax through the
                // batched KV cache — one tick loop either way, which is
                // what makes Tables 4/5 a like-for-like serving contrast.
                match model.kind {
                    AttentionKind::Linear => {
                        let mut backend = model.batched_session_with_pool(cfg.max_batch, pool);
                        run_engine(&mut backend, &cfg, rx, stats_w);
                    }
                    AttentionKind::Softmax => {
                        let mut backend =
                            model.batched_softmax_session_with_pool(cfg.max_batch, pool);
                        run_engine(&mut backend, &cfg, rx, stats_w);
                    }
                    AttentionKind::Lsh { .. } => {
                        // lintra: allow(panic) -- rejected at spawn entry before the worker starts
                        unreachable!("LSH models are rejected before the worker spawns")
                    }
                }
            })?;
        Ok(EngineHandle {
            tx,
            stats,
            worker: Some(worker),
        })
    }
}

// ---------------------------------------------------------------------------
// PJRT engine
// ---------------------------------------------------------------------------

/// Serving engine over a batched AOT decode artifact.
pub struct PjrtEngine;

/// Parameters identifying the artifact the PJRT engine decodes with.
#[derive(Clone, Debug)]
pub struct PjrtEngineSpec {
    pub artifacts_dir: String,
    /// e.g. "mnist" — uses `<task>_decode_linear_b<max_batch>`
    pub task: String,
    pub model_cfg: ModelConfig,
}

impl PjrtEngine {
    pub fn spawn(spec: PjrtEngineSpec, cfg: ServeConfig) -> anyhow::Result<EngineHandle> {
        cfg.validate()?;
        let (tx, rx) = channel::<Msg>();
        let (ready_tx, ready_rx) = channel::<anyhow::Result<()>>();
        let stats = Arc::new(Mutex::new(EngineStats::default()));
        let stats_w = stats.clone();
        let worker = std::thread::Builder::new()
            .name("lintra-pjrt-engine".into())
            .spawn(move || pjrt_worker(spec, cfg, rx, stats_w, ready_tx))?;
        // surface artifact-loading errors synchronously
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("pjrt worker died during startup"))??;
        Ok(EngineHandle {
            tx,
            stats,
            worker: Some(worker),
        })
    }
}

/// Decode lanes over a batched `*_decode_linear_b<B>` artifact: the host
/// keeps the `[l, B, h, dh, dh]` / `[l, B, h, dh]` state blocks and the
/// per-lane positions, compacting lane stripes on eviction exactly like
/// the native backend compacts its rows. Inactive lanes ride along as
/// padding (token 0, pos 0) and are re-zeroed on allocation.
struct PjrtBackend {
    artifact: std::rc::Rc<crate::runtime::LoadedArtifact>,
    params: Vec<Value>,
    mcfg: ModelConfig,
    /// artifact batch dimension (== ServeConfig::max_batch)
    b: usize,
    lanes: usize,
    l: usize,
    h: usize,
    dh: usize,
    s_shape: Vec<usize>,
    z_shape: Vec<usize>,
    s: Vec<f32>,
    z: Vec<f32>,
    pos: Vec<i32>,
    token_buf: Vec<i32>,
}

impl PjrtBackend {
    fn new(
        artifact: std::rc::Rc<crate::runtime::LoadedArtifact>,
        params: Vec<Value>,
        mcfg: ModelConfig,
        b: usize,
    ) -> Self {
        let (l, h, dh) = (mcfg.n_layers, mcfg.n_heads, mcfg.d_head());
        PjrtBackend {
            artifact,
            params,
            mcfg,
            b,
            lanes: 0,
            l,
            h,
            dh,
            s_shape: vec![l, b, h, dh, dh],
            z_shape: vec![l, b, h, dh],
            s: vec![0.0; l * b * h * dh * dh],
            z: vec![0.0; l * b * h * dh],
            pos: vec![0; b],
            token_buf: vec![0; b],
        }
    }

    /// Zero one lane's stripes in (s, z).
    fn clear_lane(&mut self, lane: usize) {
        let (l, b, h, dh) = (self.l, self.b, self.h, self.dh);
        for li in 0..l {
            for hi in 0..h {
                let base = ((li * b + lane) * h + hi) * dh * dh;
                // lintra: allow(panic) -- stripe arithmetic is bounded by the (l, b, h, dh)
                self.s[base..base + dh * dh].fill(0.0);
                let zbase = ((li * b + lane) * h + hi) * dh;
                // lintra: allow(panic) -- geometry the buffers were sized with at construction
                self.z[zbase..zbase + dh].fill(0.0);
            }
        }
        self.pos[lane] = 0;
    }

    /// Copy lane `src`'s stripes over lane `dst`.
    fn copy_lane(&mut self, dst: usize, src: usize) {
        let (l, b, h, dh) = (self.l, self.b, self.h, self.dh);
        for li in 0..l {
            for hi in 0..h {
                let sb = ((li * b + src) * h + hi) * dh * dh;
                let db = ((li * b + dst) * h + hi) * dh * dh;
                self.s.copy_within(sb..sb + dh * dh, db);
                let szb = ((li * b + src) * h + hi) * dh;
                let dzb = ((li * b + dst) * h + hi) * dh;
                self.z.copy_within(szb..szb + dh, dzb);
            }
        }
        self.pos[dst] = self.pos[src];
    }
}

impl DecodeBackend for PjrtBackend {
    fn vocab(&self) -> usize {
        self.mcfg.vocab
    }

    fn max_len(&self) -> usize {
        self.mcfg.max_len
    }

    fn lanes(&self) -> usize {
        self.lanes
    }

    fn alloc_lane(&mut self) -> anyhow::Result<usize> {
        if self.lanes == self.b {
            anyhow::bail!("pjrt decode capacity {} exhausted", self.b);
        }
        let lane = self.lanes;
        self.clear_lane(lane);
        self.lanes += 1;
        Ok(lane)
    }

    fn free_lane(&mut self, lane: usize) -> Option<usize> {
        assert!(lane < self.lanes, "lane {lane} out of {} live lanes", self.lanes);
        let last = self.lanes - 1;
        self.lanes = last;
        if lane == last {
            self.pos[last] = 0;
            return None;
        }
        self.copy_lane(lane, last);
        self.pos[last] = 0;
        Some(last)
    }

    fn step_batch(&mut self, tokens: &[u32], logits_out: &mut Vec<f32>) -> anyhow::Result<()> {
        assert_eq!(tokens.len(), self.lanes, "one token per live lane");
        for lane in 0..self.b {
            self.token_buf[lane] = if lane < self.lanes {
                tokens[lane] as i32
            } else {
                0 // padding lane: harmless input, state unused until re-zeroed
            };
        }
        let mut inputs = self.params.clone();
        inputs.push(Value::I32(vec![self.b], self.token_buf.clone()));
        inputs.push(Value::I32(vec![self.b], self.pos.clone()));
        inputs.push(Value::F32(self.s_shape.clone(), self.s.clone()));
        inputs.push(Value::F32(self.z_shape.clone(), self.z.clone()));
        let outputs = self.artifact.run(&inputs)?;
        let vocab = self.mcfg.vocab;
        let logits = outputs[0].as_f32()?;
        self.s.copy_from_slice(outputs[1].as_f32()?);
        self.z.copy_from_slice(outputs[2].as_f32()?);
        for lane in 0..self.lanes {
            self.pos[lane] += 1;
        }
        logits_out.clear();
        // lintra: allow(panic) -- the artifact's logits rows cover all b >= lanes lanes
        logits_out.extend_from_slice(&logits[..self.lanes * vocab]);
        Ok(())
    }
}

fn pjrt_worker(
    spec: PjrtEngineSpec,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
    stats: Arc<Mutex<EngineStats>>,
    ready: Sender<anyhow::Result<()>>,
) {
    // Build everything PJRT inside the worker (handles are not Send).
    let setup = (|| -> anyhow::Result<_> {
        let mut rt = Runtime::open(&spec.artifacts_dir)?;
        let art_name = format!("{}_decode_linear_b{}", spec.task, cfg.max_batch);
        let artifact = rt.load(&art_name)?;
        let model_key = format!("{}_linear", spec.task);
        let weights = rt.load_weights(&model_key)?;
        let model_spec = rt
            .bundle
            .model(&model_key)
            .ok_or_else(|| anyhow::anyhow!("model {model_key} missing"))?
            .clone();
        // params in manifest order
        let params: Vec<Value> = model_spec
            .params
            .iter()
            .map(|n| Value::from_tensor(weights.req(n)))
            .collect();
        Ok((artifact, params))
    })();
    let (artifact, params) = match setup {
        Ok(x) => {
            let _ = ready.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let mut backend = PjrtBackend::new(artifact, params, spec.model_cfg, cfg.max_batch);
    run_engine(&mut backend, &cfg, rx, stats);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    /// The attention kind the engine tests build models with: linear by
    /// default, softmax when `LINTRA_ATTENTION_BACKEND=softmax` (the
    /// fifth CI test leg) — every engine test then drives the KV-cache
    /// backend through the same tick loop. Valid because `generate` (the
    /// tests' oracle) routes through the same batched session machinery
    /// the engine serves with for both kinds, bitwise.
    fn test_kind() -> AttentionKind {
        crate::config::resolve_attention_backend(None).kind()
    }

    fn tiny_model() -> TransformerLM {
        let cfg = ModelConfig {
            vocab: 11,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            max_len: 64,
            d_ff: 64,
            chunk: 16,
            causal: true,
            lsh_rounds: 1,
            lsh_buckets: 8,
            lsh_chunk: 8,
        };
        TransformerLM::init(&cfg, test_kind(), 0)
    }

    #[test]
    fn serves_single_request() {
        let mut handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 1,
            prompt: vec![1, 2, 3],
            max_new: 5,
            temperature: 0.0,
            top_k: 0,
        });
        assert_eq!(resp.id, 1);
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.error.is_none());
        let st = handle.stats();
        assert_eq!(st.completed, 1);
        assert_eq!(st.tokens_generated, 5);
        handle.shutdown();
    }

    #[test]
    fn serves_concurrent_requests_batched() {
        let mut handle = NativeEngine::spawn(
            tiny_model(),
            ServeConfig {
                max_batch: 4,
                max_wait_us: 2000,
                ..Default::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = (0..8)
            .map(|i| {
                handle.submit(GenerateRequest {
                    id: i,
                    prompt: vec![1, (i % 10) as u32],
                    max_new: 6,
                    temperature: 0.0,
                    top_k: 0,
                })
            })
            .collect();
        let mut ids = Vec::new();
        for rx in rxs {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.tokens.len(), 6);
            ids.push(resp.id);
        }
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u64>>());
        let st = handle.stats();
        assert_eq!(st.completed, 8);
        // batching actually happened: mean occupancy > 1
        assert!(
            st.mean_batch_occupancy() > 1.0,
            "occupancy {}",
            st.mean_batch_occupancy()
        );
        handle.shutdown();
    }

    #[test]
    fn softmax_backend_serves_end_to_end_matching_direct_generation() {
        // the KV-cache backend through the whole serving path — chunked
        // prefill (150 tokens = 3 chunks), continuous batching, retire —
        // regardless of what LINTRA_ATTENTION_BACKEND says; greedy
        // outputs must equal direct generation exactly, because
        // session()/generate route through the same batched KV machinery
        let model = long_model_of(AttentionKind::Softmax);
        let vocab = model.cfg.vocab;
        let short_prompt = vec![1, 2, 3];
        let long_prompt = prompt_of(150, vocab, 41);
        let direct_short = model.generate(&short_prompt, 8, 0.0, 0);
        let direct_long = model.generate(&long_prompt, 5, 0.0, 0);
        let mut handle = NativeEngine::spawn(
            long_model_of(AttentionKind::Softmax),
            ServeConfig {
                max_batch: 2,
                max_wait_us: 50_000,
                ..Default::default()
            },
        )
        .unwrap();
        let rx_short = handle.submit(GenerateRequest {
            id: 1,
            prompt: short_prompt,
            max_new: 8,
            temperature: 0.0,
            top_k: 0,
        });
        let rx_long = handle.submit(GenerateRequest {
            id: 2,
            prompt: long_prompt,
            max_new: 5,
            temperature: 0.0,
            top_k: 0,
        });
        let resp_short = rx_short.recv().unwrap();
        let resp_long = rx_long.recv().unwrap();
        assert!(resp_short.error.is_none(), "{:?}", resp_short.error);
        assert!(resp_long.error.is_none(), "{:?}", resp_long.error);
        assert_eq!(resp_short.tokens, direct_short);
        assert_eq!(resp_long.tokens, direct_long);
        handle.shutdown();
    }

    #[test]
    fn deterministic_greedy_responses_match_direct_generation() {
        let model = tiny_model();
        let direct = model.generate(&[1, 2, 3], 5, 0.0, 0);
        let mut handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 9,
            prompt: vec![1, 2, 3],
            max_new: 5,
            temperature: 0.0,
            top_k: 0,
        });
        assert_eq!(resp.tokens, direct);
        handle.shutdown();
    }

    #[test]
    fn ragged_batch_matches_direct_generation_under_churn() {
        // Requests of very different lengths share the batch, so slots
        // join mid-stream, finish early, and their lanes get compacted.
        // Greedy decode must still match per-request direct generation.
        let model = tiny_model();
        let cases: Vec<(Vec<u32>, usize)> = vec![
            (vec![1], 14),
            (vec![2, 3, 4, 5, 6], 2),
            (vec![7, 8], 9),
            (vec![9, 10, 1, 2], 4),
            (vec![3], 1),
            (vec![4, 5, 6], 7),
        ];
        let direct: Vec<Vec<u32>> = cases
            .iter()
            .map(|(p, n)| model.generate(p, *n, 0.0, 0))
            .collect();
        let mut handle = NativeEngine::spawn(
            tiny_model(),
            ServeConfig {
                max_batch: 3, // force waves of admission + eviction
                max_wait_us: 1000,
                ..Default::default()
            },
        )
        .unwrap();
        let rxs: Vec<_> = cases
            .iter()
            .enumerate()
            .map(|(i, (p, n))| {
                handle.submit(GenerateRequest {
                    id: i as u64,
                    prompt: p.clone(),
                    max_new: *n,
                    temperature: 0.0,
                    top_k: 0,
                })
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "{:?}", resp.error);
            assert_eq!(
                resp.tokens, direct[resp.id as usize],
                "request {i} diverged from direct generation under churn"
            );
        }
        let st = handle.stats();
        assert_eq!(st.completed, 6);
        handle.shutdown();
    }

    #[test]
    fn oversized_prompt_is_rejected_not_fatal() {
        let model = tiny_model();
        let max_len = model.cfg.max_len;
        let mut handle = NativeEngine::spawn(model, ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 1,
            prompt: vec![1; max_len + 1],
            max_new: 4,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(resp.error.is_some(), "oversized prompt must be rejected");
        assert!(resp.tokens.is_empty());
        let empty = handle.generate_blocking(GenerateRequest {
            id: 3,
            prompt: vec![],
            max_new: 4,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(empty.error.is_some(), "empty prompt must be rejected");
        // the worker must still be alive and serving
        let ok = handle.generate_blocking(GenerateRequest {
            id: 2,
            prompt: vec![1, 2],
            max_new: 3,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(ok.error.is_none());
        assert_eq!(ok.tokens.len(), 3);
        handle.shutdown();
    }

    #[test]
    fn respects_max_len_and_reports_truncation() {
        let model = tiny_model();
        let max_len = model.cfg.max_len;
        let mut handle = NativeEngine::spawn(model, ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 2,
            prompt: vec![1; 10],
            max_new: 10_000,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(resp.tokens.len() <= max_len - 10);
        assert!(resp.error.is_none());
        assert!(resp.truncated, "a max_len cutoff must be reported, not silent");
        // a request that completes normally is not marked truncated
        let full = handle.generate_blocking(GenerateRequest {
            id: 3,
            prompt: vec![1, 2],
            max_new: 4,
            temperature: 0.0,
            top_k: 0,
        });
        assert_eq!(full.tokens.len(), 4);
        assert!(!full.truncated);
        handle.shutdown();
    }

    #[test]
    fn zero_max_new_completes_without_sampling() {
        // regression: the tick loop used to sample (and return) one token
        // before noticing max_new was already satisfied
        let mut handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 5,
            prompt: vec![1, 2, 3],
            max_new: 0,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(resp.tokens.is_empty(), "asked for zero tokens, got {:?}", resp.tokens);
        assert!(!resp.truncated);
        let st = handle.stats();
        assert_eq!(st.completed, 1);
        assert_eq!(st.tokens_generated, 0, "no token may be sampled for max_new = 0");
        // the worker keeps serving
        let ok = handle.generate_blocking(GenerateRequest {
            id: 6,
            prompt: vec![4],
            max_new: 2,
            temperature: 0.0,
            top_k: 0,
        });
        assert_eq!(ok.tokens.len(), 2);
        handle.shutdown();
    }

    #[test]
    fn single_token_request_retires_at_admission() {
        // max_new = 1 finishes inside the prefill admission path, before
        // the slot ever joins the tick loop
        let model = tiny_model();
        let direct = model.generate(&[2, 3, 4], 1, 0.0, 0);
        let mut handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 7,
            prompt: vec![2, 3, 4],
            max_new: 1,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(resp.error.is_none());
        assert_eq!(resp.tokens, direct);
        assert!(!resp.truncated);
        handle.shutdown();
    }

    #[test]
    fn submit_after_shutdown_returns_error_response_not_panic() {
        // regression: submit used to expect("engine worker gone"), so a
        // connection thread racing a shutdown panicked — and with it the
        // whole server process
        let mut handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let ok = handle.generate_blocking(GenerateRequest {
            id: 1,
            prompt: vec![1, 2],
            max_new: 2,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(ok.error.is_none());
        handle.shutdown();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 42,
            prompt: vec![1],
            max_new: 2,
            temperature: 0.0,
            top_k: 0,
        });
        assert_eq!(resp.id, 42);
        assert!(resp.tokens.is_empty());
        assert!(
            resp.error.as_deref().unwrap_or("").contains("engine unavailable"),
            "expected an engine-unavailable error, got {:?}",
            resp.error
        );
        // shutdown is idempotent
        handle.shutdown();
    }

    #[test]
    fn poisoned_stats_lock_does_not_take_down_the_engine() {
        // regression: stats were read with .lock().unwrap(), so one
        // panicked thread holding the stats mutex poisoned it — and every
        // later stats() call AND the worker's own per-tick stats flush
        // panicked in turn, taking the whole engine down. All stats
        // acquisitions now go through parallel::lock_unpoisoned.
        let mut handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let ok = handle.generate_blocking(GenerateRequest {
            id: 1,
            prompt: vec![1, 2],
            max_new: 2,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(ok.error.is_none(), "{:?}", ok.error);
        // poison the stats mutex: a thread panics while holding the lock
        let stats = handle.stats.clone();
        let poisoner = std::thread::spawn(move || {
            let _guard = stats.lock().unwrap();
            panic!("deliberate poison");
        });
        assert!(poisoner.join().is_err(), "the poisoner must have panicked");
        assert!(handle.stats.is_poisoned(), "the mutex must actually be poisoned");
        // the engine must keep serving (its tick flush locks stats too)...
        let resp = handle.generate_blocking(GenerateRequest {
            id: 2,
            prompt: vec![3, 4],
            max_new: 3,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 3);
        // ...and stats() must keep answering with coherent counters
        let st = handle.stats();
        assert_eq!(st.completed, 2);
        assert_eq!(st.requests, 2);
        handle.shutdown();
    }

    #[test]
    fn lone_request_is_admitted_at_the_batcher_deadline() {
        // with pending batcher entries and no active lanes the loop used
        // to busy-spin on try_recv until max_wait elapsed; it now blocks
        // until the deadline — and must still admit the request there
        let mut handle = NativeEngine::spawn(
            tiny_model(),
            ServeConfig {
                max_batch: 4,
                max_wait_us: 60_000, // 60 ms: long enough to observe the wait
                ..Default::default()
            },
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 11,
            prompt: vec![1, 2],
            max_new: 2,
            temperature: 0.0,
            top_k: 0,
        });
        let waited = t0.elapsed();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 2);
        assert!(
            waited >= Duration::from_millis(55),
            "an underfull batch must wait out the deadline, waited {waited:?}"
        );
        handle.shutdown();
    }

    #[test]
    fn explicit_worker_pool_plumbing_matches_serial_engine_output() {
        // wiring-only check: tiny_model's shapes sit below PAR_MIN_WORK,
        // so both engines run the serial kernels — this covers the
        // num_threads -> pool_for -> session plumbing, not pooled
        // dispatch itself. Kernel-level pooled parity lives in
        // tensor.rs::pooled_* and rust/tests/batched_parity.rs
        // (d_model = 128 geometry that crosses the threshold).
        let mut outs = Vec::new();
        for num_threads in [1usize, 4] {
            let mut handle = NativeEngine::spawn(
                tiny_model(),
                ServeConfig {
                    num_threads,
                    ..Default::default()
                },
            )
            .unwrap();
            let resp = handle.generate_blocking(GenerateRequest {
                id: 1,
                prompt: vec![3, 1, 4, 1, 5],
                max_new: 8,
                temperature: 0.0,
                top_k: 0,
            });
            assert!(resp.error.is_none(), "{:?}", resp.error);
            outs.push(resp.tokens);
            handle.shutdown();
        }
        assert_eq!(outs[0], outs[1], "thread count must never change generations");
    }

    /// tiny geometry with room for multi-chunk prompts (max_len 192 spans
    /// three PREFILL_CHUNK-sized chunks)
    fn long_model_of(kind: AttentionKind) -> TransformerLM {
        let cfg = ModelConfig {
            vocab: 11,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            max_len: 192,
            d_ff: 64,
            chunk: 16,
            causal: true,
            lsh_rounds: 1,
            lsh_buckets: 8,
            lsh_chunk: 8,
        };
        TransformerLM::init(&cfg, kind, 17)
    }

    fn long_model() -> TransformerLM {
        long_model_of(test_kind())
    }

    fn prompt_of(len: usize, vocab: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::rng::Rng::new(seed);
        (0..len).map(|_| rng.below(vocab as u64) as u32).collect()
    }

    #[test]
    fn long_prompt_admits_over_multiple_ticks_while_residents_decode() {
        // a 150-token prompt (3 chunks, budget 1 chunk/tick) must admit
        // incrementally while a resident lane keeps decoding — and both
        // outputs must equal direct per-request generation exactly
        let model = long_model();
        let vocab = model.cfg.vocab;
        let resident_prompt = vec![1, 2, 3];
        let long_prompt = prompt_of(150, vocab, 70);
        let direct_resident = model.generate(&resident_prompt, 24, 0.0, 0);
        let direct_long = model.generate(&long_prompt, 5, 0.0, 0);

        // max_batch 2 + a generous deadline: both requests land in the
        // same released batch, so the resident lane is guaranteed to be
        // decoding while the long prompt absorbs its 3 chunks
        let mut handle = NativeEngine::spawn(
            long_model(),
            ServeConfig {
                max_batch: 2,
                max_wait_us: 50_000,
                ..Default::default()
            },
        )
        .unwrap();
        let rx_resident = handle.submit(GenerateRequest {
            id: 1,
            prompt: resident_prompt,
            max_new: 24,
            temperature: 0.0,
            top_k: 0,
        });
        let rx_long = handle.submit(GenerateRequest {
            id: 2,
            prompt: long_prompt.clone(),
            max_new: 5,
            temperature: 0.0,
            top_k: 0,
        });
        let resident = rx_resident.recv().unwrap();
        let long = rx_long.recv().unwrap();
        assert!(resident.error.is_none(), "{:?}", resident.error);
        assert!(long.error.is_none(), "{:?}", long.error);
        assert_eq!(resident.tokens, direct_resident, "resident lane disturbed by prefill");
        assert_eq!(long.tokens, direct_long, "incremental prefill changed the output");

        let st = handle.stats();
        // 150 tokens at one 64-token chunk per tick is at least 3
        // prefill-carrying ticks (plus the resident's own admission tick)
        assert!(st.prefill_ticks >= 3, "prefill_ticks = {}", st.prefill_ticks);
        // a per-tick cursor walk would burn 150+ ticks on the prompt;
        // chunked ingestion adds at most ceil(150/64) = 3 on top of the
        // ~24 decode ticks the resident needs
        assert!(st.ticks <= 40, "prompt ingestion leaked into the tick budget: {}", st.ticks);
        assert_eq!(
            st.prompt_tokens_ingested,
            150 + 3,
            "every prompt token must enter through the prefill path"
        );
        assert_eq!(
            st.tick_latency.prefill.count() as u64,
            st.prefill_ticks,
            "every prefill tick must be recorded in the latency split"
        );
        assert!(
            st.tick_latency.decode.count() > 0,
            "pure decode ticks must be recorded in the latency split"
        );
        assert_eq!(st.ticks, st.prefill_ticks + st.tick_latency.decode.count() as u64);
        handle.shutdown();
    }

    #[test]
    fn slots_retiring_and_rejections_leave_mid_prefill_lanes_intact() {
        // while a long prompt is mid-prefill: a resident slot retires
        // (forcing compaction across the prefix/suffix boundary), an
        // oversized prompt and an empty prompt are rejected — and the
        // mid-prefill request still decodes exactly like direct generation
        let model = long_model();
        let vocab = model.cfg.vocab;
        let max_len = model.cfg.max_len;
        let long_prompt = prompt_of(170, vocab, 71);
        let short_prompt = vec![4, 5];
        let direct_long = model.generate(&long_prompt, 6, 0.0, 0);
        let direct_short = model.generate(&short_prompt, 2, 0.0, 0);

        let mut handle = NativeEngine::spawn(
            long_model(),
            ServeConfig {
                max_batch: 3,
                max_wait_us: 100,
                ..Default::default()
            },
        )
        .unwrap();
        // short request first so it is decoding (and retires) while the
        // long prompt is still absorbing chunks
        let rx_short = handle.submit(GenerateRequest {
            id: 1,
            prompt: short_prompt,
            max_new: 2,
            temperature: 0.0,
            top_k: 0,
        });
        let rx_long = handle.submit(GenerateRequest {
            id: 2,
            prompt: long_prompt,
            max_new: 6,
            temperature: 0.0,
            top_k: 0,
        });
        let rx_oversized = handle.submit(GenerateRequest {
            id: 3,
            prompt: vec![1; max_len + 1],
            max_new: 2,
            temperature: 0.0,
            top_k: 0,
        });
        let rx_empty = handle.submit(GenerateRequest {
            id: 4,
            prompt: vec![],
            max_new: 2,
            temperature: 0.0,
            top_k: 0,
        });
        assert_eq!(rx_short.recv().unwrap().tokens, direct_short);
        assert!(rx_oversized.recv().unwrap().error.is_some());
        assert!(rx_empty.recv().unwrap().error.is_some());
        let long = rx_long.recv().unwrap();
        assert!(long.error.is_none(), "{:?}", long.error);
        assert_eq!(long.tokens, direct_long, "churn around a mid-prefill lane broke it");
        let st = handle.stats();
        assert_eq!(st.completed, 2);
        handle.shutdown();
    }

    #[test]
    fn shutdown_drains_a_prompt_still_in_prefill() {
        // shutdown lands while the prompt is (at best) barely admitted;
        // the engine must drain it to a complete, correct response
        let model = long_model();
        let long_prompt = prompt_of(160, model.cfg.vocab, 72);
        let direct = model.generate(&long_prompt, 4, 0.0, 0);
        let mut handle = NativeEngine::spawn(long_model(), ServeConfig::default()).unwrap();
        let rx = handle.submit(GenerateRequest {
            id: 9,
            prompt: long_prompt,
            max_new: 4,
            temperature: 0.0,
            top_k: 0,
        });
        handle.shutdown(); // joins the worker: drain must finish the request
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens, direct, "shutdown drain corrupted a mid-prefill request");
        assert!(!resp.truncated);
        let st = handle.stats();
        assert_eq!(st.completed, 1);
        handle.shutdown();
    }

    #[test]
    fn prefill_chunk_budget_never_changes_tokens() {
        // the scheduler knob trades latency shape only: outputs at
        // 1, 2, and effectively-unbounded chunks per tick are identical
        let model = long_model();
        let vocab = model.cfg.vocab;
        let cases: Vec<(Vec<u32>, usize)> = vec![
            (prompt_of(150, vocab, 73), 5),
            (vec![7, 8], 8),
            (prompt_of(65, vocab, 74), 1), // finishes inside prefill (max_new = 1)
        ];
        let mut outs_per_budget = Vec::new();
        for budget in [1usize, 2, 1_000_000] {
            let mut handle = NativeEngine::spawn(
                long_model(),
                ServeConfig {
                    max_batch: 3,
                    max_wait_us: 100,
                    prefill_chunks_per_tick: budget,
                    ..Default::default()
                },
            )
            .unwrap();
            let rxs: Vec<_> = cases
                .iter()
                .enumerate()
                .map(|(i, (p, n))| {
                    handle.submit(GenerateRequest {
                        id: i as u64,
                        prompt: p.clone(),
                        max_new: *n,
                        temperature: 0.0,
                        top_k: 0,
                    })
                })
                .collect();
            let mut outs = vec![Vec::new(); cases.len()];
            for rx in rxs {
                let resp = rx.recv().unwrap();
                assert!(resp.error.is_none(), "{:?}", resp.error);
                outs[resp.id as usize] = resp.tokens;
            }
            handle.shutdown();
            outs_per_budget.push(outs);
        }
        assert_eq!(outs_per_budget[0], outs_per_budget[1]);
        assert_eq!(outs_per_budget[0], outs_per_budget[2]);
    }

    // the acceptance bar for the prefix-reuse state cache, including
    // second-chance deposit admission: the FIRST request carrying a
    // prefix only registers it (no snapshot is deposited, so a
    // repeat of the same prompt still misses), the SECOND deposits,
    // and the THIRD — sharing the chunk-aligned prefix — restores
    // it, producing BIT-IDENTICAL greedy output to a cold run while
    // ingesting only the non-shared suffix tokens. Parameterized over
    // both serving backends: the cache machinery is backend-agnostic,
    // only the snapshot payload differs (O(1) linear state vs O(N)
    // KV rows — both honestly sized, both well under the budget here)
    fn shared_prefix_restore_case(kind: AttentionKind) {
        let model = long_model_of(kind);
        let vocab = model.cfg.vocab;
        let shared = prompt_of(2 * crate::nn::PREFILL_CHUNK, vocab, 90); // 128: 2 chunks
        let mut p1 = shared.clone();
        p1.extend(prompt_of(20, vocab, 91));
        let mut p2 = shared.clone();
        p2.extend(prompt_of(35, vocab, 92));
        let direct1 = model.generate(&p1, 6, 0.0, 0);
        let direct2 = model.generate(&p2, 6, 0.0, 0);

        let mut handle = NativeEngine::spawn(
            long_model_of(kind),
            ServeConfig {
                state_cache_mb: 16,
                ..Default::default()
            },
        )
        .unwrap();
        let r1 = handle.generate_blocking(GenerateRequest {
            id: 1,
            prompt: p1.clone(),
            max_new: 6,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(r1.error.is_none(), "{:?}", r1.error);
        assert_eq!(r1.tokens, direct1, "cold run must match direct generation");
        let st1 = handle.stats();
        assert_eq!(st1.state_cache.hits, 0, "nothing cached yet");
        assert_eq!(st1.state_cache.misses, 1);
        assert_eq!(st1.prompt_tokens_skipped, 0);
        assert_eq!(st1.prompt_tokens_ingested, p1.len() as u64);
        assert_eq!(st1.prefill_ticks, 3, "148 tokens = 3 chunks at 1 chunk/tick");

        // identical prompt again: its prefixes were only first-sighted
        // above, so nothing was deposited and this run must fully
        // prefill again (a miss) — the deposits happen during THIS run
        let r1b = handle.generate_blocking(GenerateRequest {
            id: 2,
            prompt: p1.clone(),
            max_new: 6,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(r1b.error.is_none(), "{:?}", r1b.error);
        assert_eq!(r1b.tokens, direct1, "greedy outputs never depend on the cache");
        let st1b = handle.stats();
        assert_eq!(
            st1b.state_cache.hits, 0,
            "first sighting must not have deposited a snapshot"
        );
        assert_eq!(st1b.state_cache.misses, 2);
        assert_eq!(st1b.prompt_tokens_skipped, 0);
        assert_eq!(st1b.prompt_tokens_ingested, 2 * p1.len() as u64);

        let r2 = handle.generate_blocking(GenerateRequest {
            id: 3,
            prompt: p2.clone(),
            max_new: 6,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(r2.error.is_none(), "{:?}", r2.error);
        assert_eq!(
            r2.tokens, direct2,
            "warm (restored-prefix) run must be bit-identical to a cold run"
        );
        let st2 = handle.stats();
        assert_eq!(st2.state_cache.hits, 1, "the shared prefix must hit");
        assert_eq!(
            st2.prompt_tokens_skipped,
            shared.len() as u64,
            "exactly the shared prefix must be skipped"
        );
        assert_eq!(
            st2.prompt_tokens_ingested,
            (2 * p1.len() + p2.len() - shared.len()) as u64,
            "the third request must ingest only its non-shared suffix"
        );
        assert_eq!(
            st2.prefill_ticks - st1b.prefill_ticks,
            1,
            "the 35-token suffix needs a single prefill tick"
        );
        assert_eq!(st2.state_cache.evictions, 0, "a 16 MiB budget fits two tiny entries");
        handle.shutdown();
    }

    #[test]
    fn shared_prefix_restore_skips_prefill_and_matches_cold_run() {
        shared_prefix_restore_case(AttentionKind::Linear);
    }

    #[test]
    fn shared_prefix_restore_skips_prefill_and_matches_cold_run_softmax() {
        shared_prefix_restore_case(AttentionKind::Softmax);
    }

    #[test]
    fn global_prefill_chunk_budget_caps_work_per_tick_without_changing_tokens() {
        // three 128-token prompts admitted in the same batch: the
        // per-slot cap alone lets one tick absorb 3 chunks; a global
        // budget of 1 spreads the same 6 chunks over >= 6 ticks — and
        // neither schedule may move a single output token
        let model = long_model();
        let vocab = model.cfg.vocab;
        let cases: Vec<Vec<u32>> = (0..3).map(|i| prompt_of(128, vocab, 95 + i)).collect();
        let direct: Vec<Vec<u32>> = cases.iter().map(|p| model.generate(p, 4, 0.0, 0)).collect();
        let mut prefill_ticks = Vec::new();
        for budget in [1usize, 0] {
            let mut handle = NativeEngine::spawn(
                long_model(),
                ServeConfig {
                    max_batch: 3,
                    max_wait_us: 50_000, // all three land in one released batch
                    prefill_chunks_per_tick: 1_000_000, // per-slot effectively unbounded
                    prefill_chunk_budget: budget,
                    ..Default::default()
                },
            )
            .unwrap();
            let rxs: Vec<_> = cases
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    handle.submit(GenerateRequest {
                        id: i as u64,
                        prompt: p.clone(),
                        max_new: 4,
                        temperature: 0.0,
                        top_k: 0,
                    })
                })
                .collect();
            for rx in rxs {
                let resp = rx.recv().unwrap();
                assert!(resp.error.is_none(), "{:?}", resp.error);
                assert_eq!(
                    resp.tokens, direct[resp.id as usize],
                    "the global chunk budget must never change tokens (budget {budget})"
                );
            }
            let st = handle.stats();
            assert_eq!(st.prompt_tokens_ingested, 3 * 128);
            prefill_ticks.push(st.prefill_ticks);
            handle.shutdown();
        }
        assert!(
            prefill_ticks[0] >= 6,
            "budget 1 must spread 6 chunks over >= 6 ticks, took {}",
            prefill_ticks[0]
        );
        assert!(
            prefill_ticks[1] <= 3,
            "unlimited budget + unbounded per-slot cap must ingest in the admission \
             tick(s), took {}",
            prefill_ticks[1]
        );
    }

    #[test]
    fn invalid_temperature_is_rejected_at_admission() {
        let mut handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -0.5] {
            let resp = handle.generate_blocking(GenerateRequest {
                id: 1,
                prompt: vec![1, 2],
                max_new: 2,
                temperature: bad,
                top_k: 0,
            });
            assert!(
                resp.error.as_deref().unwrap_or("").contains("temperature"),
                "temperature {bad} must be rejected, got {:?}",
                resp.error
            );
            assert!(resp.tokens.is_empty());
        }
        // the worker keeps serving, and temperature 0 is still fine
        let ok = handle.generate_blocking(GenerateRequest {
            id: 2,
            prompt: vec![1, 2],
            max_new: 3,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(ok.error.is_none(), "{:?}", ok.error);
        assert_eq!(ok.tokens.len(), 3);
        handle.shutdown();
    }

    #[test]
    fn top_k_one_is_deterministic_greedy_at_any_temperature() {
        // per-request top_k plumbing: k = 1 collapses sampling to argmax
        // no matter the temperature, so it must reproduce greedy direct
        // generation exactly — including across the prefill-sampled
        // first token and the per-tick sampled rest
        let model = tiny_model();
        let greedy = model.generate(&[3, 1, 4], 8, 0.0, 0);
        let mut handle = NativeEngine::spawn(tiny_model(), ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 1,
            prompt: vec![3, 1, 4],
            max_new: 8,
            temperature: 5.0,
            top_k: 1,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens, greedy, "top_k = 1 must be greedy regardless of temperature");
        handle.shutdown();
    }

    #[test]
    fn full_length_prompt_yields_one_truncated_token() {
        // a prompt that already fills max_len leaves room to sample
        // exactly one token from the final position's logits
        let model = tiny_model();
        let max_len = model.cfg.max_len;
        let mut handle = NativeEngine::spawn(model, ServeConfig::default()).unwrap();
        let resp = handle.generate_blocking(GenerateRequest {
            id: 8,
            prompt: vec![1; max_len],
            max_new: 5,
            temperature: 0.0,
            top_k: 0,
        });
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens.len(), 1);
        assert!(resp.truncated);
        handle.shutdown();
    }
}
