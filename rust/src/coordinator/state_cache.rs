//! Prefix-reuse state cache: skip prefill for shared prompt prefixes.
//!
//! The paper's decode state is a **fixed-size** (S, Z) pair per
//! layer×head (eqs 16-20), so the entire attention memory of a prompt
//! prefix is one small flat buffer — a [`LaneSnapshot`] — no matter how
//! long the prefix is. This cache keys such snapshots by the exact token
//! prefix that produced them; a later request whose prompt shares a
//! cached prefix (system prompts, few-shot templates, multi-turn chat)
//! restores the snapshot into its lane and ingests only the non-shared
//! suffix. Restore is a memcpy and bit-identical to having prefilled the
//! prefix in place, so outputs never depend on whether a request hit the
//! cache.
//!
//! Design points:
//!
//! * **Chunk alignment.** Entries exist only at multiples of the
//!   backend's prefill granularity (`PREFILL_CHUNK` tokens for the
//!   native engine): the engine deposits snapshots exactly when a
//!   prefilling lane's cursor crosses a chunk boundary, so lookups only
//!   ever need to probe `prompt_len / chunk` candidate lengths.
//! * **Second-chance deposit admission.** A snapshot is deposited only
//!   for a prefix whose chunk-aligned hash has been *sighted before*
//!   ([`StateCache::note_and_should_deposit`]): the first request
//!   carrying a prefix just registers it, the second deposits. One-off
//!   prompts — the common case under diverse traffic — therefore never
//!   pay the snapshot copy, and can never evict genuinely shared
//!   prefixes out of the LRU budget.
//! * **Hash-keyed, collision-safe.** The primary key is an FNV-1a hash
//!   of the token prefix; each hash bucket stores the full token slice
//!   and verifies it on lookup, so a hash collision degrades to a probe,
//!   never to restoring the wrong state. The engine supplies the hash
//!   from the slot's *running* prefix fold (extended chunk by chunk as
//!   prefill advances), so deposits cost O(chunk) hashing, not
//!   O(cursor).
//! * **LRU under a byte budget.** `insert` evicts least-recently-used
//!   entries until the new snapshot fits; an entry larger than the whole
//!   budget is refused outright.
//! * **Eviction never races a restore.** Snapshots are handed out as
//!   [`Arc`] clones; evicting an entry drops only the cache's reference,
//!   so a restore that is mid-flight (or merely scheduled) keeps its
//!   snapshot alive until it is done with it.
//!
//! The cache is owned by the engine worker thread (one per engine) and
//! is purely in-memory; `--state-cache-mb` / `LINTRA_STATE_CACHE_MB`
//! size it (0 = off, the default).
//!
//! # Example
//!
//! ```
//! use linear_transformer::attention::AttentionKind;
//! use linear_transformer::config::ModelConfig;
//! use linear_transformer::coordinator::state_cache::StateCache;
//! use linear_transformer::nn::TransformerLM;
//!
//! let model = TransformerLM::init(&ModelConfig::small_copy(), AttentionKind::Linear, 0);
//! let mut sess = model.batched_session(1);
//! sess.alloc_row();
//! let prompt = [7u32, 8, 9, 10, 11, 12];
//! sess.prefill_row_partial(0, &prompt[..4], false); // ingest the prefix
//! let mut cache = StateCache::new(1 << 20, 4);
//! cache.insert(&prompt[..4], sess.export_lane(0));
//! // a prompt sharing the 4-token prefix restores it and skips ahead
//! let (skip, snap) = cache.lookup(&prompt).expect("prefix cached");
//! assert_eq!(skip, 4);
//! assert_eq!(snap.pos, 4); // the snapshot carries the lane's cursor
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use crate::nn::LaneSnapshot;

/// FNV-1a offset basis / prime (64-bit). The offset is pub(crate) so
/// [`crate::coordinator::sessions::SlotInfo`] can seed its running
/// prefix hash with the same scheme.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one token into a running FNV-1a hash (the slot table maintains
/// an incremental `prompt[..cursor]` hash with this exact fold, so
/// engine-side keys never need a from-scratch rehash).
#[inline]
pub(crate) fn fnv1a_extend(mut h: u64, token: u32) -> u64 {
    for b in token.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Hash of a whole token prefix. `lookup` keeps its own incremental
/// per-boundary fold of [`fnv1a_extend`]; every other key computation
/// must go through this so the schemes can never desynchronize.
pub(crate) fn hash_tokens(tokens: &[u32]) -> u64 {
    tokens.iter().fold(FNV_OFFSET, |h, &t| fnv1a_extend(h, t))
}

/// One cached prefix: the exact tokens (collision verification), the
/// snapshot, a recency stamp, and the entry's accounted byte cost.
struct Entry {
    tokens: Box<[u32]>,
    snap: Arc<LaneSnapshot>,
    last_used: u64,
    bytes: usize,
}

impl Entry {
    fn cost(tokens: &[u32], snap: &LaneSnapshot) -> usize {
        // snapshot payload + key tokens + a flat allowance for the
        // entry/bucket/Arc bookkeeping, so the budget tracks real memory
        snap.bytes() + tokens.len() * std::mem::size_of::<u32>() + 128
    }
}

/// First-sighting set bound: when the admission set reaches this many
/// hashes it is cleared wholesale. Forgetting a first sighting only
/// delays that prefix's deposit by one more encounter — a latency cost,
/// never a correctness one — and the bound keeps the set's memory (8
/// bytes/hash + table overhead) negligible next to the snapshot budget.
const SEEN_CAP: usize = 1 << 16;

/// Chunk-aligned prefix → lane-snapshot map with LRU byte-budget
/// eviction. See the module docs for the contract.
pub struct StateCache {
    budget: usize,
    chunk: usize,
    buckets: HashMap<u64, Vec<Entry>>,
    bytes: usize,
    entries: usize,
    clock: u64,
    /// Deposit admission (second-chance): hashes of chunk-aligned
    /// prefixes sighted at least once. A snapshot is only deposited for
    /// a prefix whose hash is already here — i.e. on its second
    /// sighting — so one-off prompts never pay the snapshot copy or
    /// evict genuinely shared prefixes.
    seen: std::collections::HashSet<u64>,
}

impl StateCache {
    /// A cache holding at most `budget` bytes of entries, keyed at
    /// multiples of `chunk` tokens (the backend's prefill granularity).
    pub fn new(budget: usize, chunk: usize) -> Self {
        assert!(chunk >= 1, "chunk alignment must be at least one token");
        StateCache {
            budget,
            chunk,
            buckets: HashMap::new(),
            bytes: 0,
            entries: 0,
            clock: 0,
            seen: std::collections::HashSet::new(),
        }
    }

    /// Record a sighting of a chunk-aligned prefix (by its
    /// [`hash_tokens`]-scheme hash) and report whether a snapshot for it
    /// should be deposited now: `false` on the first sighting (the hash
    /// is merely remembered), `true` from the second sighting on. The
    /// caller still guards with [`Self::contains`] — this method decides
    /// *admission*, not dedup.
    pub fn note_and_should_deposit(&mut self, hash: u64) -> bool {
        if self.seen.contains(&hash) {
            return true;
        }
        if self.seen.len() >= SEEN_CAP {
            self.seen.clear();
        }
        self.seen.insert(hash);
        false
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// Accounted bytes currently held (always <= the budget).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// The byte budget this cache evicts down to.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Longest cached chunk-aligned prefix of `prompt` that is *strictly
    /// shorter* than the prompt (at least one token must remain to
    /// prefill, so the finishing slice can produce the first-token
    /// logits). Returns the prefix length and the snapshot; bumps the
    /// entry's recency. O(prompt_len / chunk) probes, one forward hash
    /// pass over the prompt.
    pub fn lookup(&mut self, prompt: &[u32]) -> Option<(usize, Arc<LaneSnapshot>)> {
        if self.entries == 0 || prompt.len() <= self.chunk {
            return None;
        }
        // prefix hashes at every aligned length, one forward FNV pass
        let max_k = (prompt.len() - 1) / self.chunk; // k*chunk < prompt.len()
        let mut hashes = Vec::with_capacity(max_k);
        let mut h = FNV_OFFSET;
        // lintra: allow(panic) -- max_k * chunk <= prompt.len() - 1 by construction
        for (i, &t) in prompt[..max_k * self.chunk].iter().enumerate() {
            h = fnv1a_extend(h, t);
            if (i + 1) % self.chunk == 0 {
                hashes.push(h);
            }
        }
        for k in (1..=max_k).rev() {
            let n = k * self.chunk;
            // lintra: allow(panic) -- hashes holds exactly max_k entries and k >= 1
            let Some(bucket) = self.buckets.get_mut(&hashes[k - 1]) else {
                continue;
            };
            // lintra: allow(panic) -- n = k * chunk <= max_k * chunk < prompt.len()
            if let Some(e) = bucket.iter_mut().find(|e| *e.tokens == prompt[..n]) {
                self.clock += 1;
                e.last_used = self.clock;
                return Some((n, e.snap.clone()));
            }
        }
        None
    }

    /// True if exactly this prefix is already cached (no recency bump).
    pub fn contains(&self, prefix: &[u32]) -> bool {
        self.contains_hashed(hash_tokens(prefix), prefix)
    }

    /// [`Self::contains`] with the caller supplying the prefix's
    /// [`hash_tokens`]-scheme hash — the engine passes the slot's running
    /// prefix hash here, so the deposit path never rehashes from
    /// position 0.
    pub fn contains_hashed(&self, hash: u64, prefix: &[u32]) -> bool {
        debug_assert_eq!(hash, hash_tokens(prefix), "caller-supplied hash desynchronized");
        self.buckets
            .get(&hash)
            .is_some_and(|b| b.iter().any(|e| *e.tokens == *prefix))
    }

    /// Deposit a snapshot for `prefix` (which must be a non-empty
    /// multiple of the chunk alignment — the engine only calls this at
    /// chunk boundaries). Evicts LRU entries until the snapshot fits.
    /// Returns how many entries were evicted. A duplicate prefix only
    /// refreshes recency; a snapshot larger than the whole budget is
    /// refused (nothing is evicted for it).
    pub fn insert(&mut self, prefix: &[u32], snap: LaneSnapshot) -> usize {
        self.insert_hashed(hash_tokens(prefix), prefix, snap)
    }

    /// [`Self::insert`] with a caller-supplied [`hash_tokens`]-scheme
    /// hash (see [`Self::contains_hashed`]).
    pub fn insert_hashed(&mut self, h: u64, prefix: &[u32], snap: LaneSnapshot) -> usize {
        debug_assert_eq!(h, hash_tokens(prefix), "caller-supplied hash desynchronized");
        debug_assert!(
            !prefix.is_empty() && prefix.len() % self.chunk == 0,
            "cache keys must be non-empty chunk-aligned prefixes"
        );
        debug_assert_eq!(
            snap.pos,
            prefix.len(),
            "snapshot position must match the prefix it claims to hold"
        );
        self.clock += 1;
        if let Some(bucket) = self.buckets.get_mut(&h) {
            if let Some(e) = bucket.iter_mut().find(|e| *e.tokens == *prefix) {
                e.last_used = self.clock;
                return 0;
            }
        }
        let cost = Entry::cost(prefix, &snap);
        if cost > self.budget {
            return 0; // would evict everything and still not fit
        }
        let mut evicted = 0;
        while self.bytes + cost > self.budget {
            if !self.evict_lru() {
                // nothing left to evict yet still over budget: the
                // accounting drifted (debug builds catch this in
                // debug_check_accounting); refuse the insert rather
                // than loop forever or panic
                return evicted;
            }
            evicted += 1;
        }
        self.bytes += cost;
        self.entries += 1;
        self.buckets.entry(h).or_default().push(Entry {
            tokens: prefix.into(),
            snap: Arc::new(snap),
            last_used: self.clock,
            bytes: cost,
        });
        evicted
    }

    /// Drop the least-recently-used entry; reports whether one existed.
    /// The snapshot itself survives in any [`Arc`] a caller still holds —
    /// eviction only releases the cache's reference, so it can never
    /// invalidate an in-flight restore.
    fn evict_lru(&mut self) -> bool {
        debug_assert!(self.entries > 0, "evict_lru on an empty cache");
        let mut victim: Option<(u64, usize, u64)> = None; // (hash, idx, last_used)
        for (&h, bucket) in &self.buckets {
            for (i, e) in bucket.iter().enumerate() {
                if victim.is_none_or(|(_, _, lu)| e.last_used < lu) {
                    victim = Some((h, i, e.last_used));
                }
            }
        }
        let Some((h, i, _)) = victim else {
            return false; // empty cache: nothing to evict
        };
        let Some(bucket) = self.buckets.get_mut(&h) else {
            return false; // victim bucket vanished (unreachable)
        };
        let e = bucket.swap_remove(i);
        self.bytes = self.bytes.saturating_sub(e.bytes);
        self.entries -= 1;
        if bucket.is_empty() {
            self.buckets.remove(&h);
        }
        true
    }

    /// Re-derive the byte/entry accounting from the buckets themselves
    /// and assert it matches the running counters. Called once per engine
    /// tick by `propcheck::engine_invariants::check_tick`; a no-op in
    /// release builds (unless `-C debug-assertions` is on, as in the CI
    /// release test leg).
    pub fn debug_check_accounting(&self) {
        if !cfg!(debug_assertions) {
            return;
        }
        let mut bytes = 0usize;
        let mut entries = 0usize;
        for bucket in self.buckets.values() {
            for e in bucket {
                bytes += e.bytes;
                entries += 1;
            }
        }
        debug_assert_eq!(bytes, self.bytes, "state-cache byte accounting drifted");
        debug_assert_eq!(entries, self.entries, "state-cache entry accounting drifted");
        debug_assert!(
            self.bytes <= self.budget,
            "state-cache holds {} bytes over its {} byte budget",
            self.bytes,
            self.budget
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::AttentionKind;
    use crate::config::ModelConfig;
    use crate::nn::TransformerLM;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            vocab: 11,
            d_model: 32,
            n_heads: 2,
            n_layers: 2,
            max_len: 64,
            d_ff: 64,
            chunk: 16,
            causal: true,
            lsh_rounds: 1,
            lsh_buckets: 8,
            lsh_chunk: 8,
        }
    }

    /// A real snapshot whose `pos` matches `n` ingested tokens.
    fn snap_at(model: &TransformerLM, tokens: &[u32]) -> LaneSnapshot {
        let mut sess = model.batched_session(1);
        sess.alloc_row().unwrap();
        if !tokens.is_empty() {
            sess.prefill_row_partial(0, tokens, false);
        }
        sess.export_lane(0)
    }

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        let mut rng = crate::rng::Rng::new(seed);
        (0..n).map(|_| rng.below(11) as u32).collect()
    }

    #[test]
    fn lookup_finds_longest_aligned_prefix_only() {
        let model = TransformerLM::init(&tiny_cfg(), AttentionKind::Linear, 1);
        let mut cache = StateCache::new(1 << 20, 4);
        let prompt = toks(14, 10);
        cache.insert(&prompt[..4], snap_at(&model, &prompt[..4]));
        cache.insert(&prompt[..12], snap_at(&model, &prompt[..12]));
        // a foreign prefix of the same length must not match
        let mut other = prompt[..8].to_vec();
        other[0] ^= 1;
        cache.insert(&other, snap_at(&model, &other));
        assert_eq!(cache.len(), 3);

        let (n, snap) = cache.lookup(&prompt).expect("hit");
        assert_eq!(n, 12, "the longest cached aligned prefix wins");
        assert_eq!(snap.pos, 12);
        // a prompt exactly as long as its cached prefix cannot hit it —
        // at least one token must remain for the finishing prefill slice
        let (n, _) = cache.lookup(&prompt[..12]).expect("shorter entry still hits");
        assert_eq!(n, 4);
        assert!(cache.lookup(&prompt[..4]).is_none());
        // a prompt differing inside the first chunk (and not matching
        // the `other` entry either) shares no cached prefix: miss
        let mut foreign = prompt.clone();
        foreign[1] ^= 1;
        assert!(cache.lookup(&foreign).is_none());
    }

    #[test]
    fn eviction_under_pressure_is_lru_and_budget_bounded() {
        let model = TransformerLM::init(&tiny_cfg(), AttentionKind::Linear, 2);
        let probe = snap_at(&model, &[1, 2, 3, 4]);
        let cost = Entry::cost(&[1, 2, 3, 4], &probe);
        // room for exactly two entries
        let mut cache = StateCache::new(2 * cost + cost / 2, 4);
        let (a, b, c) = (vec![1u32, 2, 3, 4], vec![5u32, 6, 7, 8], vec![9u32, 10, 0, 1]);
        assert_eq!(cache.insert(&a, snap_at(&model, &a)), 0);
        assert_eq!(cache.insert(&b, snap_at(&model, &b)), 0);
        assert_eq!(cache.len(), 2);
        // touch `a` so `b` becomes the LRU victim
        let mut probe_a = a.clone();
        probe_a.push(0);
        assert!(cache.lookup(&probe_a).is_some());
        assert_eq!(cache.insert(&c, snap_at(&model, &c)), 1, "one eviction to fit");
        assert_eq!(cache.len(), 2);
        assert!(cache.bytes() <= cache.budget());
        cache.debug_check_accounting();
        assert!(cache.contains(&a), "recently used entry must survive");
        assert!(!cache.contains(&b), "LRU entry must be the victim");
        assert!(cache.contains(&c));
        // a snapshot bigger than the whole budget is refused, evicting nothing
        let mut tiny = StateCache::new(8, 4);
        assert_eq!(tiny.insert(&a, snap_at(&model, &a)), 0);
        assert!(tiny.is_empty());
    }

    #[test]
    fn eviction_never_invalidates_a_handed_out_snapshot() {
        // the refcount-vs-evict contract: an Arc obtained from lookup
        // stays alive and intact after the entry is evicted
        let model = TransformerLM::init(&tiny_cfg(), AttentionKind::Linear, 3);
        let a = vec![1u32, 2, 3, 4];
        let probe = snap_at(&model, &a);
        let cost = Entry::cost(&a, &probe);
        let mut cache = StateCache::new(cost + cost / 4, 4); // exactly one entry fits
        cache.insert(&a, probe.clone());
        let mut probe_a = a.clone();
        probe_a.push(0);
        let (_, held) = cache.lookup(&probe_a).expect("hit");
        // force the eviction of `a`
        let b = vec![5u32, 6, 7, 8];
        assert_eq!(cache.insert(&b, snap_at(&model, &b)), 1);
        assert!(!cache.contains(&a), "entry evicted");
        // the handed-out snapshot is still the exact state we inserted
        assert_eq!(*held, probe, "evicted snapshot must survive via its Arc");
        assert_eq!(Arc::strong_count(&held), 1, "cache reference released");
    }

    #[test]
    fn duplicate_insert_refreshes_recency_without_growth() {
        let model = TransformerLM::init(&tiny_cfg(), AttentionKind::Linear, 4);
        let probe = snap_at(&model, &[1, 2, 3, 4]);
        let cost = Entry::cost(&[1, 2, 3, 4], &probe);
        let mut cache = StateCache::new(2 * cost + cost / 2, 4);
        let (a, b, c) = (vec![1u32, 2, 3, 4], vec![5u32, 6, 7, 8], vec![9u32, 10, 0, 1]);
        cache.insert(&a, snap_at(&model, &a));
        let bytes = cache.bytes();
        cache.insert(&a, snap_at(&model, &a));
        assert_eq!(cache.len(), 1, "duplicate insert must not duplicate the entry");
        assert_eq!(cache.bytes(), bytes);
        // the refresh protects `a` from the next eviction
        cache.insert(&b, snap_at(&model, &b));
        cache.insert(&a, snap_at(&model, &a)); // refresh again: b is now LRU
        cache.insert(&c, snap_at(&model, &c));
        assert!(cache.contains(&a) && !cache.contains(&b) && cache.contains(&c));
    }

    #[test]
    fn no_deposit_on_first_sight() {
        // second-chance admission: the first sighting of a prefix hash
        // must answer "don't deposit" and only register it; the second
        // (and every later) sighting admits
        let mut cache = StateCache::new(1 << 20, 4);
        let a = hash_tokens(&[1, 2, 3, 4]);
        let b = hash_tokens(&[5, 6, 7, 8]);
        assert!(!cache.note_and_should_deposit(a), "first sighting must not deposit");
        assert!(cache.is_empty(), "a sighting alone must not create entries");
        assert!(!cache.note_and_should_deposit(b), "sightings are tracked per hash");
        assert!(cache.note_and_should_deposit(a), "second sighting admits");
        assert!(cache.note_and_should_deposit(a), "and it keeps admitting");
        assert!(cache.note_and_should_deposit(b));
    }

    #[test]
    fn hashed_entry_points_match_their_rehashing_counterparts() {
        // contains_hashed/insert_hashed with a correct caller-side hash
        // must behave exactly like contains/insert
        let model = TransformerLM::init(&tiny_cfg(), AttentionKind::Linear, 6);
        let a = vec![1u32, 2, 3, 4];
        let mut cache = StateCache::new(1 << 20, 4);
        let h = hash_tokens(&a);
        assert!(!cache.contains_hashed(h, &a));
        assert_eq!(cache.insert_hashed(h, &a, snap_at(&model, &a)), 0);
        assert!(cache.contains_hashed(h, &a));
        assert!(cache.contains(&a));
        let mut probe = a.clone();
        probe.push(0);
        let (n, _) = cache.lookup(&probe).expect("hashed insert must be visible to lookup");
        assert_eq!(n, 4);
    }

    #[test]
    fn hash_collisions_degrade_to_probes_not_wrong_state() {
        // force two different prefixes into the same bucket by hand:
        // verification against the stored tokens must keep them apart
        let model = TransformerLM::init(&tiny_cfg(), AttentionKind::Linear, 5);
        let a = vec![1u32, 2, 3, 4];
        let mut cache = StateCache::new(1 << 20, 4);
        cache.insert(&a, snap_at(&model, &a));
        let h = hash_tokens(&a);
        let b = vec![5u32, 6, 7, 8];
        let fake = snap_at(&model, &b);
        cache.buckets.get_mut(&h).unwrap().push(Entry {
            bytes: Entry::cost(&b, &fake),
            tokens: b.clone().into_boxed_slice(),
            snap: Arc::new(fake),
            last_used: 0,
        });
        cache.entries += 1;
        let mut probe = a.clone();
        probe.push(0);
        let (n, snap) = cache.lookup(&probe).expect("hit");
        assert_eq!(n, 4);
        assert_eq!(*snap, snap_at(&model, &a), "collision must never return foreign state");
    }
}
