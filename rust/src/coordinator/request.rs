//! Request/response types and the JSON-lines wire codec.
//!
//! # Example
//!
//! ```
//! use linear_transformer::coordinator::request::GenerateRequest;
//! use linear_transformer::json::Json;
//!
//! let wire = r#"{"id": 7, "prompt": [12, 3, 4], "max_new": 8}"#;
//! let req = GenerateRequest::from_json(&Json::parse(wire).unwrap()).unwrap();
//! assert_eq!(req.prompt, vec![12, 3, 4]);
//! assert_eq!(req.max_new, 8);
//! assert_eq!(req.temperature, 1.0); // omitted fields take defaults
//! assert_eq!(req.top_k, 0); // 0 = unrestricted sampling
//! ```

use crate::json::{obj, Json};

/// A generation request.
#[derive(Clone, Debug, PartialEq)]
pub struct GenerateRequest {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub max_new: usize,
    /// Per-request sampling temperature (`0` = greedy argmax). Must be
    /// finite and non-negative; the engine rejects anything else at
    /// admission.
    pub temperature: f32,
    /// Per-request top-k sampling cutoff (`0` = unrestricted, the
    /// default — preserving pre-top-k behavior exactly; `1` = greedy).
    pub top_k: usize,
}

impl GenerateRequest {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            (
                "prompt",
                Json::Arr(self.prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("max_new", Json::Num(self.max_new as f64)),
            ("temperature", Json::Num(self.temperature as f64)),
        ];
        if self.top_k != 0 {
            pairs.push(("top_k", Json::Num(self.top_k as f64)));
        }
        obj(pairs)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<GenerateRequest> {
        let id = j
            .get("id")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("request missing id"))? as u64;
        let prompt = j
            .get("prompt")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("request missing prompt"))?
            .iter()
            .map(|v| v.as_f64().map(|x| x as u32))
            .collect::<Option<Vec<u32>>>()
            .ok_or_else(|| anyhow::anyhow!("prompt must be an int array"))?;
        if prompt.is_empty() {
            anyhow::bail!("prompt must not be empty");
        }
        let top_k = match j.get("top_k") {
            None => 0,
            Some(v) => {
                // a negative or fractional top_k silently cast to usize
                // would sample from the wrong support — fail loudly
                let n = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("top_k must be a non-negative integer"))?;
                if !n.is_finite() || n < 0.0 || n.fract() != 0.0 {
                    anyhow::bail!("top_k must be a non-negative integer, got {n}");
                }
                n as usize
            }
        };
        Ok(GenerateRequest {
            id,
            prompt,
            max_new: j.get("max_new").and_then(|v| v.as_usize()).unwrap_or(16),
            temperature: j
                .get("temperature")
                .and_then(|v| v.as_f64())
                .unwrap_or(1.0) as f32,
            top_k,
        })
    }
}

/// A completed generation.
#[derive(Clone, Debug, PartialEq)]
pub struct GenerateResponse {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// end-to-end latency in microseconds
    pub latency_us: u64,
    /// true when generation stopped at the model's `max_len` before
    /// producing `max_new` tokens (previously indistinguishable from a
    /// normal completion)
    pub truncated: bool,
    pub error: Option<String>,
}

impl GenerateResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            (
                "tokens",
                Json::Arr(self.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
            ),
            ("latency_us", Json::Num(self.latency_us as f64)),
        ];
        if self.truncated {
            pairs.push(("truncated", Json::Bool(true)));
        }
        if let Some(e) = &self.error {
            pairs.push(("error", Json::Str(e.clone())));
        }
        obj(pairs)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<GenerateResponse> {
        Ok(GenerateResponse {
            id: j
                .get("id")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("response missing id"))? as u64,
            tokens: j
                .get("tokens")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_f64().map(|x| x as u32)).collect())
                .unwrap_or_default(),
            latency_us: j.get("latency_us").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            truncated: j.get("truncated").and_then(|v| v.as_bool()).unwrap_or(false),
            error: j.get("error").and_then(|v| v.as_str()).map(String::from),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let r = GenerateRequest {
            id: 42,
            prompt: vec![1, 2, 3],
            max_new: 8,
            temperature: 0.5,
            top_k: 40,
        };
        let back = GenerateRequest::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn request_defaults() {
        let j = Json::parse(r#"{"id": 1, "prompt": [5]}"#).unwrap();
        let r = GenerateRequest::from_json(&j).unwrap();
        assert_eq!(r.max_new, 16);
        assert_eq!(r.temperature, 1.0);
        assert_eq!(r.top_k, 0, "omitted top_k must mean unrestricted sampling");
        // top_k == 0 stays off the wire (legacy-clients compat)
        assert!(!r.to_json().to_string().contains("top_k"));
    }

    #[test]
    fn invalid_top_k_is_rejected_at_parse() {
        for bad in [r#"{"id":1,"prompt":[5],"top_k":-3}"#, r#"{"id":1,"prompt":[5],"top_k":1.5}"#]
        {
            let j = Json::parse(bad).unwrap();
            assert!(GenerateRequest::from_json(&j).is_err(), "{bad} must be rejected");
        }
        let ok = Json::parse(r#"{"id":1,"prompt":[5],"top_k":2}"#).unwrap();
        assert_eq!(GenerateRequest::from_json(&ok).unwrap().top_k, 2);
    }

    #[test]
    fn empty_prompt_rejected() {
        let j = Json::parse(r#"{"id": 1, "prompt": []}"#).unwrap();
        assert!(GenerateRequest::from_json(&j).is_err());
    }

    #[test]
    fn response_roundtrip_with_error() {
        let r = GenerateResponse {
            id: 7,
            tokens: vec![],
            latency_us: 1234,
            truncated: false,
            error: Some("boom".into()),
        };
        let back = GenerateResponse::from_json(&r.to_json()).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn truncated_flag_roundtrips_and_defaults_false() {
        let r = GenerateResponse {
            id: 8,
            tokens: vec![1, 2],
            latency_us: 10,
            truncated: true,
            error: None,
        };
        let j = r.to_json();
        assert!(j.to_string().contains("\"truncated\":true"));
        assert_eq!(GenerateResponse::from_json(&j).unwrap(), r);
        // absent field parses as not-truncated (wire compat)
        let legacy = Json::parse(r#"{"id": 1, "tokens": [3], "latency_us": 5}"#).unwrap();
        assert!(!GenerateResponse::from_json(&legacy).unwrap().truncated);
    }
}
