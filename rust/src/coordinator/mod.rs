//! Layer-3 coordinator: the serving engine.
//!
//! Architecture (vLLM-router-shaped, adapted to RNN-state decode):
//!
//! ```text
//!   clients ──> server (TCP json-lines) ─┐
//!   in-process callers ──────────────────┼──> EngineHandle (mpsc)
//!                                        │
//!                         worker thread ─┴─> Batcher ──> SlotTable
//!                                              │             │
//!                                        decode tick    per-slot RNN
//!                                        (native nn or   state (S, Z)
//!                                         PJRT artifact)
//! ```
//!
//! The paper's property that makes this engine *simple* is the O(1)
//! per-token, fixed-size recurrent state (eqs 16-20): a decode slot is
//! just (S, Z) — no paged KV cache, no prefix eviction. Continuous
//! batching is a gather over slot states; admission is a free-slot pop.
//!
//! Modules:
//! * [`request`]  — request/response types + JSON wire codec
//! * [`batcher`]  — pure batching policy (deadline + capacity), propchecked
//! * [`sessions`] — slot allocator with leak-freedom invariants
//! * [`engine`]   — the worker loop over the native model (Send-safe) and
//!   the PJRT batched-decode loop (runtime created inside the worker)
//! * [`server`]   — TCP JSON-lines front-end

pub mod batcher;
pub mod engine;
pub mod request;
pub mod server;
pub mod sessions;

pub use engine::{EngineHandle, EngineStats, NativeEngine};
pub use request::{GenerateRequest, GenerateResponse};
