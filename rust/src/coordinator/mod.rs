//! Layer-3 coordinator: the serving engine.
//!
//! Architecture (vLLM-router-shaped, adapted to RNN-state decode):
//!
//! ```text
//!   clients ──> server (TCP json-lines) ─┐
//!   in-process callers ──────────────────┼──> EngineHandle (mpsc)
//!                                        │
//!                         worker thread ─┴─> Batcher ──> SlotTable
//!                                              │             │
//!                                        decode tick    per-slot RNN
//!                                        (native nn or   state (S, Z)
//!                                         PJRT artifact)
//! ```
//!
//! The paper's property that makes this engine *simple* is the O(1)
//! per-token, fixed-size recurrent state (eqs 16-20): a decode slot is
//! just (S, Z) — no paged KV cache, no prefix eviction. Continuous
//! batching keeps every slot's state as a dense row of one contiguous
//! block ([`engine::DecodeBackend`] lanes); admission appends a zeroed
//! row, eviction swap-removes it, and one `step_batch` advances the whole
//! batch through `[B, ·]` GEMMs.
//!
//! The same recurrence makes prompt ingestion *pausable*: prefill is a
//! cumulative-state scan, so the engine streams each admitted prompt
//! into its lane a bounded number of chunks per tick (the `Prefilling`
//! slot phase), interleaved with the decode tick of resident lanes —
//! long prompts never stall the batch, and the schedule never changes a
//! single logit (so greedy outputs are schedule-independent). See
//! `ARCHITECTURE.md` at the repo root for the full request lifecycle.
//!
//! And because the recurrent state is *fixed-size*, the whole attention
//! memory of a prompt prefix is one small snapshot: the engine can
//! deposit lane snapshots at chunk boundaries into a prefix-reuse
//! [`state_cache::StateCache`] and, on admission, restore the longest
//! cached prefix of a new prompt instead of prefilling it — multi-turn
//! chats and shared system prompts skip straight past their common
//! prefix, bit-identically (`--state-cache-mb` sizes it; 0 = off).
//!
//! Modules:
//! * [`request`]  — request/response types + JSON wire codec
//! * [`batcher`]  — pure batching policy (deadline + capacity), propchecked
//! * [`sessions`] — slot allocator with leak-freedom invariants + the
//!   per-slot prompt-ingestion state machine ([`sessions::SlotPhase`])
//! * [`engine`]   — the [`engine::DecodeBackend`] trait, the shared
//!   continuous-batching tick loop with incremental prefill scheduling,
//!   and its two backends (native batched GEMM decode; PJRT batched
//!   artifact, runtime created in the worker)
//! * [`state_cache`] — chunk-aligned prefix → lane-snapshot map with
//!   LRU byte-budget eviction (the prefix-reuse cache)
//! * [`server`]   — TCP JSON-lines front-end

pub mod batcher;
pub mod engine;
pub mod request;
pub mod server;
pub mod sessions;
pub mod state_cache;

pub use engine::{DecodeBackend, EngineHandle, EngineStats, NativeEngine};
pub use request::{GenerateRequest, GenerateResponse};
