//! Slot table: maps in-flight requests to decode slots.
//!
//! A slot is one lane of the batched decode state (one (S, Z) RNN pair per
//! layer×head in either engine). The table enforces capacity, guarantees a
//! freed slot is reusable, and never hands the same slot to two requests —
//! invariants propchecked below.
//!
//! Prompt ingestion is a per-slot state machine ([`SlotPhase`]):
//!
//! * a backend with a resumable prefill path admits the slot in
//!   [`SlotPhase::Prefilling`] ([`SlotInfo::start_prefill`]) and absorbs
//!   the prompt chunk by chunk across engine ticks
//!   ([`SlotInfo::advance_prefill`]); when the final prompt token lands
//!   the slot flips to [`SlotPhase::Decoding`] on its own;
//! * a backend without the path admits straight into
//!   [`SlotPhase::Decoding`] and the `cursor` walks the prompt through
//!   the shared tick loop one token at a time. (One-shot ingestion is
//!   just the degenerate schedule: a single `advance_prefill` covering
//!   the whole prompt.)
//!
//! # Example
//!
//! ```
//! use std::time::Instant;
//! use linear_transformer::coordinator::sessions::{SlotInfo, SlotPhase};
//!
//! let mut slot = SlotInfo::new(1, Instant::now(), vec![7, 8, 9], 4, 0.0, 0);
//! slot.start_prefill();
//! slot.advance_prefill(2); // first chunk: two prompt tokens ingested
//! assert_eq!(slot.phase, SlotPhase::Prefilling);
//! slot.advance_prefill(1); // final token lands
//! assert_eq!(slot.phase, SlotPhase::Decoding);
//! assert!(slot.prompt_done());
//! ```

use std::time::Instant;

/// Where a slot's prompt ingestion stands (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPhase {
    /// The prompt is entering the lane's state via resumable prefill
    /// chunks; the lane is excluded from `step_batch` and from sampling
    /// until the final prompt position lands.
    Prefilling,
    /// The lane ticks through `step_batch` (this includes cursor-walk
    /// prompt feeding on backends without a prefill path).
    Decoding,
}

/// Metadata of an active decode slot.
#[derive(Debug, Clone)]
pub struct SlotInfo {
    pub request_id: u64,
    pub started: Instant,
    /// the full prompt; `cursor` indexes the next unconsumed token
    /// (a cursor, not `Vec::remove(0)`, so prompt feed is O(1) per tick)
    pub prompt: Vec<u32>,
    /// how many prompt tokens have been fed already
    pub cursor: usize,
    /// sampled tokens so far
    pub generated: Vec<u32>,
    pub max_new: usize,
    pub temperature: f32,
    /// per-request top-k sampling cutoff (0 = unrestricted)
    pub top_k: usize,
    /// absolute position of the next token to feed
    pub pos: usize,
    /// prompt-ingestion phase (see [`SlotPhase`])
    pub phase: SlotPhase,
    /// Running FNV-1a hash of `prompt[..cursor]`, maintained by
    /// [`Self::advance_prefill`] — i.e. only while the slot is in the
    /// resumable-prefill phase, which is the only place the engine needs
    /// it. At any chunk boundary it equals
    /// `state_cache::hash_tokens(&prompt[..cursor])`, so the state-cache
    /// deposit path gets its key in O(chunk) incremental work instead of
    /// rehashing the whole prefix from position 0 at every boundary.
    pub prefix_hash: u64,
}

impl SlotInfo {
    /// Fresh slot state for an admitted request.
    pub fn new(
        request_id: u64,
        started: Instant,
        prompt: Vec<u32>,
        max_new: usize,
        temperature: f32,
        top_k: usize,
    ) -> Self {
        SlotInfo {
            request_id,
            started,
            prompt,
            cursor: 0,
            generated: Vec::new(),
            max_new,
            temperature,
            top_k,
            pos: 0,
            phase: SlotPhase::Decoding,
            prefix_hash: crate::coordinator::state_cache::FNV_OFFSET,
        }
    }

    /// Enter the resumable-prefill phase. Must be called before any
    /// prompt token has been fed; the slot stays [`SlotPhase::Prefilling`]
    /// until [`Self::advance_prefill`] consumes the final prompt token.
    pub fn start_prefill(&mut self) {
        assert_eq!(self.cursor, 0, "start_prefill on a partially fed slot");
        assert!(!self.prompt.is_empty(), "nothing to prefill");
        self.phase = SlotPhase::Prefilling;
    }

    /// Prompt tokens not yet ingested.
    pub fn prefill_remaining(&self) -> usize {
        self.prompt.len() - self.cursor
    }

    /// Record that `n` more prompt tokens entered the lane state — via
    /// the prefill path, or via a restored prefix snapshot (the engine's
    /// state cache advances the cursor past the restored tokens with
    /// this same call, so they are never prefilled). Flips the slot to
    /// [`SlotPhase::Decoding`] when the final prompt token lands:
    /// `cursor` and `pos` sit just past the prompt, so the slot's next
    /// tick feeds its first sampled token.
    pub fn advance_prefill(&mut self, n: usize) {
        assert_eq!(self.phase, SlotPhase::Prefilling, "advance_prefill outside prefill");
        assert!(n >= 1 && self.cursor + n <= self.prompt.len(), "chunk overruns the prompt");
        // extend the running prefix hash over exactly the tokens entering
        // the lane (restored prefixes flow through here too, so the hash
        // always covers prompt[..cursor])
        // lintra: allow(panic) -- cursor + n <= prompt.len(), asserted just above
        for &t in &self.prompt[self.cursor..self.cursor + n] {
            self.prefix_hash = crate::coordinator::state_cache::fnv1a_extend(self.prefix_hash, t);
        }
        self.cursor += n;
        self.pos += n;
        if self.cursor == self.prompt.len() {
            self.phase = SlotPhase::Decoding;
        }
    }

    /// The token to feed on the next tick: the prompt under the cursor, or
    /// the last sampled token once the prompt is consumed.
    pub fn next_token(&self) -> u32 {
        if self.cursor < self.prompt.len() {
            self.prompt[self.cursor]
        } else {
            // lintra: allow(panic) -- the engine samples a token before any post-prompt tick
            *self.generated.last().expect("past the prompt there is always a sampled token")
        }
    }

    /// True once every prompt token has been fed.
    pub fn prompt_done(&self) -> bool {
        self.cursor >= self.prompt.len()
    }

}

/// Fixed-capacity slot allocator.
#[derive(Debug)]
pub struct SlotTable {
    slots: Vec<Option<SlotInfo>>,
    free: Vec<usize>,
}

impl SlotTable {
    pub fn new(capacity: usize) -> Self {
        SlotTable {
            slots: (0..capacity).map(|_| None).collect(),
            free: (0..capacity).rev().collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn active(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn has_free(&self) -> bool {
        !self.free.is_empty()
    }

    /// Claim a slot; returns its index.
    pub fn alloc(&mut self, info: SlotInfo) -> Option<usize> {
        let idx = self.free.pop()?;
        debug_assert!(self.slots[idx].is_none(), "slot {idx} double-allocated");
        self.slots[idx] = Some(info);
        Some(idx)
    }

    /// Release a slot, returning its info.
    pub fn release(&mut self, idx: usize) -> Option<SlotInfo> {
        let info = self.slots[idx].take()?;
        self.free.push(idx);
        Some(info)
    }

    pub fn get(&self, idx: usize) -> Option<&SlotInfo> {
        self.slots.get(idx).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, idx: usize) -> Option<&mut SlotInfo> {
        self.slots.get_mut(idx).and_then(|s| s.as_mut())
    }

    /// Indices of active slots (ascending).
    pub fn active_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|_| i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(id: u64) -> SlotInfo {
        SlotInfo::new(id, Instant::now(), vec![1, 2], 4, 0.0, 0)
    }

    #[test]
    fn prompt_cursor_walks_then_repeats_generation() {
        let mut s = info(1);
        assert!(!s.prompt_done());
        assert_eq!(s.next_token(), 1);
        s.cursor += 1;
        assert_eq!(s.next_token(), 2);
        s.cursor += 1;
        assert!(s.prompt_done());
        s.generated.push(7);
        assert_eq!(s.next_token(), 7);
    }

    #[test]
    fn one_shot_prefill_jumps_to_generation() {
        // the degenerate schedule: one advance covering the whole prompt
        let mut s = info(2);
        s.start_prefill();
        s.advance_prefill(2);
        assert!(s.prompt_done());
        assert_eq!(s.pos, 2, "pos must land on the first generation position");
        assert_eq!(s.phase, SlotPhase::Decoding);
        s.generated.push(9);
        assert_eq!(s.next_token(), 9, "next tick feeds the sampled token");
    }

    #[test]
    fn incremental_prefill_reaches_the_same_state_as_one_shot() {
        // chunked advance must land on exactly the single-advance state
        let mut chunked = SlotInfo::new(3, Instant::now(), vec![1, 2, 3, 4, 5], 4, 0.0, 0);
        chunked.start_prefill();
        assert_eq!(chunked.phase, SlotPhase::Prefilling);
        assert_eq!(chunked.prefill_remaining(), 5);
        chunked.advance_prefill(2);
        assert_eq!(chunked.phase, SlotPhase::Prefilling, "mid-prompt stays prefilling");
        assert_eq!(chunked.prefill_remaining(), 3);
        assert_eq!((chunked.cursor, chunked.pos), (2, 2));
        chunked.advance_prefill(3);
        let mut one_shot = SlotInfo::new(3, chunked.started, vec![1, 2, 3, 4, 5], 4, 0.0, 0);
        one_shot.start_prefill();
        one_shot.advance_prefill(5);
        assert_eq!(chunked.phase, SlotPhase::Decoding);
        assert_eq!((chunked.cursor, chunked.pos), (one_shot.cursor, one_shot.pos));
        assert!(chunked.prompt_done());
        chunked.generated.push(9);
        assert_eq!(chunked.next_token(), 9, "post-prefill tick feeds the sampled token");
    }

    #[test]
    fn running_prefix_hash_matches_full_rehash_at_every_boundary() {
        // the incremental fold must agree with hashing prompt[..cursor]
        // from scratch, for any chunking — the state-cache deposit path
        // relies on this equivalence to skip the O(cursor) rehash
        let prompt: Vec<u32> = (0..13).map(|i| (i * 7 + 3) as u32).collect();
        let mut chunked = SlotInfo::new(1, Instant::now(), prompt.clone(), 4, 0.0, 0);
        chunked.start_prefill();
        assert_eq!(
            chunked.prefix_hash,
            crate::coordinator::state_cache::hash_tokens(&[]),
            "a fresh slot hashes the empty prefix"
        );
        for take in [1usize, 4, 2, 6] {
            chunked.advance_prefill(take);
            assert_eq!(
                chunked.prefix_hash,
                crate::coordinator::state_cache::hash_tokens(&prompt[..chunked.cursor]),
                "running hash diverged at cursor {}",
                chunked.cursor
            );
        }
        // one-shot ingestion lands on the identical hash
        let mut one_shot = SlotInfo::new(2, Instant::now(), prompt.clone(), 4, 0.0, 0);
        one_shot.start_prefill();
        one_shot.advance_prefill(prompt.len());
        assert_eq!(one_shot.prefix_hash, chunked.prefix_hash);
    }

    #[test]
    #[should_panic(expected = "chunk overruns the prompt")]
    fn prefill_overrun_is_rejected() {
        let mut s = info(4);
        s.start_prefill();
        s.advance_prefill(3); // prompt is only 2 tokens long
    }

    #[test]
    #[should_panic(expected = "advance_prefill outside prefill")]
    fn prefill_advance_requires_prefill_phase() {
        let mut s = info(5); // fresh slots default to Decoding (cursor walk)
        s.advance_prefill(1);
    }

    #[test]
    fn alloc_release_cycle() {
        let mut t = SlotTable::new(2);
        let a = t.alloc(info(1)).unwrap();
        let b = t.alloc(info(2)).unwrap();
        assert_ne!(a, b);
        assert!(t.alloc(info(3)).is_none(), "capacity enforced");
        assert_eq!(t.release(a).unwrap().request_id, 1);
        let c = t.alloc(info(3)).unwrap();
        assert_eq!(c, a, "freed slot reused");
        assert_eq!(t.active(), 2);
    }

    #[test]
    fn release_empty_is_none() {
        let mut t = SlotTable::new(1);
        assert!(t.release(0).is_none());
    }

    #[test]
    fn active_indices_sorted_and_exact() {
        let mut t = SlotTable::new(4);
        let a = t.alloc(info(1)).unwrap();
        let b = t.alloc(info(2)).unwrap();
        let c = t.alloc(info(3)).unwrap();
        t.release(b);
        let idx = t.active_indices();
        assert_eq!(idx.len(), 2);
        assert!(idx.contains(&a) && idx.contains(&c));
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn leak_freedom_and_uniqueness_property() {
        crate::propcheck::check("slot-table-invariants", crate::propcheck::default_cases(), |g| {
            let cap = g.usize_in(1, 12);
            let mut t = SlotTable::new(cap);
            let mut live: Vec<(usize, u64)> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1, 60) {
                if g.bool() {
                    if let Some(idx) = t.alloc(info(next_id)) {
                        // uniqueness: idx must not be currently live
                        if live.iter().any(|&(i, _)| i == idx) {
                            return Err(format!("slot {idx} double-allocated"));
                        }
                        live.push((idx, next_id));
                        next_id += 1;
                    } else if live.len() != cap {
                        return Err("alloc failed below capacity".into());
                    }
                } else if !live.is_empty() {
                    let pick = g.usize_in(0, live.len() - 1);
                    let (idx, id) = live.swap_remove(pick);
                    match t.release(idx) {
                        Some(info) if info.request_id == id => {}
                        Some(info) => {
                            return Err(format!(
                                "slot {idx} returned request {} not {id}",
                                info.request_id
                            ))
                        }
                        None => return Err(format!("slot {idx} lost its info")),
                    }
                }
                if t.active() != live.len() {
                    return Err(format!(
                        "active() = {} but {} live",
                        t.active(),
                        live.len()
                    ));
                }
            }
            // leak freedom: releasing everything restores full capacity
            for (idx, _) in live {
                t.release(idx);
            }
            if t.active() != 0 || !t.has_free() {
                return Err("slots leaked".into());
            }
            let mut all = Vec::new();
            for i in 0..cap {
                match t.alloc(info(1000 + i as u64)) {
                    Some(idx) => all.push(idx),
                    None => return Err("cannot re-fill to capacity after drain".into()),
                }
            }
            all.sort_unstable();
            all.dedup();
            if all.len() != cap {
                return Err("duplicate slots after drain/refill".into());
            }
            Ok(())
        });
    }
}
