//! Slot table: maps in-flight requests to decode slots.
//!
//! A slot is one lane of the batched decode state (one (S, Z) RNN pair per
//! layer×head in either engine). The table enforces capacity, guarantees a
//! freed slot is reusable, and never hands the same slot to two requests —
//! invariants propchecked below. Prompt ingestion is tracked per slot: a
//! backend with a prefill path absorbs the whole prompt at admission
//! (`complete_prompt`), otherwise the `cursor` walks it one tick at a time.

use std::time::Instant;

/// Metadata of an active decode slot.
#[derive(Debug, Clone)]
pub struct SlotInfo {
    pub request_id: u64,
    pub started: Instant,
    /// the full prompt; `cursor` indexes the next unconsumed token
    /// (a cursor, not `Vec::remove(0)`, so prompt feed is O(1) per tick)
    pub prompt: Vec<u32>,
    /// how many prompt tokens have been fed already
    pub cursor: usize,
    /// sampled tokens so far
    pub generated: Vec<u32>,
    pub max_new: usize,
    pub temperature: f32,
    /// absolute position of the next token to feed
    pub pos: usize,
}

impl SlotInfo {
    /// Fresh slot state for an admitted request.
    pub fn new(
        request_id: u64,
        started: Instant,
        prompt: Vec<u32>,
        max_new: usize,
        temperature: f32,
    ) -> Self {
        SlotInfo {
            request_id,
            started,
            prompt,
            cursor: 0,
            generated: Vec::new(),
            max_new,
            temperature,
            pos: 0,
        }
    }

    /// The token to feed on the next tick: the prompt under the cursor, or
    /// the last sampled token once the prompt is consumed.
    pub fn next_token(&self) -> u32 {
        if self.cursor < self.prompt.len() {
            self.prompt[self.cursor]
        } else {
            *self.generated.last().expect("past the prompt there is always a sampled token")
        }
    }

    /// True once every prompt token has been fed.
    pub fn prompt_done(&self) -> bool {
        self.cursor >= self.prompt.len()
    }

    /// Mark the whole prompt as ingested in one shot — the prefill path.
    /// The cursor jumps past the prompt and `pos` to the first generation
    /// position, so the slot's next tick feeds its first sampled token
    /// instead of walking the prompt.
    pub fn complete_prompt(&mut self) {
        self.cursor = self.prompt.len();
        self.pos = self.prompt.len();
    }
}

/// Fixed-capacity slot allocator.
#[derive(Debug)]
pub struct SlotTable {
    slots: Vec<Option<SlotInfo>>,
    free: Vec<usize>,
}

impl SlotTable {
    pub fn new(capacity: usize) -> Self {
        SlotTable {
            slots: (0..capacity).map(|_| None).collect(),
            free: (0..capacity).rev().collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn active(&self) -> usize {
        self.slots.len() - self.free.len()
    }

    pub fn has_free(&self) -> bool {
        !self.free.is_empty()
    }

    /// Claim a slot; returns its index.
    pub fn alloc(&mut self, info: SlotInfo) -> Option<usize> {
        let idx = self.free.pop()?;
        debug_assert!(self.slots[idx].is_none(), "slot {idx} double-allocated");
        self.slots[idx] = Some(info);
        Some(idx)
    }

    /// Release a slot, returning its info.
    pub fn release(&mut self, idx: usize) -> Option<SlotInfo> {
        let info = self.slots[idx].take()?;
        self.free.push(idx);
        Some(info)
    }

    pub fn get(&self, idx: usize) -> Option<&SlotInfo> {
        self.slots.get(idx).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, idx: usize) -> Option<&mut SlotInfo> {
        self.slots.get_mut(idx).and_then(|s| s.as_mut())
    }

    /// Indices of active slots (ascending).
    pub fn active_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|_| i))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(id: u64) -> SlotInfo {
        SlotInfo::new(id, Instant::now(), vec![1, 2], 4, 0.0)
    }

    #[test]
    fn prompt_cursor_walks_then_repeats_generation() {
        let mut s = info(1);
        assert!(!s.prompt_done());
        assert_eq!(s.next_token(), 1);
        s.cursor += 1;
        assert_eq!(s.next_token(), 2);
        s.cursor += 1;
        assert!(s.prompt_done());
        s.generated.push(7);
        assert_eq!(s.next_token(), 7);
    }

    #[test]
    fn complete_prompt_jumps_to_generation() {
        let mut s = info(2);
        s.complete_prompt();
        assert!(s.prompt_done());
        assert_eq!(s.pos, 2, "pos must land on the first generation position");
        s.generated.push(9);
        assert_eq!(s.next_token(), 9, "next tick feeds the sampled token");
    }

    #[test]
    fn alloc_release_cycle() {
        let mut t = SlotTable::new(2);
        let a = t.alloc(info(1)).unwrap();
        let b = t.alloc(info(2)).unwrap();
        assert_ne!(a, b);
        assert!(t.alloc(info(3)).is_none(), "capacity enforced");
        assert_eq!(t.release(a).unwrap().request_id, 1);
        let c = t.alloc(info(3)).unwrap();
        assert_eq!(c, a, "freed slot reused");
        assert_eq!(t.active(), 2);
    }

    #[test]
    fn release_empty_is_none() {
        let mut t = SlotTable::new(1);
        assert!(t.release(0).is_none());
    }

    #[test]
    fn active_indices_sorted_and_exact() {
        let mut t = SlotTable::new(4);
        let a = t.alloc(info(1)).unwrap();
        let b = t.alloc(info(2)).unwrap();
        let c = t.alloc(info(3)).unwrap();
        t.release(b);
        let idx = t.active_indices();
        assert_eq!(idx.len(), 2);
        assert!(idx.contains(&a) && idx.contains(&c));
        assert!(idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn leak_freedom_and_uniqueness_property() {
        crate::propcheck::check("slot-table-invariants", crate::propcheck::default_cases(), |g| {
            let cap = g.usize_in(1, 12);
            let mut t = SlotTable::new(cap);
            let mut live: Vec<(usize, u64)> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..g.usize_in(1, 60) {
                if g.bool() {
                    if let Some(idx) = t.alloc(info(next_id)) {
                        // uniqueness: idx must not be currently live
                        if live.iter().any(|&(i, _)| i == idx) {
                            return Err(format!("slot {idx} double-allocated"));
                        }
                        live.push((idx, next_id));
                        next_id += 1;
                    } else if live.len() != cap {
                        return Err("alloc failed below capacity".into());
                    }
                } else if !live.is_empty() {
                    let pick = g.usize_in(0, live.len() - 1);
                    let (idx, id) = live.swap_remove(pick);
                    match t.release(idx) {
                        Some(info) if info.request_id == id => {}
                        Some(info) => {
                            return Err(format!(
                                "slot {idx} returned request {} not {id}",
                                info.request_id
                            ))
                        }
                        None => return Err(format!("slot {idx} lost its info")),
                    }
                }
                if t.active() != live.len() {
                    return Err(format!(
                        "active() = {} but {} live",
                        t.active(),
                        live.len()
                    ));
                }
            }
            // leak freedom: releasing everything restores full capacity
            for (idx, _) in live {
                t.release(idx);
            }
            if t.active() != 0 || !t.has_free() {
                return Err("slots leaked".into());
            }
            let mut all = Vec::new();
            for i in 0..cap {
                match t.alloc(info(1000 + i as u64)) {
                    Some(idx) => all.push(idx),
                    None => return Err("cannot re-fill to capacity after drain".into()),
                }
            }
            all.sort_unstable();
            all.dedup();
            if all.len() != cap {
                return Err("duplicate slots after drain/refill".into());
            }
            Ok(())
        });
    }
}
